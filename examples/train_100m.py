"""End-to-end driver: train a ~100M-param dense LM for a few hundred
steps under the full C/R runtime (background checkpoints, crash-safe),
on whatever devices exist.

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse
import json
import tempfile
import time

from repro.configs.base import ModelConfig
from repro.core import CheckpointManager, LocalFSBackend
from repro.train.loop import Trainer, TrainJob
from repro.configs import registry as cfg_registry


# ~137M params: 12L d=768 12H ff=3072 vocab=32k, tied embeddings
CONFIG_100M = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=12, d_ff=3072, vocab_size=32_000, head_dim=64,
    act="silu", norm="rmsnorm", tie_embeddings=True,
    source="examples/train_100m",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    # register the config so the C/R Compile op can rebuild the step
    cfg_registry._MODULES["lm-100m"] = "examples.train_100m"
    import sys
    sys.modules.setdefault("examples.train_100m", sys.modules[__name__])

    from repro.models import model as M
    n = M.param_count(CONFIG_100M)
    print(f"lm-100m: {n/1e6:.1f}M params, seq={args.seq}, "
          f"batch={args.batch}, steps={args.steps}")

    root = tempfile.mkdtemp(prefix="repro_100m_")
    mgr = CheckpointManager(LocalFSBackend(root), async_save=True,
                            keep_last=2)
    job = TrainJob(arch="lm-100m",
                   shape_key=f"train_s{args.seq}_b{args.batch}")
    tr = Trainer(job, (1, 1), ("data", "model"), manager=mgr)
    tr.init_state()

    t0 = time.monotonic()
    losses = []
    for step in range(args.steps):
        m = tr.train_steps(1)
        losses.append(m["loss"])
        if (step + 1) % args.ckpt_every == 0:
            tr.save(block=False)
        if (step + 1) % 10 == 0:
            dt = (time.monotonic() - t0) / (step + 1)
            print(f"step {step+1:4d} loss {m['loss']:.4f} "
                  f"({dt*1e3:.0f} ms/step)", flush=True)
    mgr.wait()
    print(f"first loss {losses[0]:.4f} -> last {losses[-1]:.4f} "
          f"(ckpts: {mgr.backend.list_steps()})")
    assert losses[-1] < losses[0], "loss must decrease"


CONFIG = CONFIG_100M  # registry hook


def smoke_config():
    return CONFIG_100M.replace(name="lm-100m-smoke", n_layers=2,
                               d_model=64, n_heads=4, n_kv_heads=4,
                               head_dim=16, d_ff=128, vocab_size=256)


if __name__ == "__main__":
    main()
