"""The paper's Maya demo (§IV) as a training job: periodic background
checkpoints, a crash, a restore into a *fresh lower half* (new mesh, replay
recompiles the step), and a bitwise-identical continuation — plus the
cold-start vs restart timing comparison (Fig. 2).

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import tempfile
import time

import numpy as np

from repro.core import CheckpointManager, LocalFSBackend
from repro.core.failure import FailurePolicy, FailureAction
from repro.train.loop import Trainer, TrainJob

STEPS_BEFORE_CRASH = 6
TOTAL_STEPS = 12


def main() -> None:
    root = tempfile.mkdtemp(prefix="repro_ft_")
    job = TrainJob(arch="phi4-mini-3.8b-smoke", shape_key="train_s32_b4")
    mgr = CheckpointManager(LocalFSBackend(root), async_save=True,
                            keep_last=2)

    # ---------- reference run (no crash) ----------
    ref = Trainer(job, (1, 1), ("data", "model"))
    ref.init_state()
    for _ in range(TOTAL_STEPS):
        ref.train_steps(1)
    ref_digest = ref.params_digest()

    # ---------- run with a crash ----------
    t_cold0 = time.monotonic()
    tr = Trainer(job, (1, 1), ("data", "model"), manager=mgr)
    tr.init_state()
    for s in range(STEPS_BEFORE_CRASH):
        m = tr.train_steps(1)
        if (s + 1) % 3 == 0:
            tr.save(block=False)
            print(f"[run] step {s+1} loss={m['loss']:.4f}  "
                  f"(background checkpoint)")
    mgr.wait()
    cold_start_s = time.monotonic() - t_cold0
    print(f"[run] CRASH simulated at step {STEPS_BEFORE_CRASH} "
          f"(lower half destroyed: mesh, executables, device buffers)")
    del tr

    # ---------- failure policy decides ----------
    policy = FailurePolicy(spares=[], allow_shrink=False)
    action, info = policy.decide(dead=[0], world=[0])
    assert action == FailureAction.RESTART_LAST_CKPT
    print(f"[policy] {action.value}")

    # ---------- restore: fresh lower half + replay + rebind ----------
    t0 = time.monotonic()
    tr2 = Trainer.restore(mgr)
    restore_s = time.monotonic() - t0
    start = int(tr2.upper.get("step"))
    print(f"[restore] resumed at step {start} in {restore_s:.2f}s "
          f"(cold start took {cold_start_s:.2f}s -> "
          f"{cold_start_s / restore_s:.1f}x; paper: 60s -> 4s = 15x)")
    print(f"[restore] op-log replayed: {len(tr2.lower.oplog)} ops "
          f"(pruned from the run's full history at save time)")

    for _ in range(TOTAL_STEPS - start):
        m = tr2.train_steps(1)
    print(f"[cont] final loss={m['loss']:.4f}")

    assert tr2.params_digest() == ref_digest, "continuation diverged!"
    print("[check] BITWISE-IDENTICAL to the uninterrupted run — "
          "transparent checkpointing works end to end.")


if __name__ == "__main__":
    main()
