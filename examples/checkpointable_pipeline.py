"""A third checkpointable workload, written ONLY against ``repro.api``.

This is the agnosticism proof for the public surface: a stateful
streaming-aggregation app (think: a metrics rollup consuming an ordered
event stream) that never imports ``repro.core`` — it declares its
upper-half entries, names its kind, and rebinds in ``bind()`` — and
gets the full machinery for free from ``CheckpointSession``: async
delta-chained snapshots, policy-driven cadence, kill-anywhere restore,
even supervision. Nothing here knows whether the store is the
CRIU-analogue or the DMTCP-analogue; that's a string.

    PYTHONPATH=src python examples/checkpointable_pipeline.py \
        [--events 200] [--store sharded:/tmp/agg?hosts=4]

The demo ingests half the stream, "crashes" (drops the app object),
restores through the app-kind registry, finishes the stream, and
verifies the aggregation state is identical to an uninterrupted run.
"""
from __future__ import annotations

import argparse
import hashlib
import tempfile
from typing import Any, Dict

import numpy as np

from repro.api import (CheckpointSession, Policy, RestoreContext,
                       UpperHalf, register_app_kind)


class StreamAggregator:
    """Streaming per-key aggregation over a deterministic event stream.

    Each event ``i`` is derived from (seed, i) alone, so the stream is
    replayable from any cursor — the app's only durable state is the
    aggregation arrays plus the cursor, which is exactly what it
    declares as upper-half entries."""

    KIND = "stream-agg"

    def __init__(self, n_bins: int = 32, seed: int = 0) -> None:
        self.n_bins = n_bins
        self.seed = seed
        self.cursor = 0
        self.counts = np.zeros(n_bins, np.int64)
        self.sums = np.zeros(n_bins, np.float64)
        self.sumsq = np.zeros(n_bins, np.float64)
        self.quiesced = 0          # times the supervisor flushed us

    # --- the workload ---------------------------------------------------

    def _event(self, i: int) -> tuple:
        rng = np.random.RandomState((self.seed * 1_000_003 + i)
                                    % (2 ** 31 - 1))
        return int(rng.randint(self.n_bins)), float(rng.standard_normal())

    def ingest(self, n: int = 1) -> None:
        for _ in range(n):
            key, value = self._event(self.cursor)
            self.counts[key] += 1
            self.sums[key] += value
            self.sumsq[key] += value * value
            self.cursor += 1

    def digest(self) -> str:
        h = hashlib.blake2b(digest_size=16)
        for arr in (self.counts, self.sums, self.sumsq):
            h.update(np.ascontiguousarray(arr).tobytes())
        h.update(str(self.cursor).encode())
        return h.hexdigest()

    # --- CheckpointableApp protocol ------------------------------------

    def checkpoint_state(self) -> UpperHalf:
        up = UpperHalf()
        up.register("agg", "agg", {"counts": self.counts.copy(),
                                   "sums": self.sums.copy(),
                                   "sumsq": self.sumsq.copy()})
        up.register("cursor", "step", np.int64(self.cursor))
        return up

    def checkpoint_step(self) -> int:
        return self.cursor

    def job_meta(self) -> Dict[str, Any]:
        return {"kind": self.KIND, "n_bins": self.n_bins,
                "seed": self.seed}

    def bind(self, restore: RestoreContext) -> None:
        agg = restore.tree("agg")
        self.counts = np.asarray(agg["counts"], np.int64).copy()
        self.sums = np.asarray(agg["sums"], np.float64).copy()
        self.sumsq = np.asarray(agg["sumsq"], np.float64).copy()
        self.cursor = int(restore.scalar("cursor"))
        restore.release()

    def quiesce(self) -> None:
        # nothing buffered in this app; the hook exists so a supervisor
        # teardown is observable (and so the optional surface is proven)
        self.quiesced += 1


@register_app_kind(StreamAggregator.KIND)
def _restore_stream_agg(restore: RestoreContext) -> StreamAggregator:
    app = StreamAggregator(n_bins=int(restore.job["n_bins"]),
                           seed=int(restore.job["seed"]))
    app.bind(restore)
    return app


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=200)
    ap.add_argument("--bins", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store", default=None,
                    help="store spec (default: localfs:<tmpdir>)")
    args = ap.parse_args()
    store = args.store or f"localfs:{tempfile.mkdtemp(prefix='agg_')}"

    # uninterrupted reference
    ref = StreamAggregator(args.bins, args.seed)
    ref.ingest(args.events)

    policy = Policy(interval=10, chain=4, keep_last=4)
    with CheckpointSession(store, policy) as sess:
        app = sess.attach(StreamAggregator(args.bins, args.seed))
        for _ in range(args.events // 2):
            app.ingest(1)
            sess.maybe_snapshot()
        sess.wait()
        print(f"ingested {app.cursor} events, snapshots at "
              f"{sess.backend.list_steps()}")
        del app                       # crash: the process state is gone

        app = sess.restore("latest")  # registry-resolved by kind
        print(f"restored at cursor {app.cursor} from {store}")
        app.ingest(args.events - app.cursor)
        ok = app.digest() == ref.digest()
        print(f"aggregation state identical to uninterrupted run: {ok}")
        assert ok


if __name__ == "__main__":
    main()
