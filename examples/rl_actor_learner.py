"""An elastic RL actor-learner workload, written ONLY against
``repro.api``.

A second third-party kind for the agnosticism proof (alongside
``checkpointable_pipeline.py``), this one exercising the *elastic*
restore surface: ``n_actors`` is topology, not state. Experience
streams are a data constant — stream ``s`` at environment step ``t``
yields a transition derived from ``(seed, s, t)`` alone — and the
learner consumes transitions in fixed stream-major order, so the
learned weights are bit-identical no matter how many actors collected
them. Restore onto more (or fewer) actors by passing ``n_actors=`` to
``CheckpointSession.restore``, exactly like ``n_slots=`` re-slots the
serving engine; actor→stream ownership is rebuilt round-robin and can
be moved later through ``apply_reassignment`` (the supervisor's hook).

    PYTHONPATH=src python examples/rl_actor_learner.py \
        [--steps 120] [--actors 2] [--restore-actors 3] \
        [--store sharded:/tmp/rl?hosts=4]

The demo trains halfway, "crashes" (drops the app object), restores
onto a different actor count through the app-kind registry, finishes,
and verifies the policy weights match an uninterrupted run bit for bit.
"""
from __future__ import annotations

import argparse
import hashlib
import tempfile
from typing import Any, Dict, List, Sequence, Tuple

import numpy as np

from repro.api import (CheckpointSession, Policy, RestoreContext,
                       UpperHalf, register_app_kind)


class RLActorLearner:
    """TD(0)-flavored linear learner over deterministic experience
    streams.

    Durable state is the learner's weights, per-stream visit counts and
    the global environment step — what ``checkpoint_state`` declares.
    Actor count and stream ownership are topology: they shape who
    *collects*, never what is *learned*."""

    KIND = "rl-actor-learner"

    def __init__(self, n_actors: int = 2, n_streams: int = 8,
                 dim: int = 16, seed: int = 0) -> None:
        if n_actors < 1:
            raise ValueError(f"n_actors={n_actors} must be >= 1")
        self.n_actors = n_actors
        self.n_streams = n_streams
        self.dim = dim
        self.seed = seed
        self.lr = 0.05
        self.t = 0
        self.weights = np.zeros(dim, np.float64)
        self.visits = np.zeros(n_streams, np.int64)
        self.owner = {s: s % n_actors for s in range(n_streams)}
        self.quiesced = 0
        self.reassigned = 0

    # --- the workload ---------------------------------------------------

    def _transition(self, stream: int, t: int) -> Tuple[np.ndarray, float]:
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + stream * 9_973 + t) % (2 ** 31 - 1))
        x = rng.standard_normal(self.dim)
        reward = float(np.tanh(x[:4].sum()))
        return x, reward

    def collect_and_learn(self, n: int = 1) -> None:
        """n environment steps: every actor collects from its owned
        streams, the learner applies the transitions in stream order —
        the same sequence of updates for any ownership layout."""
        for _ in range(n):
            for s in range(self.n_streams):
                x, r = self._transition(s, self.t)
                td = r - float(self.weights @ x)
                self.weights = self.weights + self.lr * td * x
                self.visits[s] += 1
            self.t += 1

    def digest(self) -> str:
        h = hashlib.blake2b(digest_size=16)
        h.update(np.ascontiguousarray(self.weights).tobytes())
        h.update(np.ascontiguousarray(self.visits).tobytes())
        h.update(str(self.t).encode())
        return h.hexdigest()

    # --- CheckpointableApp protocol ------------------------------------

    def checkpoint_state(self) -> UpperHalf:
        up = UpperHalf()
        up.register("learner", "params", {"weights": self.weights.copy()})
        up.register("visits", "agg", {"visits": self.visits.copy()})
        up.register("t", "step", np.int64(self.t))
        return up

    def checkpoint_step(self) -> int:
        return self.t

    def job_meta(self) -> Dict[str, Any]:
        # n_actors rides along as the *last* topology, a default the
        # restore binder uses when the caller doesn't re-slot
        return {"kind": self.KIND, "n_streams": self.n_streams,
                "dim": self.dim, "seed": self.seed,
                "n_actors": self.n_actors}

    def bind(self, restore: RestoreContext) -> None:
        self.weights = np.asarray(restore.tree("learner")["weights"],
                                  np.float64).copy()
        self.visits = np.asarray(restore.tree("visits")["visits"],
                                 np.int64).copy()
        self.t = int(restore.scalar("t"))
        restore.release()

    def quiesce(self) -> None:
        # actors have no buffered transitions (collect == learn here);
        # the hook proves the optional surface for supervisor teardown
        self.quiesced += 1

    def apply_reassignment(
            self, assignment: Sequence[Tuple[int, int]]) -> None:
        """Adopt (actor, stream) ownership pairs — a supervisor moving
        collection off a dead actor. Ownership is topology: the learned
        trajectory is unchanged by construction."""
        for actor, stream in assignment:
            self.owner[int(stream)] = int(actor)
        self.reassigned += 1


@register_app_kind(RLActorLearner.KIND)
def _restore_rl(restore: RestoreContext,
                n_actors: int = None) -> RLActorLearner:
    """Elastic binder: ``n_actors`` re-slots collection onto a larger or
    smaller actor pool; omitted, the checkpoint's own topology is
    reused."""
    jm = restore.job
    app = RLActorLearner(
        n_actors=int(n_actors if n_actors is not None
                     else jm.get("n_actors", 1)),
        n_streams=int(jm["n_streams"]), dim=int(jm["dim"]),
        seed=int(jm["seed"]))
    app.bind(restore)
    return app


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--actors", type=int, default=2)
    ap.add_argument("--restore-actors", type=int, default=3)
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store", default=None,
                    help="store spec (default: localfs:<tmpdir>)")
    args = ap.parse_args()
    store = args.store or f"localfs:{tempfile.mkdtemp(prefix='rl_')}"

    # uninterrupted reference (actor count deliberately different: the
    # trajectory must not depend on it)
    ref = RLActorLearner(1, args.streams, seed=args.seed)
    ref.collect_and_learn(args.steps)

    policy = Policy(interval=10, chain=4, keep_last=4)
    with CheckpointSession(store, policy) as sess:
        app = sess.attach(RLActorLearner(args.actors, args.streams,
                                         seed=args.seed))
        for _ in range(args.steps // 2):
            app.collect_and_learn(1)
            sess.maybe_snapshot()
        sess.wait()
        print(f"trained to env step {app.t} on {app.n_actors} actors, "
              f"snapshots at {sess.backend.list_steps()}")
        del app                       # crash: the process state is gone

        app = sess.restore("latest", n_actors=args.restore_actors)
        print(f"restored at env step {app.t} onto {app.n_actors} actors")
        app.collect_and_learn(args.steps - app.t)
        ok = app.digest() == ref.digest()
        print(f"weights identical to uninterrupted run: {ok}")
        assert ok


if __name__ == "__main__":
    main()
