"""Quickstart: train a small model under the C/R runtime, checkpoint,
and print losses.

    PYTHONPATH=src python examples/quickstart.py --arch qwen2.5-32b-smoke
"""
import argparse
import tempfile

from repro.core import CheckpointManager, LocalFSBackend
from repro.train.loop import Trainer, TrainJob


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b-smoke",
                    help="registry id or '<id>-smoke'")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    root = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    # delta_base_interval=4: full base snapshot every 4th checkpoint,
    # XOR delta links between — restore walks the chain automatically
    mgr = CheckpointManager(LocalFSBackend(root), async_save=True,
                            keep_last=3, delta_base_interval=4)
    job = TrainJob(arch=args.arch, shape_key="train_s32_b4")
    tr = Trainer(job, (1, 1), ("data", "model"), manager=mgr)
    tr.init_state()
    print(f"arch={args.arch} params checkpointing to {root}")

    for step in range(args.steps):
        m = tr.train_steps(1)
        print(f"step {m['step']:4.0f} loss {m['loss']:.4f} "
              f"lr {m['lr']:.2e} |g| {m['grad_norm']:.3f}")
        if (step + 1) % args.ckpt_every == 0:
            tr.snapshot()  # non-blocking: encode+write overlap next steps
            print(f"  checkpoint @ step {int(tr.upper.get('step'))} "
                  f"(async)")
    mgr.wait()
    s = mgr.stats
    print(f"done; checkpoints at steps {mgr.backend.list_steps()} "
          f"({s['bytes_written'] / 2**20:.1f} MiB written for "
          f"{s['bytes_logical'] / 2**20:.1f} MiB logical)")


if __name__ == "__main__":
    main()
