"""Quickstart: train a small model under the C/R runtime through the
public session API, checkpoint on a policy cadence, and print losses.

    PYTHONPATH=src python examples/quickstart.py --arch qwen2.5-32b-smoke
"""
import argparse
import tempfile

from repro.api import CheckpointSession, Policy
from repro.train.loop import Trainer, TrainJob


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b-smoke",
                    help="registry id or '<id>-smoke'")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--store", default=None,
                    help="store spec, e.g. localfs:/tmp/job or "
                         "sharded:/tmp/job?hosts=4 (default: a localfs "
                         "tempdir) — swapping checkpoint packages is "
                         "this one string")
    args = ap.parse_args()

    store = args.store or f"localfs:{tempfile.mkdtemp(prefix='repro_ckpt_')}"
    # chain=4: full base snapshot every 4th checkpoint, XOR delta links
    # between — restore walks the chain automatically
    sess = CheckpointSession(store, Policy(interval=args.ckpt_every,
                                           keep_last=3, chain=4))
    job = TrainJob(arch=args.arch, shape_key="train_s32_b4")
    tr = sess.attach(Trainer(job, (1, 1), ("data", "model"),
                             manager=sess.manager))
    tr.init_state()
    print(f"arch={args.arch} params checkpointing to {store}")

    for _ in range(args.steps):
        m = tr.train_steps(1)
        print(f"step {m['step']:4.0f} loss {m['loss']:.4f} "
              f"lr {m['lr']:.2e} |g| {m['grad_norm']:.3f}")
        if sess.maybe_snapshot() is not None:
            print(f"  checkpoint @ step {tr.checkpoint_step()} (async)")
    sess.wait()
    s = sess.stats
    print(f"done; checkpoints at steps {sess.backend.list_steps()} "
          f"({s['bytes_written'] / 2**20:.1f} MiB written for "
          f"{s['bytes_logical'] / 2**20:.1f} MiB logical)")


if __name__ == "__main__":
    main()
