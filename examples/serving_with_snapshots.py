"""Serving with live-session snapshots: the paper's §IV demo (the
artist reopens Maya and the scene is still there) for inference.

A continuous-batching engine built through the logged C/R runtime
snapshots its *complete* session state mid-generation — KV cache,
in-flight requests with their partial outputs, the waiting queue — and
a later ``ServingEngine.restore`` brings every session back, even onto
a *different slot count* (elastic re-slotting: each session's KV slice
is rebuilt by replaying its token history through prefill).

    PYTHONPATH=src python examples/serving_with_snapshots.py
"""
import tempfile

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import CheckpointManager, LocalFSBackend
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    cfg = get_smoke_config("phi4-mini-3.8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=5) for _ in range(4)]

    # reference: the uninterrupted run
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    ref_eng = ServingEngine(cfg, params, mesh, n_slots=2, max_seq=48)
    refs = [Request(rid=i, prompt=p.copy(), max_new=8)
            for i, p in enumerate(prompts)]
    for r in refs:
        ref_eng.submit(r)
    ref_eng.run_until_drained(max_steps=200)
    ref = {r.rid: list(r.out) for r in refs}

    # the interrupted run: engine under the logged runtime, snapshot
    # mid-generation (non-blocking in production; blocking here so the
    # 'crash' below can't outrun the commit)
    mgr = CheckpointManager(
        LocalFSBackend(tempfile.mkdtemp(prefix="repro_serve_")),
        async_save=True)
    eng = ServingEngine.create("phi4-mini-3.8b-smoke", params, (1, 1),
                               n_slots=2, max_seq=48, manager=mgr)
    reqs = [Request(rid=i, prompt=p.copy(), max_new=8)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    for _ in range(4):
        eng.step()
    eng.snapshot(block=True)
    print(f"[snapshot] engine step {eng.steps}: "
          f"{sum(r is not None for r in eng.slot_req)} in flight, "
          f"{len(eng.queue)} queued")
    del eng  # crash: engine, executables, device buffers all gone

    # restore onto THREE slots (the checkpoint had two): every live
    # session re-enters through prefill replay of its history
    eng2 = ServingEngine.restore(mgr, params, n_slots=3)
    live = eng2.live_requests()
    print(f"[restore] engine step {eng2.steps} on {eng2.n_slots} slots, "
          f"{len(live)} sessions resumed "
          f"(materialize {eng2.incarnation.timings['materialize_s']:.2f}s, "
          f"replay {eng2.incarnation.timings['replay_s']:.2f}s)")
    eng2.run_until_drained(max_steps=200)

    for r in live:  # every resumed session must continue exactly
        assert r.out == ref[r.rid], (r.rid, r.out, ref[r.rid])
    print("[check] restored sessions finished token-identically:",
          {r.rid: r.out for r in live})


if __name__ == "__main__":
    main()
