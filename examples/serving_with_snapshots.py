"""Serving with session snapshots: continuous batching over a small
model; live KV caches checkpoint as upper-half state and a restored
engine continues generating the same tokens (the 'artist resumes where
Maya crashed' story, for inference sessions).

    PYTHONPATH=src python examples/serving_with_snapshots.py
"""
import tempfile

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import CheckpointManager, LocalFSBackend, OpLog, UpperHalf
from repro.core.split_state import fill_like
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    cfg = get_smoke_config("phi4-mini-3.8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))

    eng = ServingEngine(cfg, params, mesh, n_slots=2, max_seq=48)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i, prompt=rng.randint(0, cfg.vocab_size, size=5),
                    max_new=8) for i in range(4)]
    for r in reqs:
        eng.submit(r)

    # serve halfway, then snapshot the live session state
    for _ in range(4):
        eng.step()
    up = UpperHalf()
    up.register("kv_cache", "cache", eng.cache)
    up.register("slot_pos", "meta", np.array(eng.slot_pos))
    up.register("slot_tok", "meta", np.array(eng.slot_tok))
    mgr = CheckpointManager(
        LocalFSBackend(tempfile.mkdtemp(prefix="repro_serve_")),
        async_save=False)
    mgr.save(eng.steps, up, OpLog())
    print(f"[snapshot] engine at step {eng.steps}, "
          f"{sum(r.done for r in reqs)} requests done")

    # finish the original engine for reference outputs
    mid_outputs = {r.rid: list(r.out) for r in reqs}
    eng.run_until_drained(max_steps=200)
    ref = {r.rid: list(r.out) for r in reqs}

    # 'crash' + restore into a fresh engine (fresh lower half: new cache
    # buffers; upper half rebinds the session)
    r = mgr.restore()
    eng2 = ServingEngine(cfg, params, mesh, n_slots=2, max_seq=48)
    eng2.cache = jax.tree.map(
        jax.numpy.asarray, fill_like(eng2.cache, r.entries["kv_cache"]))
    eng2.slot_pos = np.asarray(r.entries["slot_pos"][""]).copy()
    eng2.slot_tok = np.asarray(r.entries["slot_tok"][""]).copy()
    # resubmit the in-flight requests with their partial outputs
    for req in reqs:
        req.out = list(mid_outputs[req.rid])
        req.done = False
    eng2.slot_req = [reqs[0], reqs[1]]
    eng2.queue = [q for q in reqs[2:]
                  if len(mid_outputs[q.rid]) < q.max_new]
    for q in eng2.queue:
        q.out = []
    eng2.run_until_drained(max_steps=200)
    got = {q.rid: list(q.out) for q in reqs}

    for rid in (0, 1):  # the two in-flight sessions must continue exactly
        assert got[rid] == ref[rid], (rid, got[rid], ref[rid])
    print("[check] restored sessions continued identically:",
          {k: v for k, v in got.items()})


if __name__ == "__main__":
    main()
