"""Per-move blackout of live migration, next to the restart MTTR it
replaces.

The fleet claim: moving live serving sessions between engines through
the C/R move channel costs a hot-spare-class blackout (~tens of ms per
batch, only the frozen batch stalls), not a restart-class one (seconds:
tear everything down, restore the full engine checkpoint). This
benchmark runs a Poisson-loaded fleet, migrates the source engine's
sessions mid-generation with per-batch freezing, and measures:

  live_move  — worst per-batch freeze → serving-again wall time (the
               blackout one session could observe), after the one-time
               admission-bucket compiles are warm (a production engine
               has them compiled; first-move numbers are reported in
               the detail column);
  restart    — the non-live alternative for the same sessions: restore
               the full engine checkpoint (eager, same slot count) and
               prove it serves again.

Zero dropped or duplicated requests is asserted, not measured — a fast
move that loses work is not a move.

CLI:
  PYTHONPATH=src:. python benchmarks/migration_blackout.py \
      [--smoke] [--check] [--json BENCH_migration.json]

``--check`` is the CI gate (soft — shared-runner timing is noisy): the
warm per-batch blackout must beat the restart path, or live migration
bought nothing.
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time

import numpy as np

ARCHS = {"small": "starcoder2-3b-smoke", "medium": "qwen2.5-32b-smoke"}
SMOKE_ARCHS = {"small": "starcoder2-3b-smoke"}
KINDS = ("live_move", "restart")

# prompt length pins the admission prefill bucket: histories stay under
# the width-16 bucket for every admission this benchmark performs, so
# one warmup request per engine compiles everything the moves reuse
PROMPT_LEN = 9
WARM_PROMPT_LEN = 17


def _build(arch: str, n_slots: int, max_seq: int = 64):
    import jax
    from repro.configs import registry as cfg_registry
    from repro.models import model as M
    from repro.serving.engine import ServingEngine
    cfg = cfg_registry.resolve_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    return cfg, params, mesh, ServingEngine(
        cfg, params, mesh, n_slots=n_slots, max_seq=max_seq)


def _move(arch: str, n_sessions: int, batch: int) -> tuple:
    """One loaded fleet, one mid-generation move; returns
    ((warm_blackout_s, detail), (restart_s, detail))."""
    import jax
    from repro.api import CheckpointSession
    from repro.core.migration import FleetRouter
    from repro.models import model as M
    from repro.serving.engine import ServingEngine
    from repro.serving.traffic import TrafficGenerator

    root = tempfile.mkdtemp()
    sess = None
    try:
        cfg, params, mesh, src = _build(arch, n_slots=4)
        dst = ServingEngine(cfg, params, mesh, n_slots=2, max_seq=64)
        router = FleetRouter({"src": src, "dst": dst},
                             via=f"localfs:{root}/fleet")

        # warm both engines' admission buckets + decode executables: the
        # moves below must measure the move, not one-time jit compiles
        warm = np.arange(1, WARM_PROMPT_LEN + 1, dtype=np.int32)
        for name in ("src", "dst"):
            router.submit(warm % (cfg.vocab_size - 1) + 1, 2, engine=name)
        while router.inflight:
            router.step()

        traffic = TrafficGenerator(
            rate=max(1.0, n_sessions / 4), seed=0, vocab=cfg.vocab_size,
            prompt_len=(PROMPT_LEN, PROMPT_LEN), max_new=(4, 6),
            limit=n_sessions)
        while not traffic.drained():
            traffic.tick(router, engine="src")
            router.step()                      # arrivals mid-generation
        router.step()                          # everyone past token 1

        cold = router.migrate("src", "dst", batch=batch,
                              include_queue=True)
        for _ in range(2):
            router.step()
        warm_res = router.migrate("dst", "src", batch=batch,
                                  include_queue=True)
        while router.inflight:
            router.step()
        s = router.stats()
        assert not s["dropped"] and not s["duplicates"], s
        live_detail = (f"moved={len(warm_res.moved)} batch={batch} "
                       f"batches={len(warm_res.batches)} "
                       f"cold_first_move={cold.blackout_s:.3f}s")
        live = (warm_res.blackout_s, live_detail)

        # the non-live alternative: full engine checkpoint -> eager
        # restore at the same slot count -> first step
        sess = CheckpointSession(f"localfs:{root}/restart")
        eng = ServingEngine.create(arch, params, (len(jax.devices()), 1),
                                   n_slots=4, max_seq=64,
                                   manager=sess.manager)
        sess.attach(eng)
        rng = np.random.RandomState(1)
        from repro.serving.engine import Request
        for i in range(min(n_sessions, 8)):
            eng.submit(Request(
                rid=i + 1,
                prompt=rng.randint(1, cfg.vocab_size,
                                   size=PROMPT_LEN).astype(np.int32),
                max_new=6))
        for _ in range(3):
            eng.step()
        sess.snapshot(block=True)
        t0 = time.monotonic()
        eng2 = sess.restore("latest", expect_kind="serving",
                            params=params, n_slots=4)
        eng2.step()
        restart_s = time.monotonic() - t0
        restart = (restart_s,
                   f"sessions={len(eng2.live_requests())} slots=4 eager")
        return live, restart
    finally:
        if sess is not None:
            sess.close()
        shutil.rmtree(root, ignore_errors=True)


def run(smoke: bool = False) -> list:
    """One row per (size, kind). A size whose scenario blows up is
    reported and *skipped* — check() names the hole instead of the
    whole benchmark dying on a raw traceback."""
    import sys
    rows = []
    n_sessions = 12 if smoke else 1000
    for name, arch in (SMOKE_ARCHS if smoke else ARCHS).items():
        try:
            # batch=1: the tightest per-session blackout bound the knob
            # offers (one frozen session per round, everyone else keeps
            # decoding) — the number the fleet claim is made on
            live, restart = _move(arch, n_sessions=n_sessions, batch=1)
        except Exception as e:  # noqa: BLE001 — surfaced by check()
            print(f"# migration/{name} FAILED: {e!r}", file=sys.stderr)
            continue
        rows.append((f"migration/{name}/live_move", live[0] * 1e6,
                     live[1]))
        rows.append((f"migration/{name}/restart", restart[0] * 1e6,
                     restart[1]))
    return rows


def check(rows: list, sizes) -> None:
    """The gate: both kinds executed for every expected size, and the
    warm per-batch move blackout beat the restart path — otherwise live
    migration buys nothing over tearing the engine down."""
    by_name = {n: us for n, us, _ in rows}
    failures = []
    for size in sizes:
        for kind in KINDS:
            if f"migration/{size}/{kind}" not in by_name:
                failures.append(f"{size}: {kind} never executed")
    for size in sizes:
        move = by_name.get(f"migration/{size}/live_move")
        restart = by_name.get(f"migration/{size}/restart")
        if move is not None and restart is not None and move >= restart:
            failures.append(
                f"{size}: live-move blackout {move / 1e6:.2f}s >= "
                f"restart {restart / 1e6:.2f}s")
    if failures:
        raise SystemExit("migration gate FAILED: " + "; ".join(failures))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smallest size + a small session count (CI "
                         "regression gate); full mode moves 1000 "
                         "sessions")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless the warm move blackout "
                         "beats the restart path (and every scenario "
                         "executed)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (CI artifact)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for n, us, derived in rows:
        print(f"{n},{us:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": n, "us": us, "derived": d}
                       for n, us, d in rows], f, indent=2)
    if args.check:
        check(rows, (SMOKE_ARCHS if args.smoke else ARCHS).keys())


if __name__ == "__main__":
    main()
