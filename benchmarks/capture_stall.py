"""Dirty-chunk capture gate: snapshot cost must scale with what changed,
not with model size.

Scenario (the typical adjacent-step training delta the ISSUE names): a
model of many layer leaves where each step touches ONE layer plus a few
scattered rows of an embedding table — <=10% of all chunks dirty. The
dense format-2 path pays a full device->host copy of every leaf on the
caller thread and re-XORs full buffers on the encode thread; the sparse
path (fingerprint dirty detection + dirty-chunk-only transfer, manifest
format 3) must cut BOTH the caller-thread capture stall and the bytes
the encoder processes to <=50% of dense — and, hard CI gate, move
strictly fewer capture bytes. A format-2 checkpoint written by the dense
path must still restore through the Incarnation lifecycle, bit-identical
to the sparse run's final state.

CLI:
  PYTHONPATH=src:. python benchmarks/capture_stall.py \
      [--smoke] [--check] [--json BENCH_capture.json]
"""
from __future__ import annotations

import argparse
import json
import shutil
import statistics
import tempfile

import numpy as np

from repro.core import CheckpointManager, Incarnation, LocalFSBackend, OpLog, UpperHalf

# layers x layer_bytes (jax leaves, one touched per step) + embed_bytes
# (numpy leaf, chunk-sparse in-place updates), chunk size, chained steps
SIZES = {
    "full": dict(layers=32, layer_elems=1 << 20, embed_elems=1 << 24,
                 chunk_bytes=256 * 1024, steps=8),
    "smoke": dict(layers=32, layer_elems=1 << 19, embed_elems=1 << 20,
                  chunk_bytes=64 * 1024, steps=8),
}


def _scenario(cfg, sparse: bool, root: str):
    """Run the update/snapshot sequence; returns per-step stall samples,
    byte counters (chained steps only) and the final live state."""
    import jax.numpy as jnp

    rng = np.random.RandomState(0)
    layers = [jnp.asarray(rng.randn(cfg["layer_elems"]).astype(np.float32))
              for _ in range(cfg["layers"])]
    embed = rng.randn(cfg["embed_elems"]).astype(np.float32)
    chunk_elems = cfg["chunk_bytes"] // 4
    n_embed_chunks = embed.nbytes // cfg["chunk_bytes"]

    mgr = CheckpointManager(
        LocalFSBackend(root), async_save=False,
        delta_base_interval=cfg["steps"] + 2,
        sparse_capture=sparse,
        sparse_chunk_bytes=cfg["chunk_bytes"],
        sparse_min_bytes=2 * cfg["chunk_bytes"])
    up = UpperHalf()
    up.register("params", "params",
                {f"layer_{i}": w for i, w in enumerate(layers)})
    up.register("embed", "params", {"table": embed})
    up.register("step", "step", np.int64(0))
    mgr.save(1, up, OpLog())

    base = dict(mgr.stats)
    stalls = []
    for s in range(2, cfg["steps"] + 2):
        # one layer gets a full functional update (a fresh jax array);
        # every other layer stays the SAME immutable array object
        i = (s - 1) % cfg["layers"]
        layers[i] = jnp.asarray(
            np.asarray(layers[i]) + rng.randn(cfg["layer_elems"])
            .astype(np.float32) * 0.01)
        up.update("params",
                  {f"layer_{j}": w for j, w in enumerate(layers)})
        # ~5% of embedding chunks get scattered row updates
        for c in rng.choice(n_embed_chunks, max(1, n_embed_chunks // 20),
                            replace=False):
            off = int(c) * chunk_elems
            embed[off:off + 16] += 1.0
        up.update("step", np.int64(s))
        t0 = mgr.stats["capture_seconds"]
        mgr.save(s, up, OpLog())
        stalls.append(mgr.stats["capture_seconds"] - t0)

    counters = {k: mgr.stats[k] - base[k]
                for k in ("capture_bytes", "bytes_encoded",
                          "bytes_written", "dirty_chunks", "clean_chunks",
                          "identity_skips")}
    final = {f"layer_{i}": np.asarray(w) for i, w in enumerate(layers)}
    final["embed"] = embed.copy()
    return mgr, stalls, counters, final


def _restore_through_incarnation(mgr, step, final):
    """The acceptance check's restore path: materialize the chain via
    Incarnation and compare bit-for-bit against the live state."""
    inc = Incarnation(mgr, step=step)
    state = inc.materialize()
    inc.build_lower()  # empty op-log: fresh hardware-free lower half
    for i in range(len(final) - 1):
        np.testing.assert_array_equal(
            state.entries["params"][f"['layer_{i}']"], final[f"layer_{i}"])
    np.testing.assert_array_equal(state.entries["embed"]["['table']"],
                                  final["embed"])
    assert int(inc.scalar("step")) == step
    return state.manifest["format"]


def run(smoke: bool = False) -> list:
    cfg = SIZES["smoke" if smoke else "full"]
    rows = []
    res = {}
    for sparse in (False, True):
        root = tempfile.mkdtemp()
        try:
            mgr, stalls, counters, final = _scenario(cfg, sparse, root)
            last = cfg["steps"] + 1
            fmt = _restore_through_incarnation(mgr, last, final)
            assert fmt == (3 if sparse else 2), fmt
            res[sparse] = (statistics.median(stalls), counters, final)
            mode = "sparse" if sparse else "dense"
            rows.append((f"capture_stall/{mode}/stall",
                         statistics.median(stalls) * 1e6,
                         f"steps={cfg['steps']}"))
            for k in ("capture_bytes", "bytes_encoded", "bytes_written"):
                rows.append((f"capture_stall/{mode}/{k}", counters[k],
                             f"per_step={counters[k] // cfg['steps']}"))
        finally:
            shutil.rmtree(root, ignore_errors=True)
    sd, dd = res[True], res[False]
    total_chunks = sd[1]["dirty_chunks"] + sd[1]["clean_chunks"]
    rows.append(("capture_stall/sparse/dirty_fraction",
                 1e6 * sd[1]["dirty_chunks"] / max(1, total_chunks),
                 f"dirty={sd[1]['dirty_chunks']}/{total_chunks}"))
    rows.append(("capture_stall/ratio/stall",
                 1e6 * sd[0] / dd[0], "sparse/dense"))
    for k in ("capture_bytes", "bytes_encoded"):
        rows.append((f"capture_stall/ratio/{k}",
                     1e6 * sd[1][k] / dd[1][k], "sparse/dense"))
    # the two paths must capture the identical state sequence
    for key in sd[2]:
        np.testing.assert_array_equal(sd[2][key], dd[2][key])
    return rows


def check(rows: list) -> None:
    """The gate. Hard CI failure if dirty-capture bytes >= dense-capture
    bytes; acceptance additionally wants stall and encoded bytes <=50%
    of dense at <=10% dirty chunks, and the format-2 restore (asserted
    inside run())."""
    by = {n: v for n, v, _ in rows}
    failures = []
    dirty_frac = by["capture_stall/sparse/dirty_fraction"] / 1e6
    if dirty_frac > 0.10:
        failures.append(f"scenario not sparse enough: {dirty_frac:.1%} "
                        "chunks dirty (> 10%)")
    if by["capture_stall/sparse/capture_bytes"] >= \
            by["capture_stall/dense/capture_bytes"]:
        failures.append("dirty-capture bytes >= dense-capture bytes")
    for k, lim in (("capture_bytes", 0.5), ("bytes_encoded", 0.5),
                   ("stall", 0.5)):
        r = by[f"capture_stall/ratio/{k}"] / 1e6
        if r > lim:
            failures.append(f"sparse/dense {k} ratio {r:.2f} > {lim}")
    if failures:
        raise SystemExit("capture-stall gate FAILED: " + "; ".join(failures))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes (CI regression gate)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless sparse capture beats dense "
                         "(bytes strictly; stall/encoded <= 50%%)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (CI artifact)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_or_bytes,derived")
    for n, v, derived in rows:
        print(f"{n},{v:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": n, "value": v, "derived": d}
                       for n, v, d in rows], f, indent=2)
    if args.check:
        check(rows)


if __name__ == "__main__":
    main()
