"""Time-to-first-admission after restore: streaming vs full-materialize.

The restore pipeline (core.streaming) claims a restored serving engine
can admit its first request while the bulk of the checkpoint — the KV
cache, the cold tier — is still in flight. This benchmark measures that
claim end to end through the public API, against a bandwidth-limited
store (per-GET latency plus per-byte transfer time — a stand-in for a
remote object store; local-FS numbers would hide exactly the I/O the
pipeline overlaps, same spirit as mttr.py's virtual clock). The live
engine holds long prompts, so the checkpoint is shaped like production:
a small hot tier (sessions, scheduler state) and a KV cache that is
most of the bytes.

  eager_ttfa_s        restore with the barrier materializer (every blob
                      fetched and decoded before the engine exists),
                      then submit + admit one new request;
  stream_ttfa_s       the same restore call with ``streaming=True`` —
                      the engine binds after the hot tier, admits the
                      new request while the cache streams behind it;
  stream_drained_s    ... and on to fully drained, for context.

Both walls are restore + first admission; the one-time XLA compile of
the admission prefill is identical on both paths and an order of
magnitude noisier than the I/O under test, so it is paid once outside
the timed windows (shared pre-compiled fn, see _warm_admission).

Both engines then run the same workload to completion and must produce
byte-identical outcomes (digest row) — streaming is a schedule, not a
different restore.

CLI:
  PYTHONPATH=src:. python benchmarks/restore_streaming.py \
      [--smoke] [--check] [--json BENCH_restore_streaming.json]

``--check`` is the CI gate (soft — shared-runner timing is noisy):
time-to-first-admission under streaming must land in <= 0.5x the
full-materialize wall, and the outcome digests must match.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import shutil
import tempfile
import time

import numpy as np

ARCHS = {"small": "starcoder2-3b-smoke", "medium": "qwen2.5-32b-smoke"}
SMOKE_ARCHS = {"small": "starcoder2-3b-smoke"}

# Long prompts make the KV cache carry real entropy (prefill state, not
# elided zero chunks) — the cold tier must dominate the checkpoint the
# way it does in production. One slot stays free at snapshot time so the
# restored engine has somewhere to admit its first post-restore request.
N_SLOTS, MAX_SEQ, N_REQS, PROMPT, MAX_NEW = 4, 512, 3, 400, 8
GET_LATENCY_S = 0.003      # per-GET round trip of the simulated remote
GET_BW_BYTES_S = 1.0e6     # ... and its transfer bandwidth
RESTORE_WORKERS = 8        # same pool size for both restore paths
ADMIT_RATIO_GATE = 0.5     # acceptance bar from the issue


class _RemoteStore:
    """A ShardedBackend with object-store read costs: a per-GET round
    trip plus bytes/bandwidth on blob reads — the only knobs that
    separate 'local SSD' from 'remote' for a restore. Writes are left
    fast (snapshot cost is not under test). The streaming fetcher sees
    this wrapper, finds no ``blob_sources`` override and no
    ShardedBackend instance, and reads through the (slow) ``get_blob``
    as a single source — the worst case for streaming, so the measured
    win is a floor."""

    def __init__(self, inner, latency_s: float, bw: float) -> None:
        self._inner = inner
        self._latency_s = latency_s
        self._bw = bw

    def get_blob(self, name: str) -> bytes:
        data = self._inner.get_blob(name)
        time.sleep(self._latency_s + len(data) / self._bw)
        return data

    def __getattr__(self, attr):
        return getattr(self._inner, attr)


def _requests(n, seed=0, prompt_len=PROMPT):
    from repro.serving.engine import Request
    rng = np.random.RandomState(seed)
    return [Request(rid=seed * 1000 + i,
                    prompt=rng.randint(1, 250,
                                       size=prompt_len).astype(np.int32),
                    max_new=MAX_NEW)
            for i in range(n)]


def _warm_admission(*engines):
    """Compile the width-8 admission prefill once and share it across
    the restored engines, so neither timed window pays the one-time XLA
    compile (identical on both paths, and pure noise next to the I/O
    under test — a production engine admits with a warm compile cache)."""
    import jax.numpy as jnp

    from repro.configs.base import ShapeConfig
    from repro.models import model as M
    from repro.serving.engine import jit_prefill

    e0 = engines[0]
    shape = ShapeConfig("admit_s8_b1", 8, 1, "prefill")
    fn, _ = jit_prefill(e0.cfg, shape, e0.mesh, cache_len=e0.max_seq)
    fn(e0.params, jnp.zeros((1, 8), jnp.int32),
       M.init_cache(e0.cfg, 1, e0.max_seq))
    for e in engines:
        e._admit_prefill[8] = fn


def _drain_digest(eng, extra_req) -> str:
    """Run every live request (plus one more) to completion and digest
    all their outputs — the bit-identity witness between the eager and
    streaming engines."""
    eng.submit(extra_req)
    reqs = {r.rid: r for r in eng.live_requests()}
    reqs[extra_req.rid] = extra_req
    for _ in range(600):
        if not eng.step() and not eng.queue:
            break
    h = hashlib.blake2b(digest_size=12)
    for rid in sorted(reqs):
        h.update(str(rid).encode())
        h.update(np.asarray(reqs[rid].out, np.int64).tobytes())
    h.update(np.asarray(eng.slot_pos).tobytes())
    return h.hexdigest()


def _scenario(arch: str) -> list:
    """Build + checkpoint one live engine, then restore it twice (eager
    and streaming) against the bandwidth-limited store. Returns rows."""
    import jax

    from repro.api import CheckpointSession, Policy
    from repro.configs import registry as cfg_registry
    from repro.core.backends.sharded import ShardedBackend
    from repro.models import model as M
    from repro.serving.engine import ServingEngine

    root = tempfile.mkdtemp()
    rows = []
    sessions = []
    try:
        cfg = cfg_registry.resolve_config(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        policy = Policy(chain=1)

        be = ShardedBackend(root, n_hosts=4, replicate=True)
        sess = CheckpointSession(be, policy)
        sessions.append(sess)
        eng = ServingEngine.create(arch, params, (1, 1),
                                   n_slots=N_SLOTS, max_seq=MAX_SEQ,
                                   manager=sess.manager)
        sess.attach(eng)
        for r in _requests(N_REQS):
            eng.submit(r)
        for _ in range(6):
            eng.step()
        sess.snapshot(block=True)

        def restored(streaming):
            slow = _RemoteStore(
                ShardedBackend(root, n_hosts=4, replicate=True),
                GET_LATENCY_S, GET_BW_BYTES_S)
            s = CheckpointSession.from_manager(
                policy.build_manager(slow), policy)
            sessions.append(s)
            return s.restore(streaming=streaming, params=params,
                             n_slots=N_SLOTS, workers=RESTORE_WORKERS)

        t0 = time.monotonic()
        eager = restored(streaming=False)
        eager_restore_s = time.monotonic() - t0

        t0 = time.monotonic()
        stream = restored(streaming=True)
        stream_restore_s = time.monotonic() - t0

        _warm_admission(eager, stream)   # untimed, shared (see docstring)

        # identical new request for both engines (Request objects are
        # mutated by the engine, so each gets its own copy)
        new_eager, = _requests(1, seed=99, prompt_len=6)
        new_stream, = _requests(1, seed=99, prompt_len=6)

        t0 = time.monotonic()
        stream.submit(new_stream)
        stream._admit()
        assert any(r is new_stream for r in stream.slot_req), \
            "first request not admitted"
        stream_ttfa = stream_restore_s + (time.monotonic() - t0)

        t0 = time.monotonic()
        eager.submit(new_eager)
        eager._admit()
        eager_ttfa = eager_restore_s + (time.monotonic() - t0)

        rows.append((f"restore_streaming/{arch}/eager_ttfa_s",
                     eager_ttfa * 1e6,
                     f"restore {eager_restore_s:.2f}s + admit"))
        rows.append((f"restore_streaming/{arch}/stream_ttfa_s",
                     stream_ttfa * 1e6,
                     f"restore {stream_restore_s:.2f}s + admit; "
                     f"ratio={stream_ttfa / eager_ttfa:.3f} (gate <= "
                     f"{ADMIT_RATIO_GATE})"))

        t0 = time.monotonic()
        d_stream = _drain_digest(stream, _requests(1, seed=7,
                                                   prompt_len=6)[0])
        drained_s = stream_ttfa + (time.monotonic() - t0)
        st = stream.incarnation.stream_timings() or {}
        rows.append((f"restore_streaming/{arch}/stream_drained_s",
                     drained_s * 1e6,
                     f"overlap={st.get('decode_overlap_pct', 0):.0f}% "
                     f"faults={st.get('lazy_faults', 0)}"))

        d_eager = _drain_digest(eager, _requests(1, seed=7,
                                                 prompt_len=6)[0])
        match = d_eager == d_stream
        rows.append((f"restore_streaming/{arch}/digest_match",
                     float(match),
                     f"eager={d_eager} stream={d_stream}"))
        return rows
    finally:
        for s in sessions:
            try:
                s.close()
            except Exception:
                pass
        shutil.rmtree(root, ignore_errors=True)


def run(smoke: bool = False) -> list:
    import sys
    rows = []
    for name, arch in (SMOKE_ARCHS if smoke else ARCHS).items():
        try:
            rows.extend(_scenario(arch))
        except Exception as e:  # noqa: BLE001 — surfaced by check()
            print(f"# restore_streaming/{name} FAILED: {e!r}",
                  file=sys.stderr)
    return rows


def check(rows: list, archs) -> None:
    """The gate: for every size, time-to-first-admission under streaming
    landed in <= ADMIT_RATIO_GATE x the full-materialize wall, and the
    drained outcomes are bit-identical."""
    by_name = {n: (us, d) for n, us, d in rows}
    failures = []
    for arch in archs:
        eager = by_name.get(f"restore_streaming/{arch}/eager_ttfa_s")
        admit = by_name.get(f"restore_streaming/{arch}/stream_ttfa_s")
        digest = by_name.get(f"restore_streaming/{arch}/digest_match")
        if eager is None or admit is None or digest is None:
            failures.append(f"{arch}: scenario did not complete")
            continue
        ratio = admit[0] / eager[0]
        if ratio > ADMIT_RATIO_GATE:
            failures.append(
                f"{arch}: first admission at {ratio:.2f}x the eager "
                f"wall (gate {ADMIT_RATIO_GATE}x): "
                f"stream {admit[0] / 1e6:.2f}s vs eager "
                f"{eager[0] / 1e6:.2f}s")
        if digest[0] != 1.0:
            failures.append(
                f"{arch}: streaming outcome diverged from eager "
                f"({digest[1]})")
    if failures:
        raise SystemExit("restore_streaming gate FAILED: "
                         + "; ".join(failures))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smallest size only (CI regression gate)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless streaming admits in <= "
                         f"{ADMIT_RATIO_GATE}x the full-materialize "
                         "wall with bit-identical outcomes")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (CI artifact)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for n, us, derived in rows:
        print(f"{n},{us:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": n, "us": us, "derived": d}
                       for n, us, d in rows], f, indent=2)
    if args.check:
        check(rows, (SMOKE_ARCHS if args.smoke else ARCHS).values())


if __name__ == "__main__":
    main()
