"""Benchmark harness: one module per paper table/figure.

  restart_speed   — Fig 2: cold start vs C/R restart (Maya 60s -> 4s)
  overhead        — Fig 3: interception overhead (glxgears 8%)
  oplog_bench     — §VI record-prune-replay: log size / replay cost
  ckpt_codec_bench— DESIGN §4.5: delta + int8 checkpoint payloads
  async_snapshot  — step-time overhead of sync vs async (pipelined)
                    snapshots; the <30%-of-sync acceptance gate
  capture_stall   — dirty-chunk capture vs dense: stall + bytes must
                    scale with the change rate (<=50%-of-dense gate)
  mttr            — detection -> serving-again per failure policy
                    (hot-spare / shrink / restart; hot-spare < restart
                    gate)
  ckpt_roofline   — snapshot codec vs machine memory ceiling: capture
                    fingerprint + restore decode GB/s as a fraction of
                    measured memcpy (or HBM_BW on TPU); pinned-fraction
                    gate
  roofline_table  — §Roofline: aggregated dry-run terms (reads
                    benchmarks/results/dryrun; run repro.launch.dryrun
                    first — missing cells simply produce no rows)

Prints ``name,us_per_call,derived`` CSV. Select suites with
``python -m benchmarks.run [suite ...]``.
"""
import sys


def main() -> None:
    from benchmarks import (async_snapshot_bench, capture_stall,
                            ckpt_codec_bench, ckpt_roofline, mttr,
                            oplog_bench, overhead, restart_speed,
                            roofline_table)
    suites = {
        "restart_speed": restart_speed.run,
        "overhead": overhead.run,
        "oplog": oplog_bench.run,
        "ckpt_codec": ckpt_codec_bench.run,
        "async_snapshot": async_snapshot_bench.run,
        "capture_stall": capture_stall.run,
        "ckpt_roofline": ckpt_roofline.run,
        "mttr": mttr.run,
        "roofline": roofline_table.run,
    }
    want = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    failures = []
    for name in want:
        try:
            for row in suites[name]():
                n, us, derived = row
                print(f"{n},{us:.1f},{derived}", flush=True)
        except Exception as e:  # keep the harness honest but resilient
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
    if failures:
        print(f"FAILED suites: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
