"""Step-time overhead of checkpointing: synchronous vs async pipeline.

Three interleaved runs over the same mutating state:
  baseline  step loop, no checkpoints            -> base step time
  sync      save(block=True) every K steps       -> sync step time
  async     snapshot() every K steps (pipeline)  -> async step time

The per-step *overhead* is (mean step − baseline); the headline number is
async overhead as a fraction of sync overhead. The async pipeline's
caller-side cost is only the device→staging capture, so the ratio is the
fraction of checkpoint cost the pipeline fails to hide — the acceptance
bar for this benchmark is < 30%.

CLI:
  PYTHONPATH=src:. python benchmarks/async_snapshot_bench.py [--smoke]
or via the harness:
  PYTHONPATH=src:. python -m benchmarks.run async_snapshot
"""
from __future__ import annotations

import argparse
import shutil
import tempfile
import time
from typing import Dict, Optional

import numpy as np

from repro.core import CheckpointManager, LocalFSBackend, OpLog, UpperHalf


class _Config:
    def __init__(self, smoke: bool = False):
        # state sized so encode+write dwarfs capture; snapshot cadence
        # sized so the pipeline can drain between snapshots (a cadence
        # faster than storage degrades to storage rate by design — that
        # regime is exercised by the backpressure tests, not timed here)
        self.n_floats = 2_000_000 if smoke else 8_000_000
        self.steps = 12 if smoke else 40
        self.save_every = 4 if smoke else 8
        self.step_seconds = 0.01 if smoke else 0.025
        self.mutate_stride = 997  # touches every chunk, cheap + steady


def _mk_state(cfg: _Config) -> UpperHalf:
    rng = np.random.RandomState(0)
    up = UpperHalf()
    up.register("params", "params",
                {"w": rng.randn(cfg.n_floats).astype(np.float32)})
    up.register("opt_state", "opt_state",
                {"mu": rng.randn(cfg.n_floats // 4).astype(np.float32)})
    up.register("step", "step", np.int64(0))
    return up


def _step(cfg: _Config, up: UpperHalf, i: int) -> None:
    """Stand-in train step: fixed compute latency + a strided sparse
    update. The stride touches every chunk (so a snapshot always has a
    full payload to move) while keeping the mutation itself cheap and
    deterministic — step-time variance must come from checkpointing,
    not from the workload stand-in."""
    time.sleep(cfg.step_seconds)
    w = up.get("params")["w"]
    w[(i % cfg.mutate_stride)::cfg.mutate_stride] += 0.01
    up.update("step", np.int64(i))


def _run_loop(cfg: _Config, mode: str, root: str) -> Dict[str, float]:
    up = _mk_state(cfg)
    mgr: Optional[CheckpointManager] = None
    if mode != "baseline":
        # fsync off: the benchmark isolates pipeline overlap; with it on,
        # OS writeback stalls (hundreds of ms, bursty) land on sync and
        # async runs at random and swamp the signal. Durability is the
        # commit-protocol tests' job, not a timing benchmark's.
        mgr = CheckpointManager(LocalFSBackend(root, fsync=False),
                                async_save=(mode == "async"))
        # warm-up save: allocate staging buffers + store the initial
        # blobs so the timed region measures steady-state snapshots
        mgr.save(0, up, OpLog(), block=True)
    times = []
    for i in range(1, cfg.steps + 1):
        t0 = time.monotonic()
        _step(cfg, up, i)
        if mgr is not None and i % cfg.save_every == 0:
            mgr.save(i, up, OpLog(), block=(mode == "sync"))
        times.append(time.monotonic() - t0)
    t0 = time.monotonic()
    if mgr is not None:
        mgr.wait()
    drain_s = time.monotonic() - t0
    return {"mean_step": float(np.mean(times)),
            "p50_step": float(np.median(times)),
            "max_step": float(np.max(times)),
            "drain": drain_s}


def run(smoke: bool = False) -> list:
    cfg = _Config(smoke=smoke)
    res = {}
    for mode in ("baseline", "sync", "async"):
        root = tempfile.mkdtemp(prefix=f"snapbench_{mode}_")
        try:
            res[mode] = _run_loop(cfg, mode, root)
        finally:
            shutil.rmtree(root, ignore_errors=True)

    base = res["baseline"]["mean_step"]
    sync_oh = res["sync"]["mean_step"] - base
    async_oh = res["async"]["mean_step"] - base
    ratio = async_oh / sync_oh if sync_oh > 0 else float("nan")
    rows = [
        ("async_snapshot/baseline_step", base * 1e6, ""),
        ("async_snapshot/sync_step", res["sync"]["mean_step"] * 1e6,
         f"overhead={sync_oh * 1e3:.2f}ms_max={res['sync']['max_step'] * 1e3:.1f}ms"),
        ("async_snapshot/async_step", res["async"]["mean_step"] * 1e6,
         f"overhead={async_oh * 1e3:.2f}ms_max={res['async']['max_step'] * 1e3:.1f}ms"),
        ("async_snapshot/overhead_ratio", ratio * 100.0,
         f"async_vs_sync_overhead={ratio * 100.0:.1f}%_target<30%"),
    ]
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small state + few steps (CI regression gate)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless async overhead < 30%% of sync")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    rows = run(smoke=args.smoke)
    for n, us, derived in rows:
        print(f"{n},{us:.1f},{derived}")
    if args.check:
        ratio = rows[-1][1]
        # NaN ratio means sync overhead was unmeasurably small — nothing
        # to hide, so nothing to gate on
        if ratio == ratio and ratio >= 30.0:
            raise SystemExit(
                f"async snapshot overhead {ratio:.1f}% >= 30% of sync")


if __name__ == "__main__":
    main()
