"""Goodput under churn: useful steps vs. what the fleet paid, measured.

The MTTR benchmark prices ONE incident; a preemptible fleet pays for a
*process* — Poisson deaths, grace-window preemption notices, hosts
returning — and the number that justifies the whole C/R stack is how
much useful work survives it. This benchmark drives a supervised
trainer through a PINNED 50-event seeded Poisson churn trace
(deterministic: same seed, same events, same virtual-clock decisions)
and reports:

  goodput       useful steps / attempted steps — deterministic on the
                virtual clock, so it gates hard against a pinned floor;
  steps_per_s   useful steps / wall-clock — folds in real restore and
                repair cost (reported, not gated: shared runners);
  per-incident  action, rollback cost, wall time for every executed
                decision.

The run also proves the churn engine's two survival claims end-to-end:
every preemption with sufficient grace is drained proactively (the
heartbeat-timeout path never fires for it), and a returned host is
re-used by a later grow — with the final parameters BIT-IDENTICAL to
an unchurned oracle run of the same step count.

CLI:
  PYTHONPATH=src:. python benchmarks/goodput.py \
      [--smoke] [--check] [--json BENCH_goodput.json] [--save-trace P]

``--check`` is the CI gate (soft in CI — first-land pin): goodput >=
pinned floor, oracle match, preemptions survived, grow executed.
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time

from repro.api import CheckpointSession, Policy
from repro.core.churn import ChurnEngine, ChurnTrace
from repro.train.loop import Trainer, TrainJob

ARCH = "starcoder2-3b-matrix"        # tiny 1-layer config: the benchmark
SHAPE = "train_s8_b2"                # prices the *churn*, not the matmuls
STEPS = 60
HOSTS = [0, 1, 2, 3]
SPARES = [7]
# exactly 50 events inside the horizon (15 die / 12 preempt /
# 23 return at this rate+seed) — the "50-event pinned trace" CI runs
TRACE_KW = dict(rate=0.85, seed=11, horizon=float(STEPS), preempt=0.5,
                grace=3.0, return_after=6.0, max_events=50)
# measured 0.923 on the pinned trace (deterministic); margin for a
# future policy change that trades a little goodput on purpose
GOODPUT_FLOOR = 0.85


def pinned_trace() -> ChurnTrace:
    return ChurnTrace.poisson(HOSTS, **TRACE_KW)


def _oracle_digest(steps: int) -> str:
    t = Trainer(TrainJob(arch=ARCH, shape_key=SHAPE), (1, 1),
                ("data", "model"))
    t.init_state()
    for _ in range(steps):
        t.train_steps(1)
    return t.params_digest()


def run_churned(trace: ChurnTrace, steps: int) -> dict:
    """The supervised loop from launch/train.py, against the trace."""
    root = tempfile.mkdtemp()
    sess = None
    try:
        sess = CheckpointSession(f"sharded:{root}?hosts=4",
                                 Policy(interval=4, async_save=False))
        tr = sess.attach(Trainer(TrainJob(arch=ARCH, shape_key=SHAPE),
                                 (1, 1), ("data", "model"),
                                 manager=sess.manager))
        tr.init_state()
        engine = ChurnEngine(trace,
                             snapshot=lambda: sess.snapshot(block=True))
        sup = sess.supervise(list(HOSTS), spares=list(SPARES),
                             heartbeat_timeout=3.0, clock=engine.clock,
                             n_shards=tr.shape.global_batch)
        engine.attach(sup)
        sess.snapshot(block=True)
        wall0 = time.monotonic()
        step = tr.checkpoint_step()
        while step < steps:
            tr = sup.runner
            tr.train_steps(1)
            step = tr.checkpoint_step()
            sess.maybe_snapshot(final=step == steps)
            if engine.tick(step):
                step = sup.runner.checkpoint_step()
        wall = time.monotonic() - wall0
        rep = engine.report()
        graceful = {e.host for e in trace
                    if e.kind == "preempt" and e.grace_s >= 1.0}
        died_by_timeout = {d for r in rep.incidents for d in r["dead"]}
        return {
            "digest": sup.runner.params_digest(),
            "report": rep,
            "wall_s": wall,
            "events_total": len(trace),
            "events_unfired": len(engine.unfired_events()),
            "graceful_preempt_hosts": sorted(graceful),
            "graceful_preempts_timed_out": sorted(
                graceful & died_by_timeout),
            "final_world": list(sup.world),
        }
    finally:
        if sess is not None:
            sess.close()
        shutil.rmtree(root, ignore_errors=True)


def run(smoke: bool = False) -> dict:
    trace = pinned_trace()
    out = run_churned(trace, STEPS)
    out["oracle_match"] = out["digest"] == _oracle_digest(STEPS)
    return out


def rows_of(out: dict) -> list:
    rep = out["report"]
    rows = [
        ("goodput/steps", rep.goodput,
         f"{rep.useful_steps} useful / {rep.attempted_steps} attempted"),
        ("goodput/steps_per_s", rep.steps_per_s,
         f"{rep.useful_steps} useful in {out['wall_s']:.1f}s wall"),
        ("goodput/lost_steps", float(rep.lost_steps),
         f"across {len(rep.incidents)} incidents"),
        ("goodput/proactive_preempts", float(rep.proactive_preempts),
         "graceful notices drained before the deadline"),
        ("goodput/degraded_preempts", float(rep.degraded_preempts),
         "notices too short to act on"),
        ("goodput/grows", float(rep.grows),
         "returned hosts put back to work"),
        ("goodput/oracle_match", float(out["oracle_match"]),
         "final params identical to the unchurned run"),
    ]
    for i, r in enumerate(rep.incidents):
        rows.append((f"goodput/incident_{i:02d}/{r['action']}",
                     float(r["lost_steps"]),
                     f"t={r['t']:g} dead={r['dead']} "
                     f"wall={r['wall_s']:.2f}s"))
    return rows


def check(out: dict) -> None:
    rep = out["report"]
    failures = []
    if not out["oracle_match"]:
        failures.append("post-churn params differ from the unchurned "
                        "oracle (grow/shrink continuation broke)")
    if rep.goodput < GOODPUT_FLOOR:
        failures.append(f"goodput {rep.goodput:.3f} < pinned floor "
                        f"{GOODPUT_FLOOR} (deterministic trace — a real "
                        "regression, not noise)")
    if out["graceful_preempts_timed_out"]:
        failures.append(
            f"hosts {out['graceful_preempts_timed_out']} had a graceful "
            "preemption notice but still died by heartbeat timeout "
            "(the proactive path failed)")
    if rep.proactive_preempts < 1:
        failures.append("the pinned trace contains graceful preemptions "
                        "but none was handled proactively")
    if rep.grows < 1:
        failures.append("the pinned trace returns hosts but no grow "
                        "ever re-used one")
    if failures:
        raise SystemExit("goodput gate FAILED: " + "; ".join(failures))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="accepted for CI symmetry (the pinned trace IS "
                         "the smoke size)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless goodput >= pinned floor, "
                         "the oracle matches, preemptions were survived "
                         "and a grow executed")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the report as JSON (CI artifact)")
    ap.add_argument("--save-trace", default=None, metavar="PATH",
                    help="write the pinned churn trace as JSONL (replay "
                         "with launch/train.py --churn-trace)")
    args = ap.parse_args()
    if args.save_trace:
        pinned_trace().save(args.save_trace)
    out = run(smoke=args.smoke)
    rows = rows_of(out)
    print("name,value,derived")
    for n, v, d in rows:
        print(f"{n},{v:.3f},{d}")
    if args.json:
        rep = out["report"]
        with open(args.json, "w") as f:
            json.dump({
                "arch": ARCH, "steps": STEPS, "hosts": HOSTS,
                "spares": SPARES, "trace": TRACE_KW,
                "events_total": out["events_total"],
                "events_unfired": out["events_unfired"],
                "goodput_floor": GOODPUT_FLOOR,
                "oracle_match": out["oracle_match"],
                "final_world": out["final_world"],
                **rep.to_json(),
            }, f, indent=2)
    if args.check:
        check(out)


if __name__ == "__main__":
    main()
