"""Checkpoint payload benchmarks: full vs delta vs int8-codec bytes, and
codec throughput (the DESIGN §4.5 numbers)."""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from repro.core import (CheckpointManager, LocalFSBackend, OpLog, UpperHalf)
from repro.kernels.ckpt_codec.ref import quantize_ref, dequantize_ref

N = 4_000_000  # 16 MB f32


def _upper(rng) -> UpperHalf:
    up = UpperHalf()
    up.register("params", "params", {"w": rng.randn(N).astype(np.float32)})
    up.register("opt_state", "opt_state",
                {"mu": rng.randn(N).astype(np.float32)})
    return up


def run() -> list:
    rows = []
    rng = np.random.RandomState(0)

    # --- codec throughput (numpy host path, the checkpoint writer's) ---
    x = rng.randn(N).astype(np.float32)
    t0 = time.monotonic()
    q, s = quantize_ref(x)
    enc_s = time.monotonic() - t0
    t0 = time.monotonic()
    dequantize_ref(q, s)
    dec_s = time.monotonic() - t0
    mb = x.nbytes / 2**20
    rows.append(("codec/quantize", enc_s * 1e6,
                 f"{mb/enc_s:.0f}MB/s_ratio={x.nbytes/(q.nbytes+s.nbytes):.2f}x"))
    rows.append(("codec/dequantize", dec_s * 1e6, f"{mb/dec_s:.0f}MB/s"))

    # --- checkpoint bytes: full vs delta vs delta+int8 ---
    for label, codec, mutate in [
        ("full_then_identical", None, 0.0),
        ("delta_1pct_change", None, 0.01),
        ("int8_moments", "int8", 0.01),
    ]:
        root = tempfile.mkdtemp()
        try:
            cbk = {"opt_state": codec} if codec else {}
            mgr = CheckpointManager(LocalFSBackend(root), async_save=False,
                                    codec_by_kind=cbk)
            up = _upper(rng)
            t0 = time.monotonic()
            mgr.save(1, up, OpLog())
            first_s = time.monotonic() - t0
            first_b = mgr.stats["bytes_written"]
            if mutate:
                w = up.get("params")["w"]
                k = int(len(w) * mutate)
                w[:k] += 1.0
            t0 = time.monotonic()
            mgr.save(2, up, OpLog())
            second_s = time.monotonic() - t0
            second_b = mgr.stats["bytes_written"] - first_b
            rows.append((f"ckpt/{label}/first", first_s * 1e6,
                         f"bytes={first_b}"))
            rows.append((f"ckpt/{label}/second", second_s * 1e6,
                         f"bytes={second_b}_saving="
                         f"{(1 - second_b / max(first_b, 1)) * 100:.0f}%"))
        finally:
            shutil.rmtree(root, ignore_errors=True)
    return rows
