"""Paper Fig. 3 analogue: steady-state interception overhead.

glxgears under DMTCP paid 8% for redirecting every GL call through the
upper/lower-half switch. Our interception only touches *runtime-mutating*
calls (a handful per step, not per math op), so the measured overhead of
running under the C/R runtime (logged LowerHalf API + UpperHalf
bookkeeping) vs calling the bare jitted step should be <1% — the TPU-side
equivalent of the paper's planned FSGSBASE/log-pruning fix.

Also measures the checkpoint pause itself (to_host snapshot) and the
background write, per MB.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CheckpointManager, LocalFSBackend
from repro.train.loop import Trainer, TrainJob

STEPS = 30


def run() -> list:
    rows = []
    root = tempfile.mkdtemp()
    try:
        job = TrainJob(arch="qwen2.5-32b-smoke", shape_key="train_s32_b8")
        mgr = CheckpointManager(LocalFSBackend(root), async_save=True)
        tr = Trainer(job, (1, 1), ("data", "model"), manager=mgr)
        tr.init_state()
        tr.train_steps(2)  # warm-up/compile

        # --- bare step: call the executable directly, no C/R runtime.
        # Identical work otherwise (fresh batch generated + device_put
        # per step), so the difference isolates the interception cost:
        # op-log appends + upper-half bookkeeping.
        fn = tr.lower.executable(tr.vexec)
        params = tr.upper.get("params")
        opt = tr.upper.get("opt_state")
        lr = jnp.float32(1.0)

        # interleaved A/B blocks, medians: the interception cost is
        # microseconds against a multi-ms step, so single-pass timing is
        # noise-dominated
        bare_times, logged_times = [], []
        for rep in range(5):
            t0 = time.monotonic()
            for i in range(STEPS):
                batch = tr._device_batch(tr.pipeline.batch_at(i))
                params, opt, m = fn(params, opt, batch, jnp.int32(i), lr)
            jax.block_until_ready(m["loss"])
            bare_times.append((time.monotonic() - t0) / STEPS)
            # donated inputs: hand live buffers back to the upper half
            tr.upper.update("params", params)
            tr.upper.update("opt_state", opt)

            t0 = time.monotonic()
            tr.train_steps(STEPS)
            logged_times.append((time.monotonic() - t0) / STEPS)
            params = tr.upper.get("params")
            opt = tr.upper.get("opt_state")

        bare_s = sorted(bare_times)[len(bare_times) // 2]
        logged_s = sorted(logged_times)[len(logged_times) // 2]
        overhead = (logged_s - bare_s) / bare_s * 100.0
        rows.append(("overhead/bare_step", bare_s * 1e6, ""))
        rows.append(("overhead/logged_step", logged_s * 1e6,
                     f"overhead={overhead:.2f}%_paper=8%"))

        # --- checkpoint pause + write throughput ---
        t0 = time.monotonic()
        fut = mgr.save(int(tr.upper.get("step")), tr.upper, tr.lower.oplog,
                       job_meta=tr.job_meta())
        pause_s = time.monotonic() - t0          # caller-thread stall
        mgr.wait()
        total_s = time.monotonic() - t0
        mb = mgr.stats["bytes_logical"] / 2**20
        rows.append(("overhead/ckpt_pause", pause_s * 1e6,
                     f"async_write={total_s:.3f}s_payload={mb:.1f}MB"))
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return rows
