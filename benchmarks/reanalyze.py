"""Recompute roofline terms for existing dry-run cells from their saved
HLO (benchmarks/results/hlo/), applying the current analyzer. Keeps
compile-time artifacts; only the analysis fields are refreshed."""
from __future__ import annotations

import glob
import gzip
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.hlo_analysis import analyze_hlo, roofline_terms

RESULTS = Path(__file__).resolve().parent / "results"


def reanalyze(pattern: str = "*") -> int:
    n = 0
    for hlo_path in glob.glob(str(RESULTS / "hlo" / "*" / f"{pattern}.txt.gz")):
        hlo_path = Path(hlo_path)
        mesh = hlo_path.parent.name
        cell = hlo_path.name[:-len(".txt.gz")]
        json_path = RESULTS / "dryrun" / mesh / f"{cell}.json"
        if not json_path.exists():
            continue
        r = json.loads(json_path.read_text())
        counts = analyze_hlo(gzip.open(hlo_path, "rt").read())
        r["roofline"] = roofline_terms(counts)
        r["roofline_kernel_adjusted"] = roofline_terms(
            counts, kernel_adjusted=True)
        r["parsed"].update(
            flops_per_chip=counts.flops,
            hbm_bytes_per_chip=counts.hbm_bytes,
            collective_bytes_per_chip=counts.collective_bytes,
            collective_breakdown=counts.collective_breakdown,
            n_collectives=counts.n_collectives,
        )
        r["fused_loops"] = [
            {"trips": lp.trips, "raw_gb": round(lp.raw_hbm / 2**30, 2),
             "stream_gb": round(lp.stream_hbm / 2**30, 2)}
            for lp in counts.loops if lp.fusable]
        if r["parsed"]["flops_per_chip"]:
            r["useful_flops_ratio"] = (
                r["model_flops_per_chip"] / r["parsed"]["flops_per_chip"])
        json_path.write_text(json.dumps(r, indent=1))
        n += 1
        print(f"reanalyzed {mesh}/{cell}")
    return n


if __name__ == "__main__":
    reanalyze(sys.argv[1] if len(sys.argv) > 1 else "*")
