"""Render the §Roofline table and multi-pod notes into EXPERIMENTS.md
from the dry-run artifacts (idempotent: replaces the marker sections)."""
from __future__ import annotations

import glob
import json
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
RESULTS = REPO / "benchmarks" / "results" / "dryrun"


def load(mesh, tag=""):
    rows = []
    for f in sorted(glob.glob(str(RESULTS / mesh / "*.json"))):
        r = json.load(open(f))
        if r.get("tag", "") != tag:
            continue
        rows.append(r)
    return rows


def fmt_row(r):
    t = r["roofline"]
    tk = r.get("roofline_kernel_adjusted", t)
    live = r["memory"]["live_bytes"] / 2**30
    return (f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} "
            f"| {t['memory_s']:.3f} | {t['collective_s']:.3f} "
            f"| {t['dominant'][:4]} | {tk['memory_s']:.3f} "
            f"| {tk['dominant'][:4]} | {tk['roofline_fraction']:.2f} "
            f"| {r['useful_flops_ratio']:.2f} | {live:.1f} "
            f"| {'Y' if live < 15.7 else 'over'} |")


HDR = ("| arch | shape | compute_s | mem_s (jnp) | coll_s | dom "
       "| mem_s (kernel) | dom(k) | frac(k) | useful | GiB/chip | fits |\n"
       "|---|---|---|---|---|---|---|---|---|---|---|---|")


def render():
    md = (REPO / "EXPERIMENTS.md").read_text()

    table = [HDR]
    for r in load("single"):
        table.append(fmt_row(r))
    roof = "\n".join(table)
    roof += (
        "\n\nColumns: raw terms from the compiled HLO (jnp attention "
        "path); `mem_s (kernel)` / `dom(k)` / `frac(k)` apply the "
        "kernel-adjusted memory term (§method note 4). `fits` compares "
        "live bytes (args+temps, donation-aliased) to 16 GiB v5e HBM. "
        "kimi-k2 exceeds single-pod HBM statically (params+opt "
        "16.4 GiB/chip) — see §Multi-pod.\n")

    mp = [HDR]
    for r in load("multi"):
        mp.append(fmt_row(r))
    mp_txt = ("All 32 cells on the 512-chip mesh:\n\n" + "\n".join(mp) + "\n")

    md = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\n## )",
                "<!-- ROOFLINE_TABLE -->\n" + roof + "\n",
                md, flags=re.S)
    md = re.sub(r"<!-- MULTIPOD_NOTES -->.*$",
                "<!-- MULTIPOD_NOTES -->\n" + mp_txt,
                md, flags=re.S)
    (REPO / "EXPERIMENTS.md").write_text(md)
    print(f"rendered {len(table)-1} single + {len(mp)-1} multi rows")


if __name__ == "__main__":
    render()
