"""Roofline gate for the snapshot codec hot path: capture-stall and
restore-decode throughput per leaf size, measured against the machine's
memory ceiling — so a speed regression in the hottest C/R path fails CI
like a correctness bug (ROADMAP item 2; the dace ``RooflineModel`` /
reframe Advisor workflow from SNIPPETS.md applied to our own codec).

Two ceilings, because the two paths bound differently:

``warm``  ``np.copyto`` into a preallocated buffer — the streaming-read
          ceiling the *capture* fingerprint pass is held to (capture
          reads the leaf once; its destination state is tiny).
``cold``  ``ndarray.copy()`` into freshly allocated pages — the ceiling
          *restore decode* is held to: restore materializes new buffers
          every time, so first-touch page faults are part of its roof,
          not noise to be excused.

On TPU the ceiling is ``HBM_BW`` from ``repro.launch.hlo_analysis`` and
the measured path is the fused single-pass capture kernel
(``ops.fused_dirty_chunk_capture``); on host the measured paths are the
caller-thread fingerprint pass and the sparse/dense chain decode.
Compression is off for the decode rows: the gate holds the memory-bound
codec, not zlib's entropy coding (which runs on the background encode
thread). Encode throughput is reported as an ungated reference row.

``--check`` fails when any gated row's fraction-of-ceiling drops below
its pinned floor (``PINNED``). Re-pin by running ``--json`` on the
target machine class and setting each floor to ~half the observed
fraction — headroom for shared-runner noise, tight enough that a 2x
regression (an extra pass over the data) cannot hide.

CLI:
  PYTHONPATH=src:. python benchmarks/ckpt_roofline.py \
      [--smoke] [--check] [--json BENCH_roofline.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import delta as deltamod
from repro.kernels.ckpt_codec.ref import FP_CHUNK_BYTES, fingerprint_host

# gated fraction-of-ceiling floors, pinned from measured runs (fractions
# observed on the dev box: capture 1.0-1.5, sparse decode ~0.95, dense
# xor decode ~0.5); each floor is ~half the observed value
PINNED: Dict[str, float] = {
    "capture/fingerprint": 0.50,
    "restore/sparse_decode": 0.45,
    "restore/dense_decode": 0.25,
    "capture/fused_kernel": 0.25,   # TPU only
}

SIZES = {
    "full": dict(leaf_mb=256, chunk_bytes=FP_CHUNK_BYTES, dirty_every=20),
    "smoke": dict(leaf_mb=32, chunk_bytes=64 * 1024, dirty_every=20),
}

_REPS = 5


def _median_s(f: Callable[[], object], reps: int = _REPS) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        f()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def _ceilings(nbytes: int) -> Dict[str, float]:
    """Measured memory ceilings (GB/s of payload), see module docstring."""
    src = np.random.RandomState(0).randint(0, 256, nbytes, dtype=np.uint8)
    dst = np.empty_like(src)
    warm = nbytes / _median_s(lambda: np.copyto(dst, src)) / 1e9
    cold = nbytes / _median_s(lambda: src.copy()) / 1e9
    return {"warm": warm, "cold": cold}


def _on_tpu() -> bool:
    try:
        import jax
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def _sparse_meta(src: np.ndarray, chunk_bytes: int, dirty_every: int):
    """Build a ~1/dirty_every-dirty sparse (format-3) link over src."""
    n = src.size
    nch = -(-n // chunk_bytes)
    idx = np.arange(0, nch, dirty_every, dtype=np.int64)
    cur = src.copy()
    for i in idx:
        off = int(i) * chunk_bytes
        cur[off:off + 64] ^= 0xFF
    blobs: Dict[str, bytes] = {}
    pad = (-n) % chunk_bytes
    padded = np.concatenate([cur, np.zeros(pad, np.uint8)]) if pad else cur
    compact = padded.reshape(nch, chunk_bytes)[idx].copy()
    meta = deltamod.encode_leaf_sparse(
        (n,), np.uint8, chunk_bytes, nch, idx, compact, src.copy(),
        lambda k, d: blobs.setdefault(k, d), lambda k: k in blobs,
        compress=False)
    return meta, blobs, cur, idx


def _dense_meta(src: np.ndarray, cur: np.ndarray):
    """Dense format-2 xor link between the same two states."""
    blobs: Dict[str, bytes] = {}
    meta = deltamod.encode_leaf(
        cur, lambda k, d: blobs.setdefault(k, d), lambda k: k in blobs,
        prev=src, compress=False)
    return meta, blobs


def measure(cfg: dict) -> List[dict]:
    """-> rows: {name, gbps, ceiling_gbps, fraction, pinned|None}."""
    nbytes = cfg["leaf_mb"] << 20
    cb = cfg["chunk_bytes"]
    ceil = _ceilings(nbytes)
    src = np.random.RandomState(1).randint(0, 256, nbytes, dtype=np.uint8)
    rows: List[dict] = []

    def row(name: str, seconds: float, ceiling: float,
            payload: Optional[int] = None, extra: str = "") -> None:
        gbps = (payload if payload is not None else nbytes) / seconds / 1e9
        rows.append({
            "name": f"ckpt_roofline/{name}/{cfg['leaf_mb']}MiB",
            "gbps": round(gbps, 3),
            "ceiling_gbps": round(ceiling, 3),
            "fraction": round(gbps / ceiling, 4),
            "pinned": PINNED.get(name),
            "derived": extra,
        })

    # --- capture stall: the dirty-detection read pass (caller thread) ---
    t = _median_s(lambda: fingerprint_host(src, cb))
    row("capture/fingerprint", t, ceil["warm"],
        extra=f"chunk_bytes={cb}")

    if _on_tpu():  # the fused single-pass kernel against HBM peak
        import jax
        import jax.numpy as jnp
        from repro.kernels.ckpt_codec import ops
        from repro.launch.hlo_analysis import HBM_BW
        xd = jnp.asarray(src.view(np.int32))
        prev_fp = ops.chunk_fingerprints(xd, cb)
        jax.block_until_ready(prev_fp)
        t = _median_s(lambda: ops.fused_dirty_chunk_capture(
            xd, prev_fp, cb, capacity_hint=8))
        row("capture/fused_kernel", t, HBM_BW / 1e9,
            extra="1_launch_1_d2h")

    # --- restore decode: sparse dirty-chunk link, then dense xor link ---
    meta_s, blobs_s, cur, idx = _sparse_meta(src, cb, cfg["dirty_every"])
    t = _median_s(lambda: deltamod.decode_leaf(
        meta_s, blobs_s.__getitem__, prev=src))
    row("restore/sparse_decode", t, ceil["cold"],
        extra=f"dirty_chunks={idx.size}")
    meta_d, blobs_d = _dense_meta(src, cur)
    t = _median_s(lambda: deltamod.decode_leaf(
        meta_d, blobs_d.__getitem__, prev=src))
    row("restore/dense_decode", t, ceil["cold"])

    # --- encode (background thread; ungated reference: hash-bound) ---
    nch = -(-nbytes // cb)
    pad = (-nbytes) % cb
    padded = np.concatenate([cur, np.zeros(pad, np.uint8)]) if pad else cur
    compact = padded.reshape(nch, cb)[idx].copy()
    mirror = src.copy()
    dirty_bytes = idx.size * cb
    t = _median_s(lambda: deltamod.encode_leaf_sparse(
        (nbytes,), np.uint8, cb, nch, idx, compact, mirror,
        lambda k, d: None, lambda k: False, compress=False,
        patch_prev=False))
    row("encode/sparse_xor", t, ceil["warm"], payload=dirty_bytes,
        extra=f"dirty_bytes={dirty_bytes}")

    # verification ride-along: the links we timed decode to the truth
    np.testing.assert_array_equal(
        deltamod.decode_leaf(meta_s, blobs_s.__getitem__, prev=src), cur)
    np.testing.assert_array_equal(
        deltamod.decode_leaf(meta_d, blobs_d.__getitem__, prev=src), cur)
    return rows


def run(smoke: bool = False) -> list:
    """benchmarks.run-compatible rows (name, value_us_or_ratio, derived)."""
    out = []
    for r in measure(SIZES["smoke" if smoke else "full"]):
        out.append((r["name"], r["fraction"] * 1e6,
                    f"gbps={r['gbps']}_ceiling={r['ceiling_gbps']}"
                    f"_pinned={r['pinned']}"))
    return out


def check(rows: List[dict]) -> None:
    failures = []
    for r in rows:
        base = r["name"].split("ckpt_roofline/")[1].rsplit("/", 1)[0]
        pinned = PINNED.get(base)
        if pinned is not None and r["fraction"] < pinned:
            failures.append(
                f"{r['name']}: {r['gbps']} GB/s is "
                f"{r['fraction']:.2f} of the {r['ceiling_gbps']} GB/s "
                f"ceiling (< pinned {pinned})")
    if failures:
        raise SystemExit("roofline gate FAILED: " + "; ".join(failures))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes (CI regression gate)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero when a gated path drops below its "
                         "pinned fraction of the machine ceiling")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (CI artifact)")
    args = ap.parse_args()
    rows = measure(SIZES["smoke" if args.smoke else "full"])
    print("name,gbps,ceiling_gbps,fraction,pinned")
    for r in rows:
        print(f"{r['name']},{r['gbps']},{r['ceiling_gbps']},"
              f"{r['fraction']},{r['pinned']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
    if args.check:
        check(rows)


if __name__ == "__main__":
    main()
