"""Paper Fig. 2 analogue: cold start vs checkpoint-restart time across
model sizes (Maya: 60 s cold vs 4 s restart).

Cold start = process init + param init + first-step compile + warm-up
steps + data fast-forward to the crash point.
Restart    = fresh lower half + op-log replay (recompile) + upper-half
rematerialization.

The structural win the paper demonstrates — restart skips model/project
re-initialization and warm-up — maps here to skipping param init and the
N warm-up steps; compile cost appears on both sides (XLA compile ~ Maya's
relaunch), so the ratio grows with how much work the checkpoint captures.
"""
from __future__ import annotations

import shutil
import tempfile
import time

from repro.core import CheckpointManager, LocalFSBackend
from repro.train.loop import Trainer, TrainJob

SIZES = {
    "small": ("starcoder2-3b-smoke", 3),
    "medium": ("qwen2.5-32b-smoke", 6),
    "large": ("qwen1.5-110b-smoke", 10),
}


def run() -> list:
    rows = []
    for name, (arch, warm_steps) in SIZES.items():
        root = tempfile.mkdtemp()
        try:
            job = TrainJob(arch=arch, shape_key="train_s32_b4")
            mgr = CheckpointManager(LocalFSBackend(root), async_save=False)

            t0 = time.monotonic()
            tr = Trainer(job, (1, 1), ("data", "model"), manager=mgr)
            tr.init_state()
            for _ in range(warm_steps):
                tr.train_steps(1)
            cold_s = time.monotonic() - t0
            tr.save(block=True)
            del tr

            # Timed region = restore + FIRST continuation step: jax
            # compiles lazily, so the replayed Compile op's cost lands on
            # the first step — excluding it would flatter restore. Cold
            # start symmetrically paid init + its first (compiling) step.
            # Two restore flavors:
            #   restore            — fresh XLA cache (new process);
            #   restore_warm_cache — in-process / persistent-compilation-
            #                        cache deployment (the paper's
            #                        'resume in seconds' scenario).
            import jax
            t0 = time.monotonic()
            tr2 = Trainer.restore(mgr)
            tr2.train_steps(1)
            warm_restore_s = time.monotonic() - t0
            del tr2
            jax.clear_caches()
            t0 = time.monotonic()
            tr3 = Trainer.restore(mgr)
            tr3.train_steps(1)
            restore_s = time.monotonic() - t0
            rows.append((f"restart_speed/{name}/cold_start",
                         cold_s * 1e6, f"steps={warm_steps}"))
            rows.append((f"restart_speed/{name}/restore",
                         restore_s * 1e6,
                         f"speedup={cold_s / restore_s:.2f}x"))
            rows.append((f"restart_speed/{name}/restore_warm_cache",
                         warm_restore_s * 1e6,
                         f"speedup={cold_s / max(warm_restore_s, 1e-9):.1f}x"))
        finally:
            shutil.rmtree(root, ignore_errors=True)
    return rows
