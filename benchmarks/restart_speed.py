"""Paper Fig. 2 analogue: cold start vs checkpoint-restart time across
model sizes (Maya: 60 s cold vs 4 s restart) — for training *and* for
live serving sessions.

Cold start = process init + param init + first-step compile + warm-up
steps + data fast-forward to the crash point.
Restart    = the Incarnation lifecycle: materialize the delta chain
(parallel leaf decode) + fresh lower half + op-log replay (recompile) +
upper-half rebind.

The structural win the paper demonstrates — restart skips model/project
re-initialization and warm-up — maps here to skipping param init and the
N warm-up steps; compile cost appears on both sides (XLA compile ~ Maya's
relaunch), so the ratio grows with how much work the checkpoint captures.

CLI:
  PYTHONPATH=src:. python benchmarks/restart_speed.py \
      [--smoke] [--check] [--json BENCH_restart.json]

``--check`` is the CI gate: warm restore (replay + rebind with a live
compilation cache — the paper's 'resume in seconds' deployment) must
beat the cold start it replaces, or the exit code is nonzero.
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time

from repro.core import CheckpointManager, LocalFSBackend
from repro.train.loop import Trainer, TrainJob

SIZES = {
    "small": ("starcoder2-3b-smoke", 3),
    "medium": ("qwen2.5-32b-smoke", 6),
    "large": ("qwen1.5-110b-smoke", 10),
}
SMOKE_SIZES = {"small": ("starcoder2-3b-smoke", 3)}


def _train_case(name: str, arch: str, warm_steps: int) -> list:
    rows = []
    root = tempfile.mkdtemp()
    try:
        job = TrainJob(arch=arch, shape_key="train_s32_b4")
        mgr = CheckpointManager(LocalFSBackend(root), async_save=False)

        t0 = time.monotonic()
        tr = Trainer(job, (1, 1), ("data", "model"), manager=mgr)
        tr.init_state()
        for _ in range(warm_steps):
            tr.train_steps(1)
        cold_s = time.monotonic() - t0
        tr.save(block=True)
        del tr

        # Timed region = restore + FIRST continuation step: jax
        # compiles lazily, so the replayed Compile op's cost lands on
        # the first step — excluding it would flatter restore. Cold
        # start symmetrically paid init + its first (compiling) step.
        # Two restore flavors:
        #   restore            — fresh XLA cache (new process);
        #   restore_warm_cache — in-process / persistent-compilation-
        #                        cache deployment (the paper's
        #                        'resume in seconds' scenario).
        import jax
        t0 = time.monotonic()
        tr2 = Trainer.restore(mgr)
        tr2.train_steps(1)
        warm_restore_s = time.monotonic() - t0
        inc = tr2.incarnation
        del tr2
        jax.clear_caches()
        t0 = time.monotonic()
        tr3 = Trainer.restore(mgr)
        tr3.train_steps(1)
        restore_s = time.monotonic() - t0
        rows.append((f"restart_speed/{name}/cold_start",
                     cold_s * 1e6, f"steps={warm_steps}"))
        rows.append((f"restart_speed/{name}/restore",
                     restore_s * 1e6,
                     f"speedup={cold_s / restore_s:.2f}x"))
        rows.append((f"restart_speed/{name}/restore_warm_cache",
                     warm_restore_s * 1e6,
                     f"speedup={cold_s / max(warm_restore_s, 1e-9):.1f}x"))
        rows.append((f"restart_speed/{name}/materialize_phase",
                     inc.timings["materialize_s"] * 1e6,
                     f"replay={inc.timings['replay_s'] * 1e3:.0f}ms"))
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return rows


def _serving_case(arch: str = "phi4-mini-3.8b-smoke") -> list:
    """Live serving restore (the paper's headline demo, §IV): a killed
    engine mid-generation vs restarting the whole service and replaying
    every request from scratch."""
    import jax
    import numpy as np
    from repro.models import model as M
    from repro.serving.engine import Request, ServingEngine
    from repro.configs import registry as cfg_registry

    cfg = cfg_registry.get_smoke_config(arch.removesuffix("-smoke"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    root = tempfile.mkdtemp()
    rows = []
    try:
        mgr = CheckpointManager(LocalFSBackend(root), async_save=False)
        rng = np.random.RandomState(0)
        prompts = [rng.randint(0, cfg.vocab_size, size=5) for _ in range(4)]

        t0 = time.monotonic()
        eng = ServingEngine.create(arch, params, (1, 1), n_slots=2,
                                   max_seq=48, manager=mgr)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new=8))
        for _ in range(6):
            eng.step()
        cold_to_midpoint_s = time.monotonic() - t0
        eng.snapshot(block=True)
        del eng

        # warm restore: same process, compilation cache alive — measure
        # getting back to the same midpoint (sessions re-enter bound)
        t0 = time.monotonic()
        eng2 = ServingEngine.restore(mgr, params)
        eng2.step()
        restore_s = time.monotonic() - t0
        rows.append(("restart_speed/serving/cold_to_midpoint",
                     cold_to_midpoint_s * 1e6, "steps=6"))
        rows.append(("restart_speed/serving/restore_live_sessions",
                     restore_s * 1e6,
                     f"speedup={cold_to_midpoint_s / restore_s:.2f}x"))
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return rows


def run(smoke: bool = False) -> list:
    rows = []
    for name, (arch, warm_steps) in \
            (SMOKE_SIZES if smoke else SIZES).items():
        rows.extend(_train_case(name, arch, warm_steps))
    rows.extend(_serving_case())
    return rows


def check(rows: list) -> None:
    """The gate: warm restore (replay + rebind) must beat the cold
    start it replaces — per training size, and for the live-serving
    case. Fresh-cache restore is reported but not gated — XLA
    recompilation dominates it at smoke scale and the persistent-
    compilation-cache deployment is the one the paper's claim is
    about."""
    by_name = {n: us for n, us, _ in rows}
    failures = []
    for name in {n.split("/")[1] for n in by_name if "/cold_start" in n}:
        cold = by_name[f"restart_speed/{name}/cold_start"]
        warm = by_name[f"restart_speed/{name}/restore_warm_cache"]
        if warm >= cold:
            failures.append(f"{name}: warm restore {warm / 1e6:.2f}s >= "
                            f"cold start {cold / 1e6:.2f}s")
    cold = by_name.get("restart_speed/serving/cold_to_midpoint")
    warm = by_name.get("restart_speed/serving/restore_live_sessions")
    if cold is not None and warm is not None and warm >= cold:
        failures.append(f"serving: live-session restore {warm / 1e6:.2f}s "
                        f">= cold replay to midpoint {cold / 1e6:.2f}s")
    if failures:
        raise SystemExit("restart-speed gate FAILED: " + "; ".join(failures))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smallest size only (CI regression gate)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless warm restore beats cold "
                         "start")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (CI artifact)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for n, us, derived in rows:
        print(f"{n},{us:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": n, "us": us, "derived": d}
                       for n, us, d in rows], f, indent=2)
    if args.check:
        check(rows)


if __name__ == "__main__":
    main()
