"""Aggregate the dry-run artifacts into the roofline table (§Roofline).
Reads benchmarks/results/dryrun/*/*.json (produced by
repro.launch.dryrun); emits one row per (arch, shape, mesh, tag)."""
from __future__ import annotations

import glob
import json
import os
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def load_cells(mesh: str = "single", tag: str = ""):
    out = []
    for f in sorted(glob.glob(str(RESULTS / mesh / "*.json"))):
        r = json.load(open(f))
        if r.get("tag", "") != tag:
            continue
        out.append(r)
    return out


def run() -> list:
    rows = []
    for mesh in ("single", "multi"):
        for r in load_cells(mesh):
            t = r["roofline"]
            name = f"roofline/{mesh}/{r['arch']}/{r['shape']}"
            derived = (f"dom={t['dominant']}"
                       f"_comp={t['compute_s']:.4f}s"
                       f"_mem={t['memory_s']:.4f}s"
                       f"_coll={t['collective_s']:.4f}s"
                       f"_frac={t['roofline_fraction']:.2f}"
                       f"_useful={r['useful_flops_ratio']:.2f}"
                       f"_live={r['memory']['live_bytes']/2**30:.1f}GiB")
            rows.append((name, t["bound_s"] * 1e6, derived))
        # perf-variant tags
        for f in sorted(glob.glob(str(RESULTS / mesh / "*__*__*.json"))):
            r = json.load(open(f))
            if not r.get("tag"):
                continue
            t = r["roofline"]
            name = f"roofline/{mesh}/{r['arch']}/{r['shape']}@{r['tag']}"
            rows.append((name, t["bound_s"] * 1e6,
                         f"dom={t['dominant']}"
                         f"_frac={t['roofline_fraction']:.2f}"
                         f"_coll={t['collective_s']:.4f}s"
                         f"_mem={t['memory_s']:.4f}s"))
    return rows
