"""Record-prune-replay (paper §VI): log size and replay cost, pruned vs
unpruned, as the run gets longer."""
from __future__ import annotations

import time

from repro.core import LowerHalf, OpLog
from repro.core.oplog import (CacheAlloc, CacheFree, Compile, DataAdvance,
                              ScheduleSet)
from repro.core.virtual_ids import VirtualId


class NullRuntime:
    def apply_op(self, op):
        pass


def _mk_log(steps: int) -> OpLog:
    log = OpLog()
    log.append(Compile, vexec=VirtualId("exec", 1), fn_name="train_step",
               arch="a", shape_key="s", plan_key="")
    for i in range(steps):
        log.append(DataAdvance, n=1)
        if i % 100 == 0:
            log.append(ScheduleSet, key="lr_scale", value=1.0 - i * 1e-5)
        if i % 50 == 0:
            v = VirtualId("cache", 10 + i)
            log.append(CacheAlloc, vcache=v, arch="a", batch=1, max_seq=8)
            log.append(CacheFree, vcache=v)
    return log


def run() -> list:
    rows = []
    for steps in (1_000, 10_000, 100_000):
        log = _mk_log(steps)
        t0 = time.monotonic()
        pruned = log.prune()
        prune_s = time.monotonic() - t0

        t0 = time.monotonic()
        log.replay(NullRuntime())
        full_replay = time.monotonic() - t0
        t0 = time.monotonic()
        pruned.replay(NullRuntime())
        pruned_replay = time.monotonic() - t0

        json_full = len(log.to_json())
        json_pruned = len(pruned.to_json())
        rows.append((f"oplog/{steps}_steps/replay_full",
                     full_replay * 1e6, f"ops={len(log)}"))
        rows.append((f"oplog/{steps}_steps/replay_pruned",
                     pruned_replay * 1e6,
                     f"ops={len(pruned)}_bytes={json_pruned}vs{json_full}"
                     f"_prune_time={prune_s*1e3:.1f}ms"))
    return rows
