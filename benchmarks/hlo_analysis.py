"""Re-export: canonical analyzer lives in repro.launch.hlo_analysis."""
from repro.launch.hlo_analysis import (  # noqa: F401
    analyze_hlo, roofline_terms, RooflineCounts, parse_hlo,
    PEAK_FLOPS, HBM_BW, ICI_BW, shape_bytes,
)
