"""MTTR per failure policy: detection → serving-again, measured.

The paper's pitch is "a crash costs seconds"; MANA/CRIUgpu add that the
seconds only materialize when the loop is automated. This benchmark
injects a real host death under a ``ClusterSupervisor`` (the dead
host's ShardedBackend directory is really deleted for the policies
that restore) and measures the wall time from the poll that detects
the death to the restored/remapped runner completing its next training
step, per policy:

  hot_spare          — HostMap rebind + logged DataReassign; the live
                       runner never stops, so MTTR is the remap cost;
  shrink             — storage repair + elastic Incarnation restore
                       onto the survivors (DataReassign rewritten on
                       replay);
  restart_last_ckpt  — storage repair + Incarnation restore on the
                       unchanged world.

CLI:
  PYTHONPATH=src:. python benchmarks/mttr.py \
      [--smoke] [--check] [--json BENCH_mttr.json]

``--check`` is the CI gate (soft — shared-runner timing is noisy): a
hot-spare takeover must be cheaper than a restart-from-checkpoint, or
having spares bought nothing; and every policy must actually execute.
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time

from repro.core import (CheckpointManager, ClusterSupervisor,
                        ShardedBackend)
from repro.train.loop import Trainer, TrainJob

POLICIES = ("hot_spare", "shrink", "restart_last_ckpt")
ARCHS = {"small": "starcoder2-3b-smoke", "medium": "qwen2.5-32b-smoke"}
SMOKE_ARCHS = {"small": "starcoder2-3b-smoke"}


def _incident(arch: str, policy: str) -> tuple:
    """One death under one policy; returns (mttr_s, detail)."""
    root = tempfile.mkdtemp()
    mgr = None
    try:
        be = ShardedBackend(root, n_hosts=4, replicate=True)
        mgr = CheckpointManager(be, async_save=False)
        job = TrainJob(arch=arch, shape_key="train_s32_b4")
        tr = Trainer(job, (1, 1), ("data", "model"), manager=mgr)
        tr.init_state()
        tr.train_steps(2)
        tr.save(block=True)
        tr.train_steps(1)        # uncommitted progress a rollback redoes

        vt = [0.0]

        def restore(target):
            return Trainer.restore(mgr, step=target.step,
                                   rewrite_op=target.rewrite_op())

        sup = ClusterSupervisor(
            [0, 1, 2, 3], manager=mgr,
            spares=[7] if policy == "hot_spare" else [],
            allow_shrink=(policy == "shrink"),
            heartbeat_timeout=3.0, clock=lambda: vt[0],
            n_shards=4, restore=restore, runner=tr)
        for step in (1, 2, 3):
            vt[0] += 1.0
            for h in (0, 1, 2, 3):
                sup.beat(h, step)
        assert sup.poll() is None
        if policy != "hot_spare":
            # the death takes the host's storage: repair is on the path
            shutil.rmtree(be.root / "host_001")
            be.fail_host(1)
        for step in (4, 5, 6, 7):
            vt[0] += 1.0
            for h in (0, 2, 3):
                sup.beat(h, step)

        t0 = time.monotonic()
        target = sup.poll()              # detect + decide + execute
        sup.runner.train_steps(1)        # ... and prove it serves again
        mttr_s = time.monotonic() - t0
        assert target is not None and target.action.value == policy, \
            (policy, target)
        return mttr_s, f"step={target.step} hosts={target.hosts}"
    finally:
        if mgr is not None:
            mgr.close()   # shut the pipeline's thread pools down, not
        shutil.rmtree(root, ignore_errors=True)  # at process exit


def run(smoke: bool = False) -> list:
    """One row per executed incident. A policy whose incident blows up
    is reported and *skipped* — so check() can name the missing policy
    instead of the whole benchmark dying on a raw traceback."""
    import sys
    rows = []
    for name, arch in (SMOKE_ARCHS if smoke else ARCHS).items():
        for policy in POLICIES:
            try:
                mttr_s, detail = _incident(arch, policy)
            except Exception as e:  # noqa: BLE001 — surfaced by check()
                print(f"# mttr/{name}/{policy} FAILED: {e!r}",
                      file=sys.stderr)
                continue
            rows.append((f"mttr/{name}/{policy}", mttr_s * 1e6, detail))
    return rows


def check(rows: list, sizes) -> None:
    """The gate: every policy executed for every expected size, and a
    hot-spare takeover beat a restart-from-checkpoint (otherwise
    keeping spares buys nothing). ``sizes`` is the expected size set —
    derived from the run mode, not the rows, so a size whose every
    incident failed is still named."""
    by_name = {n: us for n, us, _ in rows}
    failures = []
    for size in sizes:
        for policy in POLICIES:
            if f"mttr/{size}/{policy}" not in by_name:
                failures.append(f"{size}: policy {policy} never executed")
    for size in sizes:
        hot = by_name.get(f"mttr/{size}/hot_spare")
        restart = by_name.get(f"mttr/{size}/restart_last_ckpt")
        if hot is not None and restart is not None and hot >= restart:
            failures.append(
                f"{size}: hot-spare MTTR {hot / 1e6:.2f}s >= restart "
                f"MTTR {restart / 1e6:.2f}s")
    if failures:
        raise SystemExit("mttr gate FAILED: " + "; ".join(failures))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smallest size only (CI regression gate)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless hot-spare MTTR beats "
                         "restart MTTR (and all policies executed)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (CI artifact)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for n, us, derived in rows:
        print(f"{n},{us:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": n, "us": us, "derived": d}
                       for n, us, d in rows], f, indent=2)
    if args.check:
        check(rows, (SMOKE_ARCHS if args.smoke else ARCHS).keys())


if __name__ == "__main__":
    main()
