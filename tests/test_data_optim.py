"""Data pipeline determinism/seek + optimizer semantics + failure logic."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim import (AdamWConfig, ScheduleConfig, apply_updates,
                         init_opt_state, schedule_lr)
from repro.core.failure import (FailureAction, FailurePolicy,
                                HeartbeatMonitor, StragglerDetector,
                                rebalance_shards)


# --- data ---------------------------------------------------------------------

def test_pipeline_deterministic_and_seekable():
    cfg = DataConfig(seed=9, vocab_size=100, seq_len=8, global_batch=4,
                     n_shards=2)
    p1 = TokenPipeline(cfg)
    p2 = TokenPipeline(cfg)
    b_a = p1.batch_at(17)
    b_b = p2.batch_at(17)     # O(1) seek, fresh instance
    np.testing.assert_array_equal(b_a["tokens"], b_b["tokens"])
    assert not np.array_equal(p1.batch_at(18)["tokens"], b_a["tokens"])


@given(st.integers(0, 1000), st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_pipeline_reassignment_preserves_bytes(cursor, hosts):
    """Straggler rebalancing changes WHO materializes rows, never the
    rows: concatenating host slices in shard order equals the global
    batch regardless of assignment."""
    cfg = DataConfig(seed=3, vocab_size=50, seq_len=4, global_batch=8,
                     n_shards=4)
    pipe = TokenPipeline(cfg)
    ref = pipe.batch_at(cursor)["tokens"]
    assignment = rebalance_shards(4, list(range(hosts)))
    pipe.reassign(assignment)
    rows = {}
    for h in range(hosts):
        owned = sorted(s for hh, s in assignment if hh == h)
        sl = pipe.host_slice(cursor, h)
        if not owned:
            continue
        per = cfg.global_batch // cfg.n_shards
        for i, s in enumerate(owned):
            rows[s] = sl["tokens"][i * per:(i + 1) * per]
    rebuilt = np.concatenate([rows[s] for s in range(4)], axis=0)
    np.testing.assert_array_equal(rebuilt, ref)


def test_targets_are_shifted_tokens():
    cfg = DataConfig(seed=1, vocab_size=50, seq_len=6, global_batch=2)
    b = TokenPipeline(cfg).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


# --- optimizer ------------------------------------------------------------------

def _quadratic_losses(quantize: bool, steps=200):
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0,
                      quantize_moments=quantize)
    params = {"w": jnp.ones((512,), jnp.float32) * 5.0}
    opt = init_opt_state(params, cfg)
    target = jnp.arange(512, dtype=jnp.float32) / 256.0

    @jax.jit
    def step(p, o):
        g = jax.grad(lambda q: jnp.mean((q["w"] - target) ** 2))(p)
        return apply_updates(p, g, o, cfg, jnp.float32(0.1))

    for _ in range(steps):
        params, opt, m = step(params, opt)
    return float(jnp.mean((params["w"] - target) ** 2))


def test_adamw_converges():
    assert _quadratic_losses(False) < 1e-2


def test_quantized_moments_track_f32():
    a = _quadratic_losses(False)
    b = _quadratic_losses(True)
    assert b < 5e-2 and abs(a - b) < 3e-2


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros((16,))}
    opt = init_opt_state(params, cfg)
    g = {"w": jnp.full((16,), 1e6)}
    p2, _, m = apply_updates(params, g, opt, cfg, jnp.float32(1.0))
    assert float(m["grad_norm"]) > 1e3
    assert np.all(np.isfinite(np.asarray(p2["w"])))


def test_schedule_shapes():
    cfg = ScheduleConfig(kind="warmup_cosine", peak_lr=1.0, warmup_steps=10,
                         total_steps=100, min_ratio=0.1)
    assert float(schedule_lr(cfg, 0)) == 0.0
    assert abs(float(schedule_lr(cfg, 10)) - 1.0) < 1e-6
    assert float(schedule_lr(cfg, 100)) == pytest.approx(0.1, rel=1e-3)
    assert float(schedule_lr(cfg, 55)) < 1.0


def test_compressed_psum_error_feedback():
    """int8 gradient all-reduce with EF: the carried residual keeps the
    long-run mean unbiased (error decays instead of accumulating)."""
    from repro.optim.compression import (_blockwise_quant,
                                         _blockwise_dequant)
    rng = np.random.RandomState(0)
    g = rng.randn(4096).astype(np.float32)
    e = np.zeros_like(g)
    sent_sum = np.zeros_like(g)
    for it in range(50):
        q, s = _blockwise_quant(jnp.asarray(g + e))
        sent = np.asarray(_blockwise_dequant(q, s, g.size))
        e = (g + e) - sent
        sent_sum += sent
    # average transmitted ~= true gradient
    np.testing.assert_allclose(sent_sum / 50, g, atol=1e-2)


# --- failure handling -------------------------------------------------------------

def test_heartbeat_and_straggler_detection():
    clock = {"t": 0.0}
    mon = HeartbeatMonitor([0, 1, 2, 3], timeout=10.0,
                           clock=lambda: clock["t"])
    det = StragglerDetector(mon, k=1.5)
    for step in range(1, 6):
        for h in (0, 1, 2):
            clock["t"] = step * 1.0 + h * 0.01
            mon.beat(h, step)
        clock["t"] = step * 3.0        # host 3 is 3x slower
        mon.beat(3, step)
    assert det.stragglers() == [3]
    clock["t"] = 100.0                  # hosts stop beating
    assert set(mon.dead_hosts()) == {0, 1, 2, 3}


def test_failure_policy_escalation():
    pol = FailurePolicy(spares=[9], allow_shrink=True)
    act, info = pol.decide([], list(range(8)))
    assert act == FailureAction.NONE
    act, info = pol.decide([3], list(range(8)))
    assert act == FailureAction.HOT_SPARE and info["mapping"] == {3: 9}
    act, info = pol.decide([1, 2], list(range(8)))
    assert act == FailureAction.SHRINK and len(info["survivors"]) == 6
    pol2 = FailurePolicy(spares=[], allow_shrink=False)
    act, _ = pol2.decide([1], list(range(8)))
    assert act == FailureAction.RESTART_LAST_CKPT
