"""The Incarnation restore lifecycle: phase ordering, parallel chain
materialization equivalence, cross-incarnation handle staleness, and
restorable-step listing under GC'd delta bases."""
import numpy as np
import pytest

from repro.core import (CheckpointManager, HandleTable, Incarnation,
                        LifecycleError, LocalFSBackend, OpLog,
                        StaleHandleError, UpperHalf, restorable_steps,
                        tree_from_paths)


def _mk_upper(seed=0, n=20_000):
    rng = np.random.RandomState(seed)
    up = UpperHalf()
    up.register("params", "params",
                {"w": rng.randn(n).astype(np.float32),
                 "b": rng.randn(64).astype(np.float32)})
    up.register("step", "step", np.int64(seed))
    return up


# --- lifecycle ordering -----------------------------------------------------

def test_phases_enforced_in_order(tmp_path):
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    mgr.save(1, _mk_upper(1), OpLog())
    inc = Incarnation(mgr)
    with pytest.raises(LifecycleError):
        inc.build_lower()          # before materialize
    with pytest.raises(LifecycleError):
        inc.scalar("step")
    inc.materialize()
    with pytest.raises(LifecycleError):
        inc.bind("params", {})     # before build_lower
    inc.build_lower()
    assert int(inc.scalar("step")) == 1
    with pytest.raises(LifecycleError):
        inc.materialize()          # single-use
    with pytest.raises(LifecycleError):
        inc.build_lower()


def test_materialize_parallel_matches_serial(tmp_path):
    """The decode worker pool is a latency optimization, not a format
    change: leaves decode bit-identically at any worker count, across a
    delta chain."""
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)),
                            async_save=False, delta_base_interval=4)
    up = _mk_upper(0, n=300_000)
    for s in (1, 2, 3):
        up.get("params")["w"][s::97] += 1.0
        mgr.save(s, up, OpLog())
    serial = mgr.restore(3, workers=1)
    parallel = mgr.restore(3, workers=8)
    for name in serial.entries:
        assert set(serial.entries[name]) == set(parallel.entries[name])
        for path, arr in serial.entries[name].items():
            np.testing.assert_array_equal(arr, parallel.entries[name][path])
    np.testing.assert_array_equal(serial.entries["params"]["['w']"],
                                  up.get("params")["w"])


# --- cross-incarnation staleness -------------------------------------------

def test_stale_handle_after_new_incarnation():
    """A vid from a previous incarnation must not silently resolve: the
    translation table raises until replay rebinds it (paper §III)."""
    table = HandleTable()
    vid = table.create("exec", object())
    assert table.translate(vid) is not None
    table.new_incarnation()
    with pytest.raises(StaleHandleError):
        table.translate(vid)
    assert not table.is_bound(vid)
    # replay's rebind makes the same vid valid again
    fresh = object()
    table.bind(vid, fresh)
    assert table.translate(vid) is fresh


def test_lower_half_vids_stale_until_replayed(tmp_path):
    """End-to-end: after a checkpointed runtime's log replays into a new
    incarnation the old vids resolve to *new* objects; a vid whose op was
    never replayed stays stale."""
    from repro.core import LowerHalf
    lower = LowerHalf()
    lower.mesh_create((1, 1), ("data", "model"))
    vmesh = lower.vmesh
    gen0 = lower.handles.generation

    lower.reset()   # new incarnation, nothing rebound yet
    assert lower.handles.generation == gen0 + 1
    with pytest.raises(StaleHandleError):
        lower.handles.translate(vmesh)
    assert not lower.handles.is_bound(vmesh)

    lower.oplog.replay(lower)   # rebind: same vid, current generation
    assert lower.handles.is_bound(vmesh)
    assert lower.handles.translate(vmesh).axis_names == ("data", "model")


# --- restorable steps under GC ---------------------------------------------

def test_restorable_steps_excludes_gcd_base(tmp_path):
    """A delta step whose base manifest was GC'd is not restorable and
    must not be listed; steps with intact chains still are."""
    be = LocalFSBackend(str(tmp_path))
    mgr = CheckpointManager(be, async_save=False, delta_base_interval=2)
    up = _mk_upper(0, n=50_000)
    for s in (1, 2, 3, 4):   # 1 full, 2 delta(1), 3 full, 4 delta(3)
        up.get("params")["w"][s::53] += 1.0
        mgr.save(s, up, OpLog())
    assert be.get_manifest(2)["base_step"] == 1
    assert restorable_steps(be) == [1, 2, 3, 4]
    be.delete_step(1)        # simulate an out-of-band GC of the base
    assert restorable_steps(be) == [3, 4]


def test_restorable_steps_single_manifest_read_each(tmp_path):
    """The memoized listing reads each manifest once — O(n), not
    O(n * chain length)."""
    be = LocalFSBackend(str(tmp_path))
    mgr = CheckpointManager(be, async_save=False, delta_base_interval=100)
    up = _mk_upper(0, n=4_096)
    for s in range(1, 9):    # one long chain: 1 full, 2..8 deltas
        up.get("params")["w"][s::31] += 1.0
        mgr.save(s, up, OpLog())
    reads = []
    orig = be.get_manifest
    be.get_manifest = lambda s: (reads.append(s), orig(s))[1]
    assert restorable_steps(be) == list(range(1, 9))
    assert sorted(reads) == list(range(1, 9)), reads


# --- path-tree reconstruction ----------------------------------------------

def test_tree_from_paths_roundtrip():
    from repro.core.split_state import flatten_with_paths
    tree = {"queue": {"000000": {"rid": np.int64(7),
                                 "prompt": np.arange(4, dtype=np.int32)},
                      "000001": {"rid": np.int64(9),
                                 "prompt": np.arange(2, dtype=np.int32)}},
            "slots": {}}
    by_path = dict(flatten_with_paths(tree))
    back = tree_from_paths(by_path)
    assert back["queue"]["000000"]["rid"] == 7
    np.testing.assert_array_equal(back["queue"]["000001"]["prompt"],
                                  np.arange(2, dtype=np.int32))
    # bare-leaf path
    assert tree_from_paths({"": np.int64(3)}) == 3
    # keystr repr-quotes keys containing a single quote with double
    # quotes; both quoting forms must round-trip
    tricky = {"it's": {"a 'key'": np.int64(1)}}
    back = tree_from_paths(dict(flatten_with_paths(tricky)))
    assert back["it's"]["a 'key'"] == 1
