"""End-to-end behaviour of the paper's system: transparent C/R with
split state, log replay, virtual ids — the Maya experiment (§IV) at unit
scale, plus backend agnosticism (§V)."""
import numpy as np
import pytest

import jax

from repro.core import (CheckpointManager, LocalFSBackend, ShardedBackend)
from repro.train.loop import Trainer, TrainJob

# each case trains a real (smoke-scale) model end-to-end; excluded from
# the default tier-1 run — opt in with  pytest -m slow  or  pytest -m ""
pytestmark = pytest.mark.slow

JOB = TrainJob(arch="qwen2.5-32b-smoke", shape_key="train_s16_b4")


def _run_reference(steps: int):
    t = Trainer(JOB, (1, 1), ("data", "model"))
    t.init_state()
    m = {}
    for _ in range(steps):
        m = t.train_steps(1)
    return t.params_digest(), m


@pytest.fixture(scope="module")
def reference():
    return _run_reference(5)


@pytest.mark.parametrize("backend_cls,kw", [
    (LocalFSBackend, {}),                               # CRIU-analogue
    (ShardedBackend, {"n_hosts": 3, "replicate": True}),  # DMTCP-analogue
])
def test_crash_restore_bitwise(tmp_path, reference, backend_cls, kw):
    """Checkpoint at step 2, crash, restore, continue to step 5 — the
    continuation must be bitwise-identical to an uninterrupted run,
    under BOTH checkpoint packages (the agnosticism claim)."""
    ref_digest, ref_metrics = reference
    mgr = CheckpointManager(backend_cls(str(tmp_path), **kw),
                            async_save=False)
    t1 = Trainer(JOB, (1, 1), ("data", "model"), manager=mgr)
    t1.init_state()
    t1.train_steps(2)
    t1.save(block=True)
    del t1  # crash: mesh, executables, device buffers all gone

    t2 = Trainer.restore(mgr)
    assert int(t2.upper.get("step")) == 2
    m = {}
    for _ in range(3):
        m = t2.train_steps(1)
    assert t2.params_digest() == ref_digest
    assert np.isclose(m["loss"], ref_metrics["loss"])


def test_restore_faster_than_cold_start(tmp_path):
    """The paper's headline (Fig 2): restart from checkpoint beats
    cold start (which must redo init + warm-up steps + data
    fast-forward). Unit-scale timing, same machine, same model."""
    import time
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)

    t0 = time.monotonic()
    t1 = Trainer(JOB, (1, 1), ("data", "model"), manager=mgr)
    t1.init_state()
    t1.train_steps(3)
    cold_start_s = time.monotonic() - t0
    t1.save(block=True)
    digest = t1.params_digest()
    del t1

    t0 = time.monotonic()
    t2 = Trainer.restore(mgr)
    restore_s = time.monotonic() - t0
    assert t2.params_digest() == digest
    # restore skips param init and the 3 warm-up steps; compile is shared.
    # Generous bound — the benchmark records the real ratio.
    assert restore_s < cold_start_s * 1.5, (restore_s, cold_start_s)


def test_oplog_grows_then_prunes(tmp_path):
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    t = Trainer(JOB, (1, 1), ("data", "model"), manager=mgr)
    t.init_state()
    t.train_steps(4)
    t.lower.schedule_set("lr_scale", 0.5)
    t.lower.schedule_set("lr_scale", 0.25)
    full = len(t.lower.oplog)
    pruned = t.lower.oplog.prune()
    # 4 DataAdvance -> 1; 2 ScheduleSet -> 1; mesh+compile kept
    assert len(pruned) < full
    assert _replay_fingerprint(t.lower.oplog) == _replay_fingerprint(pruned)


def _replay_fingerprint(log):
    from repro.core import LowerHalf
    lh = LowerHalf()
    log.replay(lh)
    return lh.fingerprint()


def test_schedule_override_survives_restore(tmp_path):
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    t = Trainer(JOB, (1, 1), ("data", "model"), manager=mgr)
    t.init_state()
    t.train_steps(1)
    t.lower.schedule_set("lr_scale", 0.5)
    t.save(block=True)
    del t
    t2 = Trainer.restore(mgr)
    assert t2.lower.schedule_overrides["lr_scale"] == 0.5


def test_virtual_exec_rebinds_after_restore(tmp_path):
    """The Compile vid resolves to a *fresh* executable after restore —
    the translation-table mechanic of paper §III."""
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    t = Trainer(JOB, (1, 1), ("data", "model"), manager=mgr)
    t.init_state()
    t.train_steps(1)
    old_fn = t.lower.executable(t.vexec)
    t.save(block=True)
    del t
    t2 = Trainer.restore(mgr)
    new_fn = t2.lower.executable(t2.vexec)
    assert new_fn is not old_fn


def test_sharded_backend_survives_host_loss(tmp_path, reference):
    """Peer replication (DMTCP-analogue): a failed host's blobs restore
    from the replica."""
    ref_digest, _ = reference
    be = ShardedBackend(str(tmp_path), n_hosts=4, replicate=True)
    mgr = CheckpointManager(be, async_save=False)
    t1 = Trainer(JOB, (1, 1), ("data", "model"), manager=mgr)
    t1.init_state()
    t1.train_steps(2)
    t1.save(block=True)
    del t1
    be.fail_host(1)  # lose a host
    t2 = Trainer.restore(mgr)
    for _ in range(3):
        t2.train_steps(1)
    assert t2.params_digest() == ref_digest


def test_train_launcher_cold_then_resume(tmp_path):
    """The production crash-loop contract: the same command line either
    cold-starts or transparently resumes from the last checkpoint."""
    import subprocess, sys, os
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src")
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "starcoder2-3b-smoke", "--ckpt-every", "2",
           "--ckpt-dir", str(tmp_path)]
    p1 = subprocess.run(cmd + ["--steps", "3"], env=env,
                        capture_output=True, text=True, timeout=600)
    assert p1.returncode == 0, p1.stderr
    assert "COLD START" in p1.stdout
    p2 = subprocess.run(cmd + ["--steps", "5"], env=env,
                        capture_output=True, text=True, timeout=600)
    assert p2.returncode == 0, p2.stderr
    assert "RESUMED" in p2.stdout and "at step 3" in p2.stdout
