import json
import os
import random
import re
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a snippet in a subprocess with N virtual host devices.

    Multi-device tests can't run in the pytest process: jax locks the
    device count at first init (and smoke tests must see 1 device)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout,
        cwd=REPO)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def subproc():
    return run_with_devices


# --- determinism: seeded global RNGs, guarded global JAX config --------------

# Global config keys a test could flip and silently poison every test
# that runs after it (x64 flips dtypes; disable_jit changes numerics
# paths; matmul precision changes results on some backends).
_JAX_CONFIG_KEYS = ("jax_enable_x64", "jax_disable_jit",
                    "jax_default_matmul_precision",
                    "jax_numpy_rank_promotion", "jax_debug_nans")


def _jax_config_snapshot():
    import jax
    return {k: getattr(jax.config, k) for k in _JAX_CONFIG_KEYS}


@pytest.fixture(autouse=True)
def _seeded_rngs_and_config_guard(request):
    """Every test starts from the same global-RNG state, and no test may
    leak a global JAX config mutation into the next one.

    Explicit PRNGKey / RandomState plumbing stays the norm in this repo;
    the fixture covers the *implicit* channels — `random` / legacy
    `np.random` callers — so conformance-matrix cells (and everything
    else) are bitwise reproducible in any execution order."""
    random.seed(0x5EED)
    np.random.seed(0x5EED)
    before = _jax_config_snapshot()
    yield
    after = _jax_config_snapshot()
    changed = {k: (before[k], after[k]) for k in _JAX_CONFIG_KEYS
               if before[k] != after[k]}
    assert not changed, (
        f"{request.node.nodeid} mutated global JAX config {changed} "
        "without restoring it — use a try/finally or a fixture so later "
        "tests keep deterministic numerics")


# --- conformance-matrix cell report ------------------------------------------

def pytest_addoption(parser):
    parser.addoption(
        "--conformance-report", default=None, metavar="PATH",
        help="write per-cell conformance-matrix results (JSON) to PATH")


_CONF_RE = re.compile(r"tests[/\\]conformance[/\\]test_matrix\.py::"
                      r"[^\[]+\[(?P<cell>.+)\]$")
_CONF_CELLS = {}


def pytest_runtest_logreport(report):
    m = _CONF_RE.search(report.nodeid)
    if not m:
        return
    # pytest ascii-escapes non-ascii parametrize ids in nodeids
    # ("×" -> "\xd7"); undo that so report keys match the canonical
    # family×mode×backend cell IDs in expected_cells.json
    cell = m.group("cell")
    if "\\x" in cell or "\\u" in cell:
        cell = cell.encode("ascii").decode("unicode_escape")
    rec = _CONF_CELLS.setdefault(
        cell, {"outcome": None, "duration_s": 0.0})
    if report.when == "call":
        rec["outcome"] = report.outcome
        rec["duration_s"] = round(report.duration, 3)
    elif rec["outcome"] is None and report.outcome != "passed":
        # setup-time skip (markers) or setup/teardown error
        rec["outcome"] = report.outcome
        rec["duration_s"] = round(report.duration, 3)


def pytest_sessionfinish(session):
    path = session.config.getoption("--conformance-report", default=None)
    if not path or not _CONF_CELLS:
        return
    outcomes = [r["outcome"] for r in _CONF_CELLS.values()]
    payload = {
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "cells": dict(sorted(_CONF_CELLS.items())),
        "summary": {o: outcomes.count(o) for o in sorted(set(outcomes))},
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
