import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a snippet in a subprocess with N virtual host devices.

    Multi-device tests can't run in the pytest process: jax locks the
    device count at first init (and smoke tests must see 1 device)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout,
        cwd=REPO)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def subproc():
    return run_with_devices
