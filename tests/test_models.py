"""Per-architecture smoke tests (reduced configs, one forward/train step
on CPU, shape + finiteness assertions) and attention semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config, get_config, shapes_for
from repro.models import model as M
from repro.models import layers as L
from repro.parallel import context as pctx
from repro.train.step import make_train_step, cross_entropy
from repro.parallel.sharding import ParallelPlan, train_rules
from repro.optim import AdamWConfig, ScheduleConfig, init_opt_state

RNG = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    b = {"tokens": jax.random.randint(RNG, (B, S), 0, cfg.vocab_size),
         "targets": jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        b["frames"] = jax.random.normal(
            RNG, (B, cfg.encoder_seq, cfg.frontend_dim), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, RNG)
    batch = _batch(cfg)
    with pctx.single_device_context():
        logits, aux = jax.jit(
            lambda p, b: M.forward_train(cfg, p, b))(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """One full train step (fwd + bwd + AdamW) on the reduced config."""
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, RNG)
    plan = ParallelPlan(rules=train_rules(False, ("data",)), remat="full")
    opt_cfg = AdamWConfig(lr=1e-3)
    opt_state = init_opt_state(params, opt_cfg)
    # warmup_steps=1 so the very first step has a non-zero lr
    fn = make_train_step(cfg, plan, opt_cfg,
                         ScheduleConfig(warmup_steps=1), mesh=None)
    batch = _batch(cfg)
    with pctx.single_device_context():
        p2, o2, metrics = jax.jit(fn)(params, opt_state, batch,
                                      jnp.int32(1), jnp.float32(1.0))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x.astype(jnp.float32)))),
        jax.tree.map(lambda a, b: a.astype(jnp.float32) -
                     b.astype(jnp.float32), p2, params), 0.0)
    assert moved > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch):
    """Greedy decode after prefill must match teacher-forced forward
    logits (same positions, same cache semantics)."""
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, RNG)
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    toks = batch["tokens"]
    with pctx.single_device_context():
        logits, _ = M.forward_train(cfg, params, batch)
        cache = M.init_cache(cfg, B, 32)
        last, cache = M.prefill(cfg, params, toks, cache,
                                frames=batch.get("frames"))
        np.testing.assert_allclose(
            np.asarray(last, np.float32),
            np.asarray(logits[:, -1], np.float32), rtol=2e-2, atol=2e-2)
        # one decode step at position S using token S-1's argmax
        nxt = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
        pos = jnp.full((B,), S, jnp.int32)
        lg, _ = M.decode_step(cfg, params, cache, nxt, pos)
        assert lg.shape == (B, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(lg, np.float32)))


def test_decode_matches_prefill_stepwise():
    """Decoding token-by-token reproduces prefill logits (dense arch)."""
    cfg = get_smoke_config("phi4-mini-3.8b")
    params = M.init_params(cfg, RNG)
    B, S = 1, 8
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    with pctx.single_device_context():
        batch = {"tokens": toks, "targets": toks}
        full_logits, _ = M.forward_train(cfg, params, batch)
        cache = M.init_cache(cfg, B, 16)
        # feed tokens one at a time through decode_step
        outs = []
        for t in range(S):
            lg, cache = M.decode_step(cfg, params, cache, toks[:, t:t+1],
                                      jnp.full((B,), t, jnp.int32))
            outs.append(lg)
        stepwise = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stepwise, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_window_attention_masks_past():
    """With a window of w, logits must not depend on tokens further back
    than w."""
    cfg = get_smoke_config("recurrentgemma-9b")
    w = cfg.attn_window
    params = M.init_params(cfg, RNG)
    B, S = 1, 40  # > window (32)
    t1 = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    # change a token far outside every attention window of the last pos,
    # but note rglru layers carry state, so compare attention-only layers:
    # use pure attention_forward instead.
    x = jax.random.normal(RNG, (B, S, cfg.d_model), jnp.float32)
    p = {k: v for k, v in M.init_params(cfg, RNG)
         ["rem0_rglru"]["mlp"].items()}  # unused; build attn params below
    from repro.models.layers import attention_template, attention_forward
    from repro.models.params import init_concrete
    ap = init_concrete(attention_template(cfg), "float32", RNG)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    out1, _ = attention_forward(cfg, ap, x, pos, window=w)
    x2 = x.at[:, 0].set(x[:, 0] + 100.0)  # outside window of last pos
    out2, _ = attention_forward(cfg, ap, x2, pos, window=w)
    np.testing.assert_allclose(np.asarray(out1[:, -1]),
                               np.asarray(out2[:, -1]), atol=1e-4)
    assert not np.allclose(np.asarray(out1[:, 1]), np.asarray(out2[:, 1]))


def test_chunked_equals_dense_attention():
    from repro.models.layers import chunked_attention, dense_attention
    B, Sq, Hkv, G, hd = 2, 64, 2, 3, 16
    q = jax.random.normal(RNG, (B, Sq, Hkv, G, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(RNG, 1), (B, Sq, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(RNG, 2), (B, Sq, Hkv, hd))
    pos = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq)).astype(jnp.int32)
    a = chunked_attention(q, k, v, pos, pos, causal=True, chunk=16)
    b = dense_attention(q, k, v, pos, pos, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_cross_entropy_matches_naive():
    B, S, V = 2, 8, 32
    logits = jax.random.normal(RNG, (B, S, V), jnp.float32)
    targets = jax.random.randint(RNG, (B, S), 0, V)
    ce = cross_entropy(logits, targets)
    naive = -jnp.mean(jnp.take_along_axis(
        jax.nn.log_softmax(logits, -1), targets[..., None], -1))
    np.testing.assert_allclose(float(ce), float(naive), rtol=1e-6)


def test_param_counts_match_published():
    expect = {
        "chameleon-34b": 34.3e9, "kimi-k2-1t-a32b": 1043e9,
        "llama4-scout-17b-a16e": 108e9, "starcoder2-3b": 3.0e9,
        "qwen2.5-32b": 32.8e9, "qwen1.5-110b": 111e9,
        "phi4-mini-3.8b": 3.8e9, "mamba2-780m": 0.78e9,
        "recurrentgemma-9b": 8.5e9, "whisper-base": 0.071e9,
    }
    for arch, n in expect.items():
        got = M.param_count(get_config(arch))
        assert abs(got - n) / n < 0.05, (arch, got, n)


def test_long_500k_applicability():
    subq = {a for a in ARCH_IDS
            if any(s.name == "long_500k" for s in shapes_for(get_config(a)))}
    assert subq == {"mamba2-780m", "recurrentgemma-9b"}
