"""Sharding rules + planner behaviour (pure logic, no devices)."""
import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec

from repro.configs import get_config, get_shape
from repro.parallel.planner import (estimate_train_memory,
                                    estimate_serve_memory, make_plan,
                                    HBM_BYTES)
from repro.parallel.sharding import (ParallelPlan, spec_for_axes,
                                     train_rules)


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _plan(fsdp=True):
    return ParallelPlan(rules=train_rules(fsdp, ("data",)))


def test_spec_basic():
    p = _plan()
    s = spec_for_axes(p, ("embed", "ff"), (8192, 49152), MESH)
    assert s == PartitionSpec("data", "model")


def test_spec_indivisible_falls_back_replicated():
    p = _plan(fsdp=False)
    # 24 heads on model=16: replicate instead of invalid shard
    s = spec_for_axes(p, ("embed", "heads", None), (3072, 24, 128), MESH)
    assert s == PartitionSpec(None, "model", None) or \
        s == PartitionSpec()  # embed unsharded w/o fsdp; heads dropped
    assert "model" not in tuple(s)[1:2] or (24 % 16 == 0)


def test_spec_no_duplicate_mesh_axes():
    p = ParallelPlan(rules={"a": "model", "b": "model"})
    s = spec_for_axes(p, ("a", "b"), (32, 32), MESH)
    flat = [x for x in s if x is not None]
    assert flat.count("model") <= 1


def test_spec_multi_axis_target():
    p = ParallelPlan(rules={"embed": ("pod", "data")},
                     batch_axes=("pod", "data"))
    s = spec_for_axes(p, ("embed", None), (8192, 64), MESH_MP)
    assert s[0] == ("pod", "data")


def test_planner_small_dense_accum1():
    cfg = get_config("starcoder2-3b")
    plan = make_plan(cfg, get_shape("train_4k"), MESH)
    assert plan.grad_accum == 1
    assert plan.seq_shard


def test_planner_kimi_refuses_accum():
    """params+opt exceed HBM at 256 chips: accum would only multiply
    FSDP gathers (EXPERIMENTS §Perf iter1)."""
    cfg = get_config("kimi-k2-1t-a32b")
    plan = make_plan(cfg, get_shape("train_4k"), MESH)
    assert plan.grad_accum == 1
    assert "OVERBUDGET" in plan.notes


def test_planner_kimi_static_fits_multipod():
    cfg = get_config("kimi-k2-1t-a32b")
    est_sp = estimate_train_memory(cfg, get_shape("train_4k"), MESH,
                                   True, True, 1)
    est_mp = estimate_train_memory(cfg, get_shape("train_4k"), MESH_MP,
                                   True, True, 1)
    static_sp = est_sp.params + est_sp.opt_state
    static_mp = est_mp.params + est_mp.opt_state
    assert static_sp > 0.9 * HBM_BYTES          # 1T doesn't fit one pod
    assert static_mp == pytest.approx(static_sp / 2)


def test_planner_serving_depth_escalates():
    small = get_config("starcoder2-3b")
    big = get_config("qwen1.5-110b")
    p_small = make_plan(small, get_shape("decode_32k"), MESH)
    p_big = make_plan(big, get_shape("decode_32k"), MESH)
    assert "depth=1" in p_small.notes
    assert "depth=2" in p_big.notes


def test_serve_memory_ssm_is_tiny():
    cfg = get_config("mamba2-780m")
    est = estimate_serve_memory(cfg, get_shape("long_500k"), MESH, 1, False)
    assert est.kv_cache < 1e9  # recurrent state, not a 500k KV cache


def test_plan_interior_tp_default_off():
    cfg = get_config("qwen1.5-110b")
    plan = make_plan(cfg, get_shape("train_4k"), MESH)
    assert plan.interior_tp is False  # refuted in §Perf iter3
