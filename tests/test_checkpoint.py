"""Checkpoint manager: delta dedup, codecs, atomicity, GC — over both
backends (package agnosticism at the unit level)."""
import json

import numpy as np
import pytest

from repro.core import (CheckpointManager, LocalFSBackend, OpLog,
                        ShardedBackend, UpperHalf)
from repro.core.delta import serialize_tensor, deserialize_tensor


def _mk_upper(seed=0, n=4096):
    rng = np.random.RandomState(seed)
    up = UpperHalf()
    up.register("params", "params",
                {"w": rng.randn(n).astype(np.float32),
                 "b": rng.randn(32).astype(np.float32)})
    up.register("opt_state", "opt_state",
                {"mu": {"w": rng.randn(n).astype(np.float32)}})
    up.register("step", "step", np.int64(1))
    return up


@pytest.fixture(params=["localfs", "sharded"])
def backend(request, tmp_path):
    if request.param == "localfs":
        return LocalFSBackend(str(tmp_path))
    return ShardedBackend(str(tmp_path), n_hosts=3)


def test_roundtrip(backend):
    mgr = CheckpointManager(backend, async_save=False)
    up = _mk_upper()
    mgr.save(1, up, OpLog())
    r = mgr.restore()
    assert r.step == 1
    np.testing.assert_array_equal(r.entries["params"]["['w']"],
                                  up.get("params")["w"])
    np.testing.assert_array_equal(
        r.entries["opt_state"]["['mu']['w']"],
        up.get("opt_state")["mu"]["w"])


def test_delta_dedup_unchanged_tensors(backend):
    """Second checkpoint with identical params writes ~no new bytes —
    content-addressed chunking is the delta (DESIGN §4.5)."""
    mgr = CheckpointManager(backend, async_save=False)
    up = _mk_upper(n=300_000)
    mgr.save(1, up, OpLog())
    first = mgr.stats["bytes_written"]
    assert first > 0
    mgr.save(2, up, OpLog())     # nothing changed
    second = mgr.stats["bytes_written"] - first
    assert second == 0, second
    # change one entry: only its chunks rewrite
    up.get("params")["b"][:] += 1.0
    mgr.save(3, up, OpLog())
    third = mgr.stats["bytes_written"] - first
    assert 0 < third < first / 2


def test_int8_codec_roundtrip_error(backend):
    mgr = CheckpointManager(backend, async_save=False,
                            codec_by_kind={"opt_state": "int8"})
    up = _mk_upper(n=10_000)
    mgr.save(1, up, OpLog())
    r = mgr.restore()
    orig = up.get("opt_state")["mu"]["w"]
    back = r.entries["opt_state"]["['mu']['w']"]
    # params exact, moments within block quantization error
    np.testing.assert_array_equal(r.entries["params"]["['w']"],
                                  up.get("params")["w"])
    err = np.abs(back - orig)
    scale = np.abs(orig).reshape(-1, 250 if False else 1)
    assert err.max() < np.abs(orig).max() / 100  # 127 levels per block
    # codec shrinks payload ~4x for f32
    meta = mgr.backend.get_manifest(1)["entries"]["opt_state"]["leaves"]
    m = meta["['mu']['w']"]
    assert m["codec"] == "int8"


def test_manifest_atomicity(tmp_path):
    """A checkpoint is visible only after its manifest commit; stray
    blobs from a crashed save are invisible."""
    be = LocalFSBackend(str(tmp_path))
    be.put_blob("deadbeef", b"garbage from a crashed writer")
    mgr = CheckpointManager(be, async_save=False)
    with pytest.raises(FileNotFoundError):
        mgr.restore()
    up = _mk_upper()
    mgr.save(5, up, OpLog())
    assert mgr.restore().step == 5


def test_gc_keeps_last_and_referenced(tmp_path):
    be = LocalFSBackend(str(tmp_path))
    mgr = CheckpointManager(be, async_save=False, keep_last=2)
    up = _mk_upper(n=100_000)
    for s in (1, 2, 3, 4):
        up.get("params")["w"][:] += 1.0
        mgr.save(s, up, OpLog())
    assert be.list_steps() == [3, 4]
    # all blobs referenced by remaining manifests still restore
    r = mgr.restore(3)
    assert r.step == 3


def test_async_save_overlaps_and_completes(tmp_path):
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=True)
    up = _mk_upper(n=200_000)
    fut = mgr.save(1, up, OpLog())
    # mutate AFTER save returns: snapshot must reflect the pre-mutation
    # state (to_host copies before the background write)
    up.get("params")["w"][:] = -1.0
    mgr.wait()
    r = mgr.restore()
    assert not np.allclose(r.entries["params"]["['w']"], -1.0)


def test_serialize_tensor_chunking(tmp_path):
    blobs = {}
    meta = serialize_tensor(
        np.arange(3 * 1024 * 1024, dtype=np.float32),  # 12 MiB -> 3 chunks
        put_blob=lambda n, d: blobs.setdefault(n, d),
        has_blob=lambda n: n in blobs)
    assert len(meta["parts"]["raw"]["chunks"]) == 3
    back = deserialize_tensor(meta, blobs.__getitem__)
    np.testing.assert_array_equal(
        back, np.arange(3 * 1024 * 1024, dtype=np.float32))


def test_bfloat16_tensor_roundtrip(backend):
    import jax.numpy as jnp
    import jax
    mgr = CheckpointManager(backend, async_save=False)
    up = UpperHalf()
    x = jnp.asarray(np.random.randn(1000), jnp.bfloat16)
    up.register("params", "params", {"w": x})
    mgr.save(1, up, OpLog())
    r = mgr.restore()
    back = r.entries["params"]["['w']"]
    assert str(back.dtype) == "bfloat16"
    np.testing.assert_array_equal(np.asarray(x, np.float32),
                                  np.asarray(back, np.float32))


def test_structure_handles_scalar_and_nonarray_leaves():
    """Regression: UpperHalf.structure() used to route scalar/non-array
    leaves through jax.device_get via an inverted hasattr branch; plain
    int/float/list leaves must describe cleanly (and array leaves must
    not be transferred off device just to read shape/dtype)."""
    import jax.numpy as jnp
    up = UpperHalf()
    up.register("scalars", "step", {"i": 7, "f": 2.5})
    up.register("np_scalar", "rng", np.int64(3))
    up.register("arr", "params", {"w": jnp.zeros((2, 3), jnp.float32)})
    desc = up.structure()
    assert desc["scalars"]["leaves"]["['i']"]["shape"] == []
    assert "int" in desc["scalars"]["leaves"]["['i']"]["dtype"]
    assert desc["scalars"]["leaves"]["['f']"]["shape"] == []
    assert "float" in desc["scalars"]["leaves"]["['f']"]["dtype"]
    assert desc["np_scalar"]["leaves"][""]["shape"] == []
    assert desc["arr"]["leaves"]["['w']"] == {"shape": [2, 3],
                                              "dtype": "float32"}
