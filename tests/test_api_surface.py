"""API-surface lock: ``repro.api``'s exported names and signatures are
asserted against a checked-in snapshot (tests/api_surface.json), so an
accidental breaking change to the public surface fails loudly in CI.

A *deliberate* surface change regenerates the snapshot:

    PYTHONPATH=src python tests/test_api_surface.py --update
"""
import inspect
import json
import os

SNAPSHOT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "api_surface.json")


def build_surface():
    import repro.api as api
    out = {}
    for name in sorted(api.__all__):
        obj = getattr(api, name)
        if inspect.ismodule(obj):
            out[name] = {"type": "module"}
        elif isinstance(obj, type):
            entry = {
                "type": "class",
                "bases": [b.__name__ for b in obj.__bases__],
                "methods": sorted(
                    n for n, v in vars(obj).items()
                    if not n.startswith("_")
                    and (callable(v)
                         or isinstance(v, (classmethod, staticmethod,
                                           property)))),
            }
            # Protocol classes have synthesized __init__s whose repr
            # varies across Python versions; lock members only
            if not getattr(obj, "_is_protocol", False) and \
                    obj.__init__ is not object.__init__:
                try:
                    entry["init"] = str(inspect.signature(obj.__init__))
                except (TypeError, ValueError):
                    pass
            out[name] = entry
        elif callable(obj):
            out[name] = {"type": "function",
                         "sig": str(inspect.signature(obj))}
        else:
            out[name] = {"type": type(obj).__name__}
    return out


def test_api_surface_matches_snapshot():
    with open(SNAPSHOT) as f:
        locked = json.load(f)
    current = build_surface()
    added = sorted(set(current) - set(locked))
    removed = sorted(set(locked) - set(current))
    changed = sorted(n for n in set(locked) & set(current)
                     if locked[n] != current[n])
    assert not (added or removed or changed), (
        f"repro.api surface drifted: added={added} removed={removed} "
        f"changed={changed}. If this change is deliberate, regenerate "
        f"the lock: PYTHONPATH=src python tests/test_api_surface.py "
        f"--update — and say so in the PR. Details: " + json.dumps(
            {n: {"locked": locked.get(n), "current": current.get(n)}
             for n in (changed or added or removed)}, indent=2))


if __name__ == "__main__":
    import sys
    if "--update" in sys.argv:
        with open(SNAPSHOT, "w") as f:
            json.dump(build_surface(), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {SNAPSHOT}")
    else:
        print(json.dumps(build_surface(), indent=2, sort_keys=True))
