"""Policy validation + URI-spec backend resolution: the configuration
half of the public API fails at the line that wrote it, with an
actionable message — never deep inside the first chained save."""
import numpy as np
import pytest

from repro.api import (CheckpointSession, Policy, PolicyError,
                       parse_store_spec, resolve_backend)
from repro.core.backends.localfs import LocalFSBackend
from repro.core.backends.sharded import ShardedBackend


# --- Policy field validation -------------------------------------------------

@pytest.mark.parametrize("kw,needle", [
    (dict(interval=0), "interval"),
    (dict(interval=-5), "interval"),
    (dict(chain=0), "chain"),
    (dict(keep_last=0), "keep_last"),
    (dict(backpressure="drop"), "backpressure"),
    (dict(writers=0), "writers"),
    (dict(sparse_chunk_bytes=4096), "chain"),           # chain off
    (dict(sparse_min_bytes=1 << 16), "chain"),
    (dict(chain=4, sparse=False, sparse_chunk_bytes=4096), "sparse"),
    (dict(codecs={"opt_state": "no-such-codec"}), "codec"),
])
def test_bad_policy_raises_policyerror(kw, needle):
    with pytest.raises(PolicyError, match=needle):
        Policy(**kw)


def test_policy_error_is_valueerror():
    # the hierarchy adds ways to catch, it never removes one
    with pytest.raises(ValueError):
        Policy(interval=0)


def test_default_policy_valid_and_frozen():
    p = Policy()
    with pytest.raises(AttributeError):
        p.chain = 2  # type: ignore[misc]


def test_with_revalidates():
    p = Policy(chain=4)
    assert p.with_(keep_last=3).keep_last == 3
    with pytest.raises(PolicyError, match="chain"):
        p.with_(chain=0)


def test_build_manager_maps_fields(tmp_path):
    p = Policy(chain=4, keep_last=3, backpressure="skip", writers=2,
               compress=False, async_save=False,
               codecs={"opt_state": "int8"})
    mgr = p.build_manager(LocalFSBackend(str(tmp_path)))
    try:
        assert mgr.pipeline.delta_base_interval == 4
        assert mgr.pipeline.keep_last == 3
        assert mgr.pipeline.backpressure == "skip"
        assert mgr.pipeline.compress is False
        assert mgr.codec_by_kind == {"opt_state": "int8"}
        assert mgr.async_save is False
    finally:
        mgr.close()


def test_sparse_geometry_still_validated_at_build(tmp_path):
    # the pipeline's own geometry check is routed through PolicyError
    with pytest.raises(PolicyError, match="sparse_chunk_bytes"):
        Policy(chain=4, sparse_chunk_bytes=1000).build_manager(
            LocalFSBackend(str(tmp_path)))


# --- store specs -------------------------------------------------------------

def test_parse_store_spec():
    scheme, path, params = parse_store_spec(
        "sharded:/data/job?hosts=4&replicate=1")
    assert (scheme, path) == ("sharded", "/data/job")
    assert params == {"hosts": "4", "replicate": "1"}


@pytest.mark.parametrize("spec", ["", "nope", ":", "localfs:",
                                  ":/path", 42, None])
def test_malformed_spec_is_policyerror(spec):
    with pytest.raises(PolicyError, match="spec"):
        parse_store_spec(spec)


def test_unknown_scheme_names_register_hook(tmp_path):
    with pytest.raises(PolicyError, match="register_backend"):
        resolve_backend(f"s3:{tmp_path}")


def test_unknown_param_lists_accepted(tmp_path):
    with pytest.raises(PolicyError, match="hosts"):
        resolve_backend(f"localfs:{tmp_path}?hosts=4")


def test_bad_param_value_is_policyerror(tmp_path):
    with pytest.raises(PolicyError, match="integer"):
        resolve_backend(f"sharded:{tmp_path}?hosts=lots")
    with pytest.raises(PolicyError, match="boolean"):
        resolve_backend(f"sharded:{tmp_path}?replicate=maybe")
    # range checks too — hosts=0 would otherwise surface as a
    # modulo-by-zero at the first blob write, writers=0 as a raw
    # ThreadPoolExecutor ValueError
    with pytest.raises(PolicyError, match="hosts=0"):
        resolve_backend(f"sharded:{tmp_path}?hosts=0")
    with pytest.raises(PolicyError, match="writers=0"):
        resolve_backend(f"sharded:{tmp_path}?writers=0")


def test_resolve_builds_both_packages(tmp_path):
    lf = resolve_backend(f"localfs:{tmp_path}/a")
    assert isinstance(lf, LocalFSBackend)
    sh = resolve_backend(f"sharded:{tmp_path}/b?hosts=3&replicate=1")
    assert isinstance(sh, ShardedBackend)
    assert sh.n_hosts == 3 and sh.replicate is True


def test_malformed_query_piece(tmp_path):
    with pytest.raises(PolicyError, match="key=value"):
        resolve_backend(f"localfs:{tmp_path}?fsync")


def test_policy_replicate_default_flows_into_spec(tmp_path):
    sess = CheckpointSession(f"sharded:{tmp_path}/r?hosts=2",
                             Policy(replicate=True))
    try:
        assert sess.backend.replicate is True
    finally:
        sess.close()
    # an explicit spec param wins over the policy default
    sess = CheckpointSession(f"sharded:{tmp_path}/r2?hosts=2&replicate=0",
                             Policy(replicate=True))
    try:
        assert sess.backend.replicate is False
    finally:
        sess.close()


def test_replicate_request_on_nonreplicating_store_is_loud(tmp_path):
    """Policy(replicate=True) must never be silently unservable — a
    store that can't replicate (wrong scheme, or a pre-built instance
    with replication off) is an error now, not at the first lost host."""
    with pytest.raises(PolicyError, match="does not replicate"):
        CheckpointSession(f"localfs:{tmp_path}/nr", Policy(replicate=True))
    with pytest.raises(PolicyError, match="does not replicate"):
        CheckpointSession(ShardedBackend(str(tmp_path / "nr2"), n_hosts=2,
                                         replicate=False),
                          Policy(replicate=True))
    # a pre-built instance that DOES replicate satisfies the request
    sess = CheckpointSession(ShardedBackend(str(tmp_path / "ok"),
                                            n_hosts=2, replicate=True),
                             Policy(replicate=True))
    try:
        assert sess.backend.replicate is True
    finally:
        sess.close()


def test_third_party_backend_registers_without_core(tmp_path):
    from repro.api import register_backend
    from repro.api.registry import BACKEND_SCHEMES

    @register_backend("memdir")
    def _memdir(path, *, depth="1"):
        return ("memdir", path, int(depth))

    try:
        assert resolve_backend("memdir:/x?depth=3") == ("memdir", "/x", 3)
    finally:
        BACKEND_SCHEMES.pop("memdir", None)


# --- registry collision safety ----------------------------------------------

def test_duplicate_backend_scheme_raises():
    from repro.api import register_backend
    from repro.api.registry import BACKEND_SCHEMES

    @register_backend("collide")
    def _first(path):
        return ("first", path)

    try:
        # re-registering the same callable (module reimport) is a no-op
        register_backend("collide")(_first)
        with pytest.raises(PolicyError, match="already registered"):
            @register_backend("collide")
            def _second(path):
                return ("second", path)
        # the failed grab left the original in place
        assert resolve_backend("collide:/x") == ("first", "/x")

        @register_backend("collide", replace=True)
        def _third(path):
            return ("third", path)
        assert resolve_backend("collide:/x") == ("third", "/x")
    finally:
        BACKEND_SCHEMES.pop("collide", None)


def test_duplicate_app_kind_raises():
    from repro.api import register_app_kind
    from repro.api.registry import APP_KINDS

    @register_app_kind("collide-kind")
    def _b1(restore):
        return "b1"

    try:
        register_app_kind("collide-kind")(_b1)   # idempotent
        with pytest.raises(PolicyError, match="already registered"):
            @register_app_kind("collide-kind")
            def _b2(restore):
                return "b2"
        assert APP_KINDS["collide-kind"] is _b1

        @register_app_kind("collide-kind", replace=True)
        def _b3(restore):
            return "b3"
        assert APP_KINDS["collide-kind"] is _b3
    finally:
        APP_KINDS.pop("collide-kind", None)


def test_builtin_kind_collision_detected_before_lazy_import():
    # "train" belongs to repro.train.loop whether or not that module has
    # loaded yet — grabbing a built-in kind must be loud either way
    from repro.api import register_app_kind
    with pytest.raises(PolicyError, match="'train'.*already registered"):
        @register_app_kind("train")
        def _usurper(restore):
            return None


def test_replaced_builtin_survives_home_module_import():
    from repro.api import register_app_kind
    from repro.api.registry import APP_KINDS
    try:
        @register_app_kind("serving", replace=True)
        def _custom(restore):
            return "custom"
        import repro.serving.engine  # noqa: F401
        # the built-in module loading later must not clobber the
        # deliberate override
        assert APP_KINDS["serving"] is _custom
    finally:
        from repro.serving.engine import _restore_engine
        APP_KINDS["serving"] = _restore_engine


# --- policy edge combos ------------------------------------------------------

def test_chain_with_keep_last_one_keeps_base_closure(tmp_path):
    """keep_last=1 under chaining must keep the survivor's base too —
    retention can never leave the newest checkpoint unrestorable."""
    from repro.core import OpLog, UpperHalf
    p = Policy(chain=3, keep_last=1, async_save=False)
    mgr = p.build_manager(LocalFSBackend(str(tmp_path)))
    try:
        up = UpperHalf()
        up.register("w", "params", np.arange(64, dtype=np.float32))
        log = OpLog()
        for s in range(1, 6):
            up.update("w", np.arange(64, dtype=np.float32) + s)
            mgr.save(s, up, log, block=True)
        # bases at 1 and 4; keep_last=1 keeps 5 plus its base 4, only
        steps = mgr.backend.list_steps()
        assert steps == [4, 5]
        assert mgr.backend.get_manifest(5).get("base_step") == 4
        got = mgr.restore(5).entries["w"]
        np.testing.assert_array_equal(
            next(iter(got.values())), np.arange(64, dtype=np.float32) + 5)
    finally:
        mgr.close()


def test_interval_one_snapshots_every_step(tmp_path):
    """interval=1 is the densest legal cadence: every step boundary
    commits (step 0 never does — there is nothing to restore to)."""
    from repro.core import OpLog, UpperHalf

    class Counter:
        def __init__(self):
            self.upper = UpperHalf()
            self.upper.register("n", "step", np.int64(0))
            self.log = OpLog()

        def checkpoint_state(self):
            return self.upper

        def checkpoint_step(self):
            return int(self.upper.get("n"))

        def job_meta(self):
            return {"kind": "counter-policy-test"}

        def bind(self, restore):
            raise NotImplementedError

    sess = CheckpointSession(f"localfs:{tmp_path}",
                             Policy(interval=1, async_save=False))
    try:
        app = sess.attach(Counter())
        assert sess.maybe_snapshot() is None   # step 0: nothing yet
        for n in range(1, 4):
            app.upper.update("n", np.int64(n))
            sess.maybe_snapshot()
        assert sess.backend.list_steps() == [1, 2, 3]
    finally:
        sess.close()
