"""Property-based equivalence (hypothesis): for random change masks —
including the all-clean and all-dirty corners — the sparse dirty-chunk
encoding (manifest format 3) round-trips bit-identically against the
dense format-2 xor path. Skips itself when hypothesis is absent."""
import numpy as np
import pytest

from repro.core import delta as deltamod

CB = 4096


pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install hypothesis)")
from hypothesis import given, settings, strategies as st  # noqa: E402


@settings(max_examples=40, deadline=None)
@given(
    n_chunks=st.integers(1, 12),
    tail=st.integers(0, CB - 1),
    mask_bits=st.integers(0, 2 ** 12 - 1),
    seed=st.integers(0, 2 ** 16),
)
def test_sparse_encode_decode_matches_dense(n_chunks, tail, mask_bits, seed):
    """For ANY change mask — including the all-clean and all-dirty
    corners — the sparse dirty-chunk encoding decodes to exactly the
    bytes the dense format-2 xor path decodes to (both equal the
    current value)."""
    rng = np.random.RandomState(seed)
    nbytes = n_chunks * CB - (tail if n_chunks > 0 else 0)
    if nbytes == 0:
        nbytes = 8
    prev = rng.randint(0, 256, size=nbytes, dtype=np.uint8)
    cur = prev.copy()
    real_chunks = -(-nbytes // CB)
    dirty = [i for i in range(real_chunks) if (mask_bits >> i) & 1]
    for i in dirty:
        off = i * CB
        ln = min(CB, nbytes - off)
        cur[off:off + ln // 2 + 1] ^= rng.randint(
            1, 256, size=ln // 2 + 1, dtype=np.uint8)

    # dense format-2 xor leaf
    blobs_d = {}
    meta_d = deltamod.encode_leaf(cur, lambda n, d: blobs_d.setdefault(n, d),
                                  lambda n: n in blobs_d, prev=prev)
    out_d = deltamod.decode_leaf(meta_d, blobs_d.__getitem__, prev=prev)

    # sparse format-3 leaf from the same dirty set (conservative mask:
    # report every masked chunk dirty even if the edit was a no-op)
    compact = np.zeros((len(dirty), CB), np.uint8)
    for j, i in enumerate(dirty):
        off = i * CB
        ln = min(CB, nbytes - off)
        compact[j, :ln] = cur[off:off + ln]
    mirror = prev.copy()
    blobs_s = {}
    meta_s = deltamod.encode_leaf_sparse(
        (nbytes,), np.uint8, CB, real_chunks,
        np.asarray(dirty, np.int64), compact, mirror,
        lambda n, d: blobs_s.setdefault(n, d), lambda n: n in blobs_s)
    assert meta_s["mode"] == "xor"
    np.testing.assert_array_equal(mirror, cur)   # mirror patched in place
    out_s = deltamod.decode_leaf(meta_s, blobs_s.__getitem__, prev=prev)

    np.testing.assert_array_equal(out_d, cur)
    np.testing.assert_array_equal(out_s, cur)
    np.testing.assert_array_equal(out_s, out_d)
