"""Fused single-pass capture kernel: three-way equivalence against the
ref.py host twin AND the old two-launch path (fingerprints, dirty
indices, compacted bytes — all bit-identical), launch/transfer
accounting (exactly 1 kernel launch + 1 blocking D2H per eligible
leaf), overflow fallback, and the satellite fixes that ride along."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.ckpt_codec import kernel as K
from repro.kernels.ckpt_codec import ops
from repro.kernels.ckpt_codec.ref import (fingerprint_ref,
                                          fused_capture_ref)

CB = 1024  # 4 * BLOCK: one i32 lane row per chunk — the minimum legal


def _dirty_some(x: np.ndarray, chunk_bytes: int, which) -> np.ndarray:
    y = x.copy()
    b = y.view(np.uint8)
    for i in which:
        b[i * chunk_bytes % b.size] ^= 0x5A
    return y


def _three_way(x: np.ndarray, prev: np.ndarray, chunk_bytes: int):
    """Run fused kernel, host twin and two-launch path on the same
    (prev -> x) transition; assert bit-identical, return (idx, data)."""
    pfp = ops.chunk_fingerprints(prev, chunk_bytes, interpret=True)
    fp_f, idx_f, data_f = ops.fused_dirty_chunk_capture(
        x, pfp, chunk_bytes, interpret=True)
    fp_o, idx_o, data_o = ops.dirty_chunk_capture(
        x, pfp, chunk_bytes, interpret=True)
    fp_r, count_r, idx_r, data_r = fused_capture_ref(
        x, np.asarray(pfp), chunk_bytes)
    # fingerprints: kernel (i32) vs oracle (u32) — same bits
    np.testing.assert_array_equal(np.asarray(fp_f).view(np.uint32), fp_r)
    np.testing.assert_array_equal(np.asarray(fp_f), np.asarray(fp_o))
    np.testing.assert_array_equal(
        np.asarray(fp_f).view(np.uint32), fingerprint_ref(x, chunk_bytes))
    # dirty indices
    np.testing.assert_array_equal(idx_f, idx_o)
    np.testing.assert_array_equal(idx_f, idx_r)
    assert count_r == idx_r.size  # no overflow in the oracle run
    # compacted payload
    if idx_f.size == 0:
        assert data_f is None and data_o is None and data_r.size == 0
    else:
        np.testing.assert_array_equal(data_f, data_o)
        np.testing.assert_array_equal(data_f, data_r)
    return idx_f, data_f


@pytest.mark.parametrize("n,dirty", [
    (CB // 4 * 6, [1, 3]),          # even chunks, scattered dirty
    (CB // 4 * 6 + 31, [0, 6]),     # odd size, dirty partial tail chunk
    (CB // 4 * 6 + 31, []),         # all-clean
    (CB // 4 * 6 + 31, list(range(7))),   # all-dirty incl. tail
    (CB // 4 - 7, [0]),             # single partial chunk, dirty
    (CB // 4 - 7, []),              # single partial chunk, clean
    (3, [0]),                       # tiny leaf, sub-lane
])
def test_fused_equals_ref_equals_two_launch(n, dirty):
    rng = np.random.RandomState(n)
    prev = rng.randn(n).astype(np.float32)
    x = _dirty_some(prev, CB, dirty)
    idx, _ = _three_way(x, prev, CB)
    n_chunks = -(-x.nbytes // CB)
    assert idx.size == len(set(i % n_chunks for i in dirty))


def test_fused_non_f32_dtype():
    """int16 leaves go through the bitcast+pad path; same contract."""
    rng = np.random.RandomState(3)
    prev = rng.randint(-1000, 1000, size=CB // 2 * 3 + 11, dtype=np.int16)
    x = prev.copy()
    x[5] += 1
    idx, data = _three_way(x, prev, CB)
    assert idx.tolist() == [0]


def test_fused_overflow_falls_back_to_two_launch():
    """When a step dirties more chunks than the compaction buffer holds,
    the kernel's count overflows and the wrapper finishes via the
    two-launch gather — results still bit-identical to the old path."""
    rng = np.random.RandomState(4)
    n_chunks = 4 * ops._FUSED_MIN_CAPACITY
    prev = rng.randn(n_chunks * CB // 4).astype(np.float32)
    x = prev + 1.0  # every chunk dirty
    pfp = ops.chunk_fingerprints(prev, CB, interpret=True)
    assert ops.fused_capacity(n_chunks, CB, 1) < n_chunks
    fp_f, idx_f, data_f = ops.fused_dirty_chunk_capture(
        x, pfp, CB, capacity_hint=1, interpret=True)
    fp_o, idx_o, data_o = ops.dirty_chunk_capture(
        x, pfp, CB, interpret=True)
    np.testing.assert_array_equal(np.asarray(fp_f), np.asarray(fp_o))
    np.testing.assert_array_equal(idx_f, idx_o)
    np.testing.assert_array_equal(data_f, data_o)
    assert idx_f.size == n_chunks


def test_fused_capacity_policy():
    """2x hint, clamped to leaf and VMEM budget, pow2-bucketed."""
    assert ops.fused_capacity(1024, CB, 3) == 8      # floor
    assert ops.fused_capacity(1024, CB, 100) == 256  # 2x hint, pow2
    assert ops.fused_capacity(5, CB, 100) == 8       # leaf clamp, pow2 up
    big = ops._FUSED_VMEM_BUDGET // (256 * 1024)
    assert ops.fused_capacity(10 ** 6, 256 * 1024, 10 ** 6) <= 2 * big


def test_fused_single_launch_single_d2h(monkeypatch):
    """The acceptance property: one kernel trace contains exactly one
    pallas launch (the fused kernel; the fingerprint/gather kernels are
    never touched), and the non-overflow path performs exactly one
    blocking device_get."""
    launches = {"fused": 0, "fingerprint": 0, "gather": 0}
    real_fused = K.fused_capture_blocks
    real_fp = K.fingerprint_blocks
    monkeypatch.setattr(
        K, "fused_capture_blocks",
        lambda *a, **k: launches.__setitem__("fused", launches["fused"] + 1)
        or real_fused(*a, **k))
    monkeypatch.setattr(
        K, "fingerprint_blocks",
        lambda *a, **k: launches.__setitem__(
            "fingerprint", launches["fingerprint"] + 1) or real_fp(*a, **k))
    gets = []
    real_get = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: gets.append(1) or real_get(x))

    rng = np.random.RandomState(5)
    prev = rng.randn(CB // 4 * 8).astype(np.float32)
    x = _dirty_some(prev, CB, [2, 5])
    pfp_dev = jnp.asarray(fingerprint_ref(prev, CB).view(np.int32))
    ops._fused_capture_impl.clear_cache()  # force a fresh trace
    gets.clear()
    fp, idx, data = ops.fused_dirty_chunk_capture(
        x, pfp_dev, CB, interpret=True)
    assert launches == {"fused": 1, "fingerprint": 0, "gather": 0}
    assert len(gets) == 1, f"expected 1 blocking D2H, saw {len(gets)}"
    assert idx.tolist() == [2, 5] and data is not None
    assert isinstance(fp, jax.Array)  # fingerprints stay device-resident


def test_fused_reuses_trace_across_steps(monkeypatch):
    """Steady-state dirty-count fluctuation inside one pow2 bucket must
    not retrace (the capacity bucketing exists exactly for this)."""
    rng = np.random.RandomState(6)
    prev = rng.randn(CB // 4 * 64).astype(np.float32)
    pfp = ops.chunk_fingerprints(prev, CB, interpret=True)
    caps = {ops.fused_capacity(64, CB, h) for h in (3, 4, 2, 4, 3)}
    assert len(caps) == 1
    for hint, k in ((3, 3), (4, 5), (2, 1)):
        x = _dirty_some(prev, CB, list(range(k)))
        _, idx, _ = ops.fused_dirty_chunk_capture(
            x, pfp, CB, capacity_hint=hint, interpret=True)
        assert idx.size == k


# --- satellites ------------------------------------------------------------

def test_delta_decode_threads_interpret(monkeypatch):
    """ops.delta_decode forwards its interpret flag to delta_encode
    instead of silently dropping it (a CPU test forcing interpret=True
    must not fall through to the probed default)."""
    seen = {}
    real = ops.delta_encode

    def spy(a, b, *, interpret=None):
        seen["interpret"] = interpret
        return real(a, b, interpret=interpret)

    monkeypatch.setattr(ops, "delta_encode", spy)
    prev = np.arange(512, dtype=np.float32)
    cur = prev + 1
    delta = real(cur, prev, interpret=True)
    out = ops.delta_decode(delta, prev, np.float32, (512,), interpret=True)
    assert seen["interpret"] is True
    np.testing.assert_array_equal(out, cur)


def test_host_sparse_capture_tail_chunk_roundtrip(tmp_path):
    """Regression for the vectorized host compaction in _try_sparse: a
    leaf whose nbytes is NOT a chunk multiple, with the partial tail
    chunk among the dirty set, must roundtrip bit-identically through a
    chained sparse save -> restore."""
    from repro.core import (CheckpointManager, LocalFSBackend, OpLog,
                            UpperHalf)
    from repro.core.async_snapshot import materialize_manifest_chain

    cb = 1024
    n = cb * 5 + 57  # 6 chunks, last one partial
    rng = np.random.RandomState(7)
    leaf = rng.randint(0, 256, n, dtype=np.uint8)
    mgr = CheckpointManager(
        LocalFSBackend(str(tmp_path)), async_save=False,
        delta_base_interval=4, sparse_capture=True,
        sparse_chunk_bytes=cb, sparse_min_bytes=cb)
    up = UpperHalf()
    up.register("blob", "params", {"x": leaf})
    mgr.save(1, up, OpLog())
    # dirty chunk 1 AND the partial tail chunk
    leaf[cb + 3] ^= 0xA5
    leaf[cb * 5 + 11] ^= 0x3C
    up.update("blob", {"x": leaf})
    mgr.save(2, up, OpLog())
    assert mgr.stats["sparse_leaves"] >= 1
    assert mgr.stats["dirty_chunks"] == 2
    manifest, entries = materialize_manifest_chain(mgr.backend, 2)
    assert manifest["format"] == 3
    np.testing.assert_array_equal(entries["blob"]["['x']"], leaf)


def test_encode_leaf_sparse_unsorted_idx_guard():
    """encode_leaf_sparse tolerates an unsorted dirty set (sorts it with
    its payload) — decode still reproduces the current bytes."""
    from repro.core import delta as deltamod
    cb = 256
    n = cb * 4
    rng = np.random.RandomState(8)
    prev = rng.randint(0, 256, n, dtype=np.uint8)
    cur = prev.copy()
    for i in (3, 0, 2):
        cur[i * cb] ^= 0xFF
    idx = np.array([3, 0, 2], np.int64)
    compact = np.stack([cur[i * cb:(i + 1) * cb] for i in idx])
    blobs = {}
    mirror = prev.copy()
    meta = deltamod.encode_leaf_sparse(
        (n,), np.uint8, cb, 4, idx, compact, mirror,
        lambda k, d: blobs.setdefault(k, d), lambda k: k in blobs)
    np.testing.assert_array_equal(mirror, cur)
    out = deltamod.decode_leaf(meta, blobs.__getitem__, prev=prev)
    np.testing.assert_array_equal(out, cur)


# --- property suite (hypothesis when available, pinned sweep always) -------

def _property_case(n_chunks, tail, mask_bits, seed):
    """For ANY leaf geometry and change mask — odd sizes, partial tail
    chunks, all-clean, all-dirty, single-chunk — the fused kernel, the
    ref.py host twin and the old two-launch path agree bit-for-bit on
    fingerprints, dirty indices and compacted bytes."""
    rng = np.random.RandomState(seed)
    nbytes = max(4, n_chunks * CB - tail) // 4 * 4
    prev = rng.randint(0, 256, nbytes, dtype=np.uint8).view(np.float32)
    real_chunks = -(-nbytes // CB)
    dirty = [i for i in range(real_chunks) if (mask_bits >> i) & 1]
    cur = prev.copy()
    b = cur.view(np.uint8)
    for i in dirty:
        off = i * CB
        b[off] ^= rng.randint(1, 256)
    _three_way(cur, prev, CB)


def test_fused_three_way_pinned_sweep():
    """Deterministic slice of the property space — runs even where
    hypothesis is not installed, so the three-way contract is never
    entirely skipped."""
    rng = np.random.RandomState(9)
    for _ in range(20):
        _property_case(int(rng.randint(1, 11)), int(rng.randint(0, CB)),
                       int(rng.randint(0, 2 ** 10)), int(rng.randint(2 ** 16)))


try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image ships without hypothesis
    pass
else:
    @settings(max_examples=30, deadline=None)
    @given(
        n_chunks=st.integers(1, 10),
        tail=st.integers(0, CB - 1),
        mask_bits=st.integers(0, 2 ** 10 - 1),
        seed=st.integers(0, 2 ** 16),
    )
    def test_fused_three_way_property(n_chunks, tail, mask_bits, seed):
        _property_case(n_chunks, tail, mask_bits, seed)
