"""ClusterSupervisor: the failure loop EXECUTED, not just decided.

Each policy test injects a real host death into a simulated world
(injectable clock — silence past the timeout is death), lets the
supervisor run detect → decide → execute, and then verifies the
continuation is token-identical to an uninterrupted run:

  restart_last_ckpt — teardown + storage repair + Incarnation restore
                      from the latest restorable step;
  hot_spare         — HostMap vid rebind to the spare + a *logged*
                      DataReassign (no restore at all);
  shrink            — elastic restore onto the survivors with the
                      logged DataReassign rewritten during replay
                      (RestoreTarget.rewrite_op).

Plus: straggler feedback triggers a logged rebalance, and a world with
no restorable checkpoint fails loudly instead of limping.
"""
import shutil

import numpy as np
import pytest

from repro.core import (CheckpointManager, ClusterSupervisor, FailureAction,
                        LocalFSBackend, ShardedBackend, StaleHandleError,
                        SupervisorError, rebalance_shards)
from repro.core.oplog import DataReassign
from repro.train.loop import Trainer, TrainJob

JOB = TrainJob(arch="starcoder2-3b-smoke", shape_key="train_s32_b4")
STEPS = 5


def _run_reference():
    t = Trainer(JOB, (1, 1), ("data", "model"))
    t.init_state()
    for _ in range(STEPS):
        m = t.train_steps(1)
    return t.params_digest(), m


@pytest.fixture(scope="module")
def reference():
    return _run_reference()


class _World:
    """Deterministic heartbeat driver: one virtual-clock tick per step;
    hosts in ``down`` stay silent and die of timeout."""

    def __init__(self):
        self._t = 0.0
        self.down = set()
        self.sup = None

    def clock(self) -> float:
        return self._t

    def tick(self, step: int) -> None:
        self._t += 1.0
        for h in self.sup.world:
            if h not in self.down:
                self.sup.beat(h, step)


def _make(world_hosts, mgr, runner, *, spares=(), allow_shrink=True,
          restore=None, n_shards=4, timeout=3.0):
    w = _World()
    sup = ClusterSupervisor(
        list(world_hosts), manager=mgr, spares=list(spares),
        heartbeat_timeout=timeout, clock=w.clock,
        allow_shrink=allow_shrink, n_shards=n_shards,
        restore=restore, runner=runner)
    w.sup = sup
    return sup, w


def _drive_to_death(sup, w, dead_host, step, ticks=6):
    """Heartbeat a few healthy rounds, then silence ``dead_host`` until
    the monitor flags it."""
    for _ in range(2):
        w.tick(step)
    assert sup.poll() is None
    w.down.add(dead_host)
    for _ in range(ticks):
        w.tick(step)
    return sup.poll()


# --- restart_last_ckpt -------------------------------------------------------

def test_restart_policy_token_identical(tmp_path, reference):
    """Host death with no spares and shrink forbidden: the supervisor
    tears the job down, repairs the degraded sharded store from peer
    replicas (the dead host's directory is really deleted), restores
    through the Incarnation from the latest committed step, and the
    continuation is bitwise-identical to the uninterrupted run."""
    ref_digest, ref_metrics = reference
    be = ShardedBackend(str(tmp_path), n_hosts=4, replicate=True)
    mgr = CheckpointManager(be, async_save=False)
    tr = Trainer(JOB, (1, 1), ("data", "model"), manager=mgr)
    tr.init_state()
    # the RUNNER's own (uneven) reassignment, logged before the crash:
    # a restart keeps the world's geometry, so it must replay verbatim —
    # never be rewritten to some synthetic even layout
    custom = ((3, 0), (3, 1), (0, 2), (0, 3))
    tr.apply_reassignment(custom)
    tr.train_steps(2)
    tr.save(block=True)
    tr.train_steps(1)           # uncommitted progress, lost in the crash

    def restore(target):
        assert target.action is FailureAction.RESTART_LAST_CKPT
        assert target.step == 2
        assert target.rewrite_op() is None   # nothing to rewrite: the
        return Trainer.restore(mgr, step=target.step,   # log is truth
                               rewrite_op=target.rewrite_op())

    sup, w = _make([0, 1, 2, 3], mgr, tr, allow_shrink=False,
                   restore=restore)
    # the death takes the host's storage with it
    shutil.rmtree(be.root / "host_001")
    be.fail_host(1)
    target = _drive_to_death(sup, w, dead_host=1, step=3)

    assert target.action is FailureAction.RESTART_LAST_CKPT
    t2 = sup.runner
    assert t2 is not tr
    assert int(t2.upper.get("step")) == 2
    assert t2.lower.data_assignment == custom   # replayed, not rewritten
    m = {}
    for _ in range(STEPS - 2):
        m = t2.train_steps(1)
    assert t2.params_digest() == ref_digest
    assert np.isclose(m["loss"], ref_metrics["loss"])
    assert sup.mttr().get("restart_last_ckpt", -1.0) >= 0.0
    # repair really ran: full redundancy is back on the lost host
    from repro.core import replication
    assert not replication.scan(be).degraded


def test_restart_without_checkpoint_fails_loudly(tmp_path):
    """A death with nothing restorable must raise, not silently lose
    the job."""
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    sup, w = _make([0, 1], mgr, object(), allow_shrink=False,
                   restore=lambda t: pytest.fail("must not restore"))
    w.down.add(1)
    for _ in range(6):
        w.tick(0)
    with pytest.raises(SupervisorError, match="no restorable"):
        sup.poll()


def test_last_host_death_restarts_not_shrinks(tmp_path):
    """Death of the only host leaves nobody to shrink onto: the policy
    must fall through to restart-in-place, never divide by zero."""
    from repro.core import FailurePolicy
    action, info = FailurePolicy(allow_shrink=True).decide([0], world=[0])
    assert action is FailureAction.RESTART_LAST_CKPT, (action, info)

    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    tr = Trainer(JOB, (1, 1), ("data", "model"), manager=mgr)
    tr.init_state()
    tr.save(block=True)
    restored = []

    def restore(target):
        assert target.action is FailureAction.RESTART_LAST_CKPT
        restored.append(target.step)
        return Trainer.restore(mgr, step=target.step)

    sup, w = _make([0], mgr, tr, restore=restore)
    target = _drive_to_death(sup, w, dead_host=0, step=0)
    assert target.action is FailureAction.RESTART_LAST_CKPT
    assert restored == [0]


# --- hot_spare ---------------------------------------------------------------

def test_hot_spare_policy_token_identical(tmp_path, reference):
    """With a spare available the job never rolls back: the dead host's
    logical coordinate rebinds to the spare (same vid), a rebalanced
    DataReassign is logged through the live runner, and training
    continues token-identically on the remapped world."""
    ref_digest, _ = reference
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    tr = Trainer(JOB, (1, 1), ("data", "model"), manager=mgr)
    tr.init_state()
    tr.train_steps(2)
    tr.save(block=True)

    sup, w = _make([0, 1, 2, 3], mgr, tr, spares=[7],
                   restore=lambda t: pytest.fail("hot spare must not "
                                                 "restore"))
    target = _drive_to_death(sup, w, dead_host=1, step=2)

    assert target.action is FailureAction.HOT_SPARE
    assert target.mapping == {1: 7}
    assert sup.runner is tr                      # same live process
    assert sup.world == [0, 7, 2, 3]             # logical order kept
    assert sup.hostmap.physical(1) == 7          # vid rebound, not new
    assert sup.policy.spares == []               # spare consumed
    assert 7 in sup.monitor.hosts and 1 not in sup.monitor.hosts
    # the rebalance is LOGGED (replays after any later restart) and live
    reassigns = [op for op in tr.lower.oplog.ops
                 if isinstance(op, DataReassign)]
    assert reassigns and reassigns[-1].assignment == \
        tuple(rebalance_shards(4, [0, 7, 2, 3]))
    assert tr.pipeline.assignment == list(reassigns[-1].assignment)

    for _ in range(STEPS - 2):
        tr.train_steps(1)
    assert tr.params_digest() == ref_digest

    # and the logged decision survives a plain restart: a later
    # checkpoint of this incarnation carries the reassignment forward
    tr.save(block=True)
    t2 = Trainer.restore(mgr)
    assert t2.lower.data_assignment == reassigns[-1].assignment
    assert t2.pipeline.assignment == list(reassigns[-1].assignment)


def test_recovery_absorbs_casualty_snapshot_failure(tmp_path):
    """An async snapshot whose writer died WITH the host raises out of
    the pipeline's drain; recovery must absorb that casualty (it IS the
    incident) and restore from the last committed step — not crash on
    the very error it exists to handle."""
    be = ShardedBackend(str(tmp_path), n_hosts=2, replicate=True)
    mgr = CheckpointManager(be, async_save=True)
    tr = Trainer(JOB, (1, 1), ("data", "model"), manager=mgr)
    tr.init_state()
    tr.train_steps(1)
    tr.save(block=True)              # step 1: committed, the target
    be.fail_host(1)
    tr.train_steps(1)
    handle = tr.snapshot()           # step 2: dies on the downed writer
    if handle is not None:
        with pytest.raises(IOError):
            handle.result()          # failed, but drain() still holds it

    def restore(target):
        assert target.step == 1
        return Trainer.restore(mgr, step=target.step)

    sup, w = _make([0, 1], mgr, tr, allow_shrink=False, restore=restore)
    shutil.rmtree(be.root / "host_001")
    target = _drive_to_death(sup, w, dead_host=1, step=2)
    assert target.action is FailureAction.RESTART_LAST_CKPT
    assert int(sup.runner.upper.get("step")) == 1
    assert any(kind == "casualty_snapshot" for _, kind, _ in sup.events)
    # and the healed store accepts the next snapshot
    sup.runner.train_steps(1)
    sup.runner.save(block=True)
    assert mgr.backend.latest_step() == 2


def test_hot_spare_repairs_colocated_storage(tmp_path):
    """A death that takes its co-located storage host with it: the
    takeover must repair the degraded store (peer copies -> full
    redundancy, writer healed) or the runner's very next snapshot
    would die on the downed writer — violating 'the runner never
    stops'."""
    from repro.core import replication
    be = ShardedBackend(str(tmp_path), n_hosts=4, replicate=True)
    mgr = CheckpointManager(be, async_save=False)
    tr = Trainer(JOB, (1, 1), ("data", "model"), manager=mgr)
    tr.init_state()
    tr.train_steps(1)
    tr.save(block=True)

    sup, w = _make([0, 1, 2, 3], mgr, tr, spares=[7],
                   restore=lambda t: pytest.fail("hot spare must not "
                                                 "restore"))
    shutil.rmtree(be.root / "host_001")
    be.fail_host(1)
    target = _drive_to_death(sup, w, dead_host=1, step=1)

    assert target.action is FailureAction.HOT_SPARE
    assert not replication.scan(be).degraded
    tr.train_steps(1)
    tr.save(block=True)          # the downed writer would raise here
    assert mgr.backend.latest_step() == 2


# --- shrink ------------------------------------------------------------------

def test_shrink_policy_token_identical(tmp_path, reference):
    """No spares, shrink allowed: the dead logical host leaves the
    world, the runner restores elastically onto the survivors with the
    logged DataReassign rewritten to the survivor assignment during
    replay (RestoreTarget.rewrite_op), and the continuation is
    token-identical — moving shard ownership never changes the data."""
    ref_digest, _ = reference
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    tr = Trainer(JOB, (1, 1), ("data", "model"), manager=mgr)
    tr.init_state()
    tr.apply_reassignment(rebalance_shards(4, [0, 1, 2]))  # op to rewrite
    tr.train_steps(2)
    tr.save(block=True)

    def restore(target):
        assert target.action is FailureAction.SHRINK
        assert target.hosts == [0, 1]
        return Trainer.restore(mgr, step=target.step,
                               rewrite_op=target.rewrite_op())

    sup, w = _make([0, 1, 2], mgr, tr, restore=restore)
    target = _drive_to_death(sup, w, dead_host=2, step=2)

    assert target.action is FailureAction.SHRINK
    assert sup.world == [0, 1]
    assert sup.hostmap.logical_of(2) is None
    with pytest.raises(StaleHandleError):
        sup.hostmap.physical(2)                  # unbound, fails loudly
    t2 = sup.runner
    assert t2 is not tr
    # the REPLAYED log carries the rewritten assignment: only survivors
    want = tuple(rebalance_shards(4, [0, 1]))
    assert t2.lower.data_assignment == want
    assert t2.pipeline.assignment == list(want)
    assert all(h in (0, 1) for h, _ in t2.pipeline.assignment)

    for _ in range(STEPS - 2):
        t2.train_steps(1)
    assert t2.params_digest() == ref_digest


# --- grow (the inverse of shrink) --------------------------------------------

def test_grow_after_shrink_token_identical(tmp_path, reference):
    """Elastic expansion: after a shrink, the recovered host re-enters
    the world through ``grow`` — its vacated logical slot revives (same
    vid machinery as a hot-spare remap), the runner rebuilds from the
    latest step with the logged DataReassign rewritten onto the grown
    assignment, and the continuation is token-identical: moving shard
    ownership never changes the data, in either direction."""
    ref_digest, _ = reference
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    tr = Trainer(JOB, (1, 1), ("data", "model"), manager=mgr)
    tr.init_state()
    tr.apply_reassignment(rebalance_shards(4, [0, 1, 2]))
    tr.train_steps(2)
    tr.save(block=True)

    def restore(target):
        return Trainer.restore(mgr, step=target.step,
                               rewrite_op=target.rewrite_op())

    sup, w = _make([0, 1, 2], mgr, tr, restore=restore)
    target = _drive_to_death(sup, w, dead_host=2, step=2)
    assert target.action is FailureAction.SHRINK
    t2 = sup.runner
    t2.train_steps(1)            # progress on the shrunken world
    t2.save(block=True)          # step 3: what the grow resumes from

    sup.policy.spares.append(2)  # the host recovered
    gt = sup.grow()
    assert gt.action is FailureAction.GROW
    assert gt.step == 3          # fresh checkpoint -> zero rollback
    assert sup.world == [0, 1, 2]
    assert sup.hostmap.logical_of(2) == 2    # vacated slot revived
    assert sup.policy.spares == []
    assert 2 in sup.monitor.hosts
    assert sup.incidents[-1].action == "grow"
    t3 = sup.runner
    assert t3 is not t2
    want = tuple(rebalance_shards(4, [0, 1, 2]))
    assert t3.lower.data_assignment == want
    assert t3.pipeline.assignment == list(want)
    for _ in range(STEPS - 3):
        t3.train_steps(1)
    assert t3.params_digest() == ref_digest


def test_grow_validates_host(tmp_path):
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    sup, _ = _make([0, 1], mgr, object())
    with pytest.raises(SupervisorError, match="spare pool is empty"):
        sup.grow()
    with pytest.raises(SupervisorError, match="already serves"):
        sup.grow(1)


def test_grow_without_restorable_checkpoint_fails_loudly(tmp_path):
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    sup, _ = _make([0], mgr, object(),
                   restore=lambda t: pytest.fail("must not restore"))
    with pytest.raises(SupervisorError, match="no restorable"):
        sup.grow(5)


# --- planned_move: the unhappy paths -----------------------------------------

def test_planned_move_without_spare_is_deliberate_shrink(tmp_path,
                                                         reference):
    """Draining with nobody to land on shrinks the world ON PURPOSE:
    the drained host's logical slot unbinds, the runner rebuilds on the
    survivors through the same ``_recover`` path a SHRINK decision
    uses, and the continuation is token-identical."""
    ref_digest, _ = reference
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    tr = Trainer(JOB, (1, 1), ("data", "model"), manager=mgr)
    tr.init_state()
    tr.apply_reassignment(rebalance_shards(4, [0, 1, 2]))
    tr.train_steps(2)
    tr.save(block=True)

    def restore(target):
        assert target.action is FailureAction.PLANNED_MOVE
        assert target.hosts == [0, 1]
        return Trainer.restore(mgr, step=target.step,
                               rewrite_op=target.rewrite_op())

    sup, _ = _make([0, 1, 2], mgr, tr, restore=restore)
    target = sup.planned_move(2)
    assert sup.world == [0, 1]
    assert sup.hostmap.logical_of(2) is None
    assert 2 not in sup.monitor.hosts
    assert sup.incidents[-1].action == "planned_drain"
    t2 = sup.runner
    assert t2 is not tr
    want = tuple(rebalance_shards(4, [0, 1]))
    assert t2.lower.data_assignment == want
    for _ in range(STEPS - 2):
        t2.train_steps(1)
    assert t2.params_digest() == ref_digest


def test_drained_host_readmitted_by_later_failure(tmp_path, reference):
    """A drained host goes back to the spare pool as REUSABLE capacity:
    when its replacement later dies, the hot-spare policy consumes the
    previously drained host and it serves again."""
    ref_digest, _ = reference
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    tr = Trainer(JOB, (1, 1), ("data", "model"), manager=mgr)
    tr.init_state()
    tr.train_steps(2)
    tr.save(block=True)

    sup, w = _make([0, 1], mgr, tr, spares=[7],
                   restore=lambda t: pytest.fail("hot paths must not "
                                                 "restore"))
    moved = sup.planned_move(1)
    assert moved.mapping == {1: 7}
    assert sup.world == [0, 7]
    assert sup.policy.spares == [1]          # drained, not dead

    target = _drive_to_death(sup, w, dead_host=7, step=2)
    assert target.action is FailureAction.HOT_SPARE
    assert target.mapping == {7: 1}
    assert sup.world == [0, 1]               # the drained host is back
    assert sup.policy.spares == []
    for _ in range(STEPS - 2):
        tr.train_steps(1)
    assert tr.params_digest() == ref_digest


# --- straggler feedback ------------------------------------------------------

def test_straggler_triggers_logged_rebalance(tmp_path):
    """A host whose per-step EWMA exceeds k x median gets its shards
    moved to the fast hosts — as a logged DataReassign on the live
    runner, so the mitigation survives a later restart."""
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    tr = Trainer(JOB, (1, 1), ("data", "model"), manager=mgr)
    tr.init_state()
    sup, w = _make([0, 1, 2, 3], mgr, tr, n_shards=8, timeout=1000.0)
    # hosts 0-2 step once per tick; host 3 once per three ticks (its
    # per-step EWMA lands at 3x the others')
    w.down.add(3)          # out of the regular ticker, beaten by hand
    for step in range(1, 10):
        w.tick(step)
        if step % 3 == 0:
            sup.beat(3, step // 3)
    slow = sup.check_stragglers()
    assert slow == [3]
    reassigns = [op for op in tr.lower.oplog.ops
                 if isinstance(op, DataReassign)]
    assert len(reassigns) == 1
    assert all(h != 3 for h, _ in reassigns[-1].assignment)
    assert {s for _, s in reassigns[-1].assignment} == set(range(8))
    assert tr.pipeline.assignment == list(reassigns[-1].assignment)
    # already-applied assignment is not re-logged on the next check
    assert sup.check_stragglers() == [3]
    assert sum(isinstance(op, DataReassign)
               for op in tr.lower.oplog.ops) == 1


# --- serving under the supervisor -------------------------------------------

def test_serving_shrink_reslot_token_identical(tmp_path):
    """The serving flavor of the loop: a host death shrinks a 2-slot
    engine onto 1 slot through the elastic re-slot restore path
    (CacheAlloc/Compile rewritten on replay), and every live request
    still finishes token-identically to the uninterrupted run."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.engine import Request, ServingEngine

    cfg = get_smoke_config("phi4-mini-3.8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab_size, size=4) for _ in range(4)]

    def fresh_requests():
        return [Request(rid=i, prompt=p.copy(), max_new=5)
                for i, p in enumerate(prompts)]

    # uninterrupted reference
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    ref_eng = ServingEngine(cfg, params, mesh, n_slots=2, max_seq=32)
    ref = fresh_requests()
    for r in ref:
        ref_eng.submit(r)
    ref_eng.run_until_drained(max_steps=200)
    want = {r.rid: list(r.out) for r in ref}

    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    eng = ServingEngine.create("phi4-mini-3.8b-smoke", params, (1, 1),
                               n_slots=2, max_seq=32, manager=mgr)
    reqs = fresh_requests()
    for r in reqs:
        eng.submit(r)
    for _ in range(4):
        eng.step()
    eng.snapshot(block=True)
    assert any(eng.slot_req), "must snapshot mid-flight"

    def restore(target):
        return ServingEngine.restore(mgr, params,
                                     n_slots=len(target.hosts),
                                     step=target.step)

    sup, w = _make([0, 1], mgr, eng, restore=restore, n_shards=None)
    target = _drive_to_death(sup, w, dead_host=1, step=4)
    assert target.action is FailureAction.SHRINK

    eng2 = sup.runner
    assert eng2.n_slots == 1
    finished = {r.rid: list(r.out) for r in reqs if r.done}
    live = eng2.live_requests()
    assert {r.rid for r in live} | set(finished) == set(want)
    eng2.run_until_drained(max_steps=200)
    for r in live:
        assert r.done and r.out == want[r.rid], (r.rid, r.out, want[r.rid])
    for rid, out in finished.items():
        assert out == want[rid]


def test_serving_grow_reslot_token_identical(tmp_path):
    """Serving's grow: after a shrink onto 1 slot, the recovered host
    rejoins via ``grow`` and the live sessions re-slot back onto a
    2-slot engine through the same elastic restore path — every request
    still finishes token-identically."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.engine import Request, ServingEngine

    cfg = get_smoke_config("phi4-mini-3.8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab_size, size=4) for _ in range(4)]

    def fresh_requests():
        return [Request(rid=i, prompt=p.copy(), max_new=5)
                for i, p in enumerate(prompts)]

    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    ref_eng = ServingEngine(cfg, params, mesh, n_slots=2, max_seq=32)
    ref = fresh_requests()
    for r in ref:
        ref_eng.submit(r)
    ref_eng.run_until_drained(max_steps=200)
    want = {r.rid: list(r.out) for r in ref}

    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    eng = ServingEngine.create("phi4-mini-3.8b-smoke", params, (1, 1),
                               n_slots=2, max_seq=32, manager=mgr)
    reqs = fresh_requests()
    for r in reqs:
        eng.submit(r)
    for _ in range(4):
        eng.step()
    eng.snapshot(block=True)

    def restore(target):
        return ServingEngine.restore(mgr, params,
                                     n_slots=len(target.hosts),
                                     step=target.step)

    sup, w = _make([0, 1], mgr, eng, restore=restore, n_shards=None)
    target = _drive_to_death(sup, w, dead_host=1, step=4)
    assert target.action is FailureAction.SHRINK
    eng2 = sup.runner
    assert eng2.n_slots == 1

    if any(eng2.slot_req) or eng2.queue:
        eng2.step()                      # progress on the small engine
    eng2.snapshot(block=True)            # what the grow resumes from
    sup.policy.spares.append(1)          # the host recovered
    gt = sup.grow()
    assert gt.action is FailureAction.GROW
    assert gt.hosts == [0, 1]
    eng3 = sup.runner
    assert eng3.n_slots == 2             # slots expanded back

    finished = {r.rid: list(r.out) for r in reqs if r.done}
    finished.update({r.rid: list(r.out)
                     for r in eng2.live_requests() if r.done})
    live = eng3.live_requests()
    eng3.run_until_drained(max_steps=200)
    for r in live:
        assert r.done and r.out == want[r.rid], (r.rid, r.out, want[r.rid])
    for rid, out in finished.items():
        assert out == want[rid]


# --- multi-device shrink (slow: fresh jax subprocess) ------------------------

@pytest.mark.slow
def test_shrink_onto_smaller_mesh_multidevice(subproc):
    """The full elastic story under the supervisor: four hosts each
    backing one device column of a (2,2) mesh; a host death shrinks the
    job onto a (2,1) mesh over the survivors' devices via the
    supervisor's restore hook (mesh_factory + rewrite_op), restore is
    digest-exact and the continuation loss matches the big-mesh run."""
    out = subproc("""
    import tempfile, numpy as np, jax
    from repro.core import (CheckpointManager, ClusterSupervisor,
                            FailureAction, LocalFSBackend)
    from repro.train.loop import Trainer, TrainJob
    job = TrainJob(arch="phi4-mini-3.8b-smoke", shape_key="train_s16_b4")
    root = tempfile.mkdtemp()
    mgr = CheckpointManager(LocalFSBackend(root), async_save=False)
    tr = Trainer(job, (2, 2), ("data", "model"), manager=mgr)
    tr.init_state()
    tr.train_steps(2)
    tr.save(block=True)
    d0 = tr.params_digest()
    ref_loss = Trainer.restore(mgr).train_steps(1)["loss"]

    t = [0.0]
    def restore(target):
        return Trainer.restore(
            mgr, step=target.step,
            mesh_factory=lambda: jax.make_mesh((2, 1), ("data", "model")),
            rewrite_op=target.rewrite_op())
    sup = ClusterSupervisor([0, 1, 2, 3], manager=mgr,
                            heartbeat_timeout=3.0, clock=lambda: t[0],
                            n_shards=4, restore=restore, runner=tr)
    for step in range(8):
        t[0] += 1.0
        for h in (0, 1, 2):
            sup.beat(h, step)
    target = sup.poll()
    assert target.action is FailureAction.SHRINK, target
    t2 = sup.runner
    assert dict(t2.lower.mesh.shape) == {"data": 2, "model": 1}
    assert t2.params_digest() == d0, "restore must be exact"
    assert all(h in (0, 1, 2) for h, _ in t2.lower.data_assignment)
    loss = t2.train_steps(1)["loss"]
    np.testing.assert_allclose(loss, ref_loss, rtol=2e-2, atol=2e-3)
    print("SHRINK-MESH OK", loss)
    """, n_devices=4)
    assert "SHRINK-MESH OK" in out
