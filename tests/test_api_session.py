"""CheckpointSession: the one checkpoint-agnostic lifecycle.

The acceptance case for the API redesign: an app implemented ONLY
against ``repro.api`` (the streaming aggregator example) is killed
mid-run and restored to identical state through the app-kind registry;
the legacy ``Trainer.restore``/``ServingEngine.restore`` entry points
are thin shims over the same session API; the supervisor drives apps
only through protocol hooks."""
import os
import sys

import numpy as np
import pytest

from repro.api import (CheckpointSession, Policy, PolicyError,
                       UpperHalf, register_app_kind)

EXAMPLES = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "examples")
if EXAMPLES not in sys.path:
    sys.path.insert(0, EXAMPLES)

import checkpointable_pipeline as cp  # noqa: E402  (registers its kind)


# --- a minimal in-test app ---------------------------------------------------

class TinyApp:
    """Smallest possible protocol citizen (numpy state, no model)."""

    def __init__(self, kind="tiny"):
        self.kind = kind
        self.x = np.zeros(4, np.float64)
        self.n = 0

    def step(self):
        self.x += np.arange(4) + self.n
        self.n += 1

    def checkpoint_state(self):
        up = UpperHalf()
        up.register("x", "x", self.x.copy())
        up.register("n", "step", np.int64(self.n))
        return up

    def checkpoint_step(self):
        return self.n

    def job_meta(self):
        return {"kind": self.kind}

    def bind(self, restore):
        self.x = np.asarray(restore.tree("x"), np.float64).copy()
        self.n = int(restore.scalar("n"))
        restore.release()


@register_app_kind("tiny")
def _restore_tiny(restore):
    app = TinyApp()
    app.bind(restore)
    return app


# --- the acceptance round-trip ----------------------------------------------

@pytest.mark.parametrize("scheme", ["localfs", "sharded"])
def test_pipeline_app_kill_restore_identical(tmp_path, scheme):
    """Kill the example app mid-run; restore lands on identical
    aggregation state and the finished run matches an uninterrupted
    one — under BOTH checkpoint packages (spec is a one-string swap)."""
    ref = cp.StreamAggregator(n_bins=8, seed=3)
    ref.ingest(60)

    suffix = "?hosts=3" if scheme == "sharded" else ""
    with CheckpointSession(f"{scheme}:{tmp_path}{suffix}",
                           Policy(interval=7, chain=3)) as sess:
        app = sess.attach(cp.StreamAggregator(n_bins=8, seed=3))
        for _ in range(30):
            app.ingest(1)
            sess.maybe_snapshot()
        sess.wait()
        mid_counts = app.counts.copy()
        mid_cursor = app.cursor
        del app   # crash

        app2 = sess.restore("latest")
        assert isinstance(app2, cp.StreamAggregator)
        assert app2.cursor == 28 <= mid_cursor   # last interval boundary
        # identical aggregation state at the restored cursor
        probe = cp.StreamAggregator(n_bins=8, seed=3)
        probe.ingest(app2.cursor)
        assert app2.digest() == probe.digest()
        np.testing.assert_array_equal(
            app2.counts + 0, probe.counts)  # arrays, not just digest
        app2.ingest(60 - app2.cursor)
        assert app2.digest() == ref.digest()
        assert not np.array_equal(mid_counts, probe.counts) or \
            mid_cursor == app2.cursor  # the kill really lost progress


def test_example_imports_only_the_api():
    """The agnosticism proof is only a proof if the example can't cheat:
    no repro.core (or deeper) import anywhere in its source."""
    src = open(os.path.join(EXAMPLES, "checkpointable_pipeline.py")).read()
    imports = [ln for ln in src.splitlines()
               if ln.lstrip().startswith(("import ", "from "))]
    offenders = [ln for ln in imports
                 if "repro" in ln and "repro.api" not in ln]
    assert not offenders, offenders
    assert any("repro.api" in ln for ln in imports)


# --- protocol validation + cadence ------------------------------------------

def test_attach_rejects_non_protocol_object(tmp_path):
    sess = CheckpointSession(f"localfs:{tmp_path}")
    try:
        with pytest.raises(PolicyError, match="checkpoint_state"):
            sess.attach(object())
    finally:
        sess.close()


def test_attach_requires_kind_in_job_meta(tmp_path):
    class NoKind(TinyApp):
        def job_meta(self):
            return {}

    sess = CheckpointSession(f"localfs:{tmp_path}")
    try:
        with pytest.raises(PolicyError, match="kind"):
            sess.attach(NoKind())
    finally:
        sess.close()


def test_snapshot_without_app_is_actionable(tmp_path):
    sess = CheckpointSession(f"localfs:{tmp_path}")
    try:
        with pytest.raises(PolicyError, match="attach"):
            sess.snapshot()
    finally:
        sess.close()


def test_maybe_snapshot_cadence(tmp_path):
    with CheckpointSession(f"localfs:{tmp_path}",
                           Policy(interval=3, async_save=False)) as sess:
        app = sess.attach(TinyApp())
        for _ in range(7):
            app.step()
            sess.maybe_snapshot()
        assert sess.backend.list_steps() == [3, 6]
        sess.maybe_snapshot(final=True)
        assert sess.backend.list_steps() == [3, 6, 7]


def test_restore_unknown_kind_is_actionable(tmp_path):
    with CheckpointSession(f"localfs:{tmp_path}",
                           Policy(async_save=False)) as sess:
        app = TinyApp(kind="never-registered")
        sess.attach(app)
        app.step()
        sess.snapshot(block=True)
        with pytest.raises(PolicyError, match="register_app_kind"):
            sess.restore("latest")


def test_expect_kind_guard(tmp_path):
    with CheckpointSession(f"localfs:{tmp_path}",
                           Policy(async_save=False)) as sess:
        app = sess.attach(TinyApp())
        app.step()
        sess.snapshot(block=True)
        with pytest.raises(PolicyError, match="not a serving checkpoint"):
            sess.restore("latest", expect_kind="serving")


# --- the legacy shims delegate to the session API ---------------------------

def test_trainer_restore_shim_delegates(monkeypatch):
    from repro.train.loop import Trainer
    calls = {}

    def fake_restore(self, step=None, **kw):
        calls["step"] = step
        calls.update(kw)
        return "the-trainer"

    monkeypatch.setattr(CheckpointSession, "restore", fake_restore)

    class FakeMgr:
        backend = None

    with pytest.warns(DeprecationWarning, match="CheckpointSession"):
        out = Trainer.restore(FakeMgr(), step=7, decode_workers=2)
    assert out == "the-trainer"
    assert calls["step"] == 7
    assert calls["expect_kind"] == "train"
    assert calls["decode_workers"] == 2


def test_engine_restore_shim_delegates(monkeypatch):
    from repro.serving.engine import ServingEngine
    calls = {}

    def fake_restore(self, step=None, **kw):
        calls["step"] = step
        calls.update(kw)
        return "the-engine"

    monkeypatch.setattr(CheckpointSession, "restore", fake_restore)

    class FakeMgr:
        backend = None

    with pytest.warns(DeprecationWarning, match="CheckpointSession"):
        out = ServingEngine.restore(FakeMgr(), params={"p": 1}, n_slots=3)
    assert out == "the-engine"
    assert calls["expect_kind"] == "serving"
    assert calls["n_slots"] == 3
    assert calls["params"] == {"p": 1}


def test_tiny_app_save_restore_through_manager_session(tmp_path):
    """from_manager adopts an existing CheckpointManager — the shim
    construction path — and the round trip still works."""
    from repro.core import CheckpointManager, LocalFSBackend
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)),
                            async_save=False)
    sess = CheckpointSession.from_manager(mgr)
    app = sess.attach(TinyApp())
    app.step()
    app.step()
    sess.snapshot(block=True)
    app2 = CheckpointSession.from_manager(mgr).restore()
    assert app2.n == 2
    np.testing.assert_array_equal(app2.x, app.x)


# --- the supervisor drives apps only through protocol hooks ------------------

def test_supervisor_quiesce_hook_runs_at_teardown(tmp_path):
    with CheckpointSession(f"localfs:{tmp_path}",
                           Policy(async_save=False)) as sess:
        app = sess.attach(cp.StreamAggregator(n_bins=4, seed=0))
        app.ingest(3)
        sess.snapshot(block=True)
        restored = []
        sup = sess.supervise([0], heartbeat_timeout=1.0,
                             on_restored=lambda a, t: restored.append(a))
        assert sup.runner is app
        sup._recover(_fake_target())
        assert app.quiesced == 1           # protocol hook, not duck luck
        assert restored and restored[0].cursor == 3
        assert sup.runner is restored[0]
        assert sess.app is restored[0]     # session follows the swap


def _fake_target():
    from repro.core.supervisor import RestoreTarget
    from repro.core.failure import FailureAction
    return RestoreTarget(FailureAction.RESTART_LAST_CKPT, step=None,
                         hosts=[0])
