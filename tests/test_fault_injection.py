"""Kill-anywhere crash consistency: a CrashingBackend wrapper dies at
every write/fsync/rename boundary in turn — before a write lands
(``pre``), between the temp-file write and the atomic rename (``torn``:
a stale ``.tmp`` file is really left behind), and immediately after
durability (``post``) — across blob writes, manifest commits and GC
deletions, for both backends.

After every injected crash the store is reopened cold and must hold the
commit protocol's promise: every step ``restorable_steps`` lists
restores bit-identically to the state that was live when it was saved,
the newest committed step is never lost, no manifest is torn, and a
fresh manager can keep checkpointing (and GC'ing) on top of the
survivor. The crash points are enumerated by a dry run, so the suite
automatically covers new boundaries as the pipeline grows.
"""
import json

import numpy as np
import pytest

from repro.core import (CheckpointManager, LocalFSBackend, OpLog,
                        ShardedBackend, UpperHalf)
from repro.core.restore import restorable_steps


class CrashPoint(RuntimeError):
    """The simulated process death."""


class CrashingBackend:
    """Wraps a real backend; the k-th mutation boundary raises and the
    backend goes dead (every later mutation raises too — a dead process
    issues no more writes). ``crash_at=None`` counts boundaries.

    Boundary stages mirror ``write_atomic``:
      pre   nothing reached disk;
      torn  a partial ``.tmp`` file sits in the real target directory,
            nothing was renamed into place (only for writes);
      post  the operation is fully durable, the crash hits just after.
    """

    def __init__(self, inner, crash_at=None):
        self.inner = inner
        self.crash_at = crash_at
        self.boundary = 0
        self.dead = False

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def _point(self, torn_dir=None, data=b""):
        if self.dead:
            raise CrashPoint("backend is dead")
        k = self.boundary
        self.boundary += 1
        if self.crash_at is not None and k == self.crash_at:
            self.dead = True
            if torn_dir is not None:
                # what a kill between write and rename really leaves
                (torn_dir / f".tmp_torn{k}").write_bytes(
                    data[:max(1, len(data) // 2)])
            raise CrashPoint(f"injected crash at boundary {k}")

    def _blob_dir(self, name):
        if isinstance(self.inner, ShardedBackend):
            return self.inner._paths(name)[0].parent
        p = self.inner._blob_path(name)
        p.parent.mkdir(parents=True, exist_ok=True)
        return p.parent

    def put_blob(self, name, data):
        self._point()                                    # pre
        self._point(self._blob_dir(name), data)          # torn
        self.inner.put_blob(name, data)
        self._point()                                    # post

    def commit_manifest(self, step, manifest):
        payload = json.dumps(manifest).encode()
        self._point()                                    # pre
        self._point(self.inner._manifest_path(step).parent, payload)
        self.inner.commit_manifest(step, manifest)
        self._point()                                    # post

    def delete_step(self, step):
        self._point()                                    # pre
        self.inner.delete_step(step)
        self._point()                                    # post

    def gc_blobs(self, referenced):
        self._point()                                    # pre
        n = self.inner.gc_blobs(referenced)
        self._point()                                    # post
        return n


BACKENDS = {
    "localfs": lambda root: LocalFSBackend(root),
    "sharded": lambda root: ShardedBackend(root, n_hosts=3,
                                           replicate=True),
}


def _workload(be):
    """Deterministic save sequence exercising every pipeline moving
    part: delta chains (base interval 2), retention GC (keep_last 2),
    in-place mutation between saves. Returns ({step: expected leaves},
    [steps whose save returned committed]) at the instant of death."""
    rng = np.random.RandomState(0)
    up = UpperHalf()
    w = rng.randn(20_000).astype(np.float32)
    b = rng.randn(64).astype(np.float32)
    up.register("params", "params", {"w": w, "b": b})
    up.register("step", "step", np.int64(0))
    mgr = CheckpointManager(be, async_save=False, delta_base_interval=2,
                            keep_last=2)
    want, committed = {}, []
    for s in (1, 2, 3, 4):
        w[s::71] += 1.0
        up.update("step", np.int64(s))
        want[s] = {"['w']": w.copy(), "['b']": b.copy(), "step": s}
        try:
            mgr.save(s, up, OpLog())
        except CrashPoint:
            break
        committed.append(s)
    return want, committed


def _count_boundaries(backend_key) -> int:
    """Dry run: how many crash points does the workload cross?"""
    import shutil
    import tempfile
    root = tempfile.mkdtemp(prefix=f"dry_{backend_key}_")
    try:
        be = CrashingBackend(BACKENDS[backend_key](root))
        _workload(be)
        return be.boundary
    finally:
        shutil.rmtree(root, ignore_errors=True)


def pytest_generate_tests(metafunc):
    if "crash_at" not in metafunc.fixturenames:
        return
    cases = []
    for key in BACKENDS:
        n = _count_boundaries(key)
        assert n > 20, f"suspiciously few boundaries for {key}: {n}"
        cases += [(key, k) for k in range(n)]
    metafunc.parametrize(("backend_key", "crash_at"), cases,
                         ids=[f"{b}-{k}" for b, k in cases])


def test_crash_anywhere_reopens_committed(backend_key, crash_at, tmp_path):
    be = CrashingBackend(BACKENDS[backend_key](str(tmp_path)),
                         crash_at=crash_at)
    want, committed = _workload(be)
    assert be.dead, "the injected boundary must have been reached"

    # --- reopen cold, exactly like a restarted process ----------------
    be2 = BACKENDS[backend_key](str(tmp_path))
    ok = restorable_steps(be2)

    # no torn manifests: every published manifest parses and the torn
    # temp file (if this crash point left one) is invisible to listing
    for s in be2.list_steps():
        m = be2.get_manifest(s)
        assert m["step"] == s

    # the newest step whose save() returned is never lost (keep_last=2
    # always retains the newest; GC can only have removed older ones)
    if committed:
        assert committed[-1] in ok

    # every restorable step restores to the exact bytes live at its
    # save — including a step whose manifest landed but whose save()
    # still raised (a post-commit crash: durable is durable)
    mgr2 = CheckpointManager(be2, async_save=False)
    for s in ok:
        r = mgr2.restore(s)
        np.testing.assert_array_equal(r.entries["params"]["['w']"],
                                      want[s]["['w']"])
        np.testing.assert_array_equal(r.entries["params"]["['b']"],
                                      want[s]["['b']"])
        assert int(r.entries["step"][""]) == want[s]["step"]

    # GC is still correct: a fresh manager checkpoints and GCs on top
    # of the survivor store, and afterwards every listed step (old and
    # new) still restores — no referenced blob was ever collected
    rng = np.random.RandomState(1)
    up = UpperHalf()
    up.register("params", "params",
                {"w": rng.randn(20_000).astype(np.float32),
                 "b": rng.randn(64).astype(np.float32)})
    up.register("step", "step", np.int64(100))
    mgr3 = CheckpointManager(be2, async_save=False, delta_base_interval=2,
                             keep_last=2)
    mgr3.save(100, up, OpLog())
    after = restorable_steps(be2)
    assert 100 in after
    for s in after:
        mgr3.restore(s)
