"""core.replication: peer-replica repair of a lost ShardedBackend host,
and the coordinator's loud-commit contract (a manifest referencing lost
writes is refused, never published silently partial)."""
import shutil

import numpy as np
import pytest

from repro.core import (CheckpointManager, Incarnation, OpLog,
                        ShardedBackend, UpperHalf, replication)
from repro.core.backends.sharded import _host_of


def _mk_upper(seed=0, n=60_000):
    rng = np.random.RandomState(seed)
    up = UpperHalf()
    up.register("params", "params",
                {"w": rng.randn(n).astype(np.float32),
                 "b": rng.randn(256).astype(np.float32)})
    up.register("step", "step", np.int64(seed))
    return up


def _blob_census(be: ShardedBackend):
    """{host: set(blob filenames)} across the store."""
    out = {}
    for h in range(be.n_hosts):
        d = be.root / f"host_{h:03d}"
        out[h] = set(p.name for p in d.iterdir()) if d.is_dir() else set()
    return out


# --- repair ------------------------------------------------------------------

def test_repair_rebuilds_lost_host_from_peers(tmp_path):
    """fail_host(h) + delete host_h's directory: repair() restores every
    blob the host held — owned primaries from the (h+1)%N replicas,
    held replicas from the (h-1)%N primaries — byte-identically."""
    be = ShardedBackend(str(tmp_path), n_hosts=4, replicate=True)
    mgr = CheckpointManager(be, async_save=False)
    up = _mk_upper(1)
    mgr.save(1, up, OpLog())
    before = _blob_census(be)
    lost = 2
    assert before[lost], "host 2 must own something for the test to bite"
    data_before = {name: (be.root / f"host_{lost:03d}" / name).read_bytes()
                   for name in before[lost]}

    be.fail_host(lost)
    shutil.rmtree(be.root / f"host_{lost:03d}")

    rep = replication.repair(be, host=lost)
    assert rep.restored == len(before[lost])
    assert not rep.unrecoverable
    after = _blob_census(be)
    assert after == before
    for name, want in data_before.items():
        got = (be.root / f"host_{lost:03d}" / name).read_bytes()
        assert got == want
    # healed: reads hit the primary again, and a fresh scan is clean
    assert lost not in be._failed_hosts
    assert not replication.scan(be).degraded


def test_repair_then_incarnation_restore(tmp_path):
    """The supervisor's sequence: lose a host wholesale, repair from
    peers, then a full Incarnation restore over the repaired store
    succeeds bit-identically (replicate=True)."""
    be = ShardedBackend(str(tmp_path), n_hosts=3, replicate=True)
    mgr = CheckpointManager(be, async_save=False)
    up = _mk_upper(2)
    want = np.array(up.get("params")["w"])
    mgr.save(5, up, OpLog())

    be.fail_host(0)
    shutil.rmtree(be.root / "host_000")
    replication.repair(be, host=0)

    inc = Incarnation(mgr)
    state = inc.materialize()
    np.testing.assert_array_equal(state.entries["params"]["['w']"], want)
    assert int(inc.scalar("step")) == 2


def test_scan_reports_degradation_and_unrecoverable(tmp_path):
    """scan() is read-only truth: missing copies are counted, a blob
    with no surviving copy is named, and repair() reports (not hides)
    the unrecoverable ones."""
    be = ShardedBackend(str(tmp_path), n_hosts=4, replicate=True)
    mgr = CheckpointManager(be, async_save=False)
    mgr.save(1, _mk_upper(3), OpLog())
    assert not replication.scan(be).degraded

    # delete one primary: degraded but recoverable
    census = _blob_census(be)
    h, name = next((h, n) for h, names in census.items()
                   for n in names if not n.startswith("replica_"))
    (be.root / f"host_{h:03d}" / name).unlink()
    rep = replication.scan(be)
    assert rep.missing_primaries == 1 and not rep.unrecoverable

    # delete its replica too: unrecoverable, and repair says so
    r = (h + 1) % be.n_hosts
    (be.root / f"host_{r:03d}" / f"replica_{name}").unlink()
    rep = replication.repair(be)
    assert rep.unrecoverable == [name]


def test_repair_without_replication_cannot_invent_data(tmp_path):
    """replicate=False: a lost host's blobs have no peer copy — repair
    reports every one unrecoverable instead of pretending."""
    be = ShardedBackend(str(tmp_path), n_hosts=3, replicate=False)
    mgr = CheckpointManager(be, async_save=False)
    mgr.save(1, _mk_upper(4), OpLog())
    lost_names = _blob_census(be)[1]
    assert lost_names
    shutil.rmtree(be.root / "host_001")
    rep = replication.repair(be, host=1)
    assert set(rep.unrecoverable) == lost_names
    assert rep.restored == 0


# --- loud commit -------------------------------------------------------------

def test_commit_refuses_manifest_with_lost_writes(tmp_path):
    """The regression the docstring promised: if a host's writes were
    lost between blob write and manifest commit, the coordinator must
    refuse the commit — the store keeps its previous 'latest', never a
    checkpoint it cannot serve."""
    be = ShardedBackend(str(tmp_path), n_hosts=4, replicate=False)
    mgr = CheckpointManager(be, async_save=False)
    mgr.save(1, _mk_upper(5), OpLog())

    m = be.get_manifest(1)
    # simulate losing one referenced blob's host directory wholesale
    from repro.core.delta import referenced_hashes
    name = sorted(referenced_hashes(m))[0]
    shutil.rmtree(be.root / f"host_{_host_of(name, be.n_hosts):03d}")
    with pytest.raises(RuntimeError, match="unservable"):
        be.commit_manifest(2, m)
    assert be.list_steps() == [1]        # nothing partial published


def test_put_blob_to_down_host_raises(tmp_path):
    """A down host's writer cannot 'succeed': the write is lost and the
    pipeline must hear about it before the manifest publishes."""
    be = ShardedBackend(str(tmp_path), n_hosts=2, replicate=False)
    name = "aaaa"                        # find a name owned by host 1
    while _host_of(name, 2) != 1:
        name += "a"
    be.fail_host(1)
    with pytest.raises(IOError, match="host 1 down"):
        be.put_blob(name, b"payload")
    be.heal_host(1)
    be.put_blob(name, b"payload")        # healed writer lands it
    assert be.get_blob(name) == b"payload"


def test_streaming_restore_with_dead_peer_matches_eager(tmp_path):
    """Streaming restore under degradation: one dead host per shard ring
    (replicate=True, so every blob keeps a surviving copy) must restore
    bit-identically to the eager restore of the healthy store — the
    fetcher routes around the dead peer via the surviving placements,
    it does not relax correctness."""
    be = ShardedBackend(str(tmp_path), n_hosts=3, replicate=True)
    mgr = CheckpointManager(be, async_save=False)
    up = _mk_upper(7)
    rng = np.random.RandomState(77)
    up.register("opt_state", "opt_state",        # a cold-tier entry too
                {"m": rng.randn(4096).astype(np.float32)})
    mgr.save(1, up, OpLog())

    eager = mgr.restore(1)                       # healthy reference
    be.fail_host(1)
    streamed = mgr.restore(1, streaming=True)
    for name, by_path in eager.entries.items():
        got = streamed.entries[name]
        for path, want in by_path.items():
            np.testing.assert_array_equal(np.asarray(got[path]),
                                          np.asarray(want))
    t = streamed.streamer.timings()
    served = t["fetch_bytes_per_source"]
    assert sum(served.values()) > 0
    assert "host_001" not in served, \
        f"dead host served bytes: {served}"


def test_scan_cli_json_contract(tmp_path, capsys):
    """``python -m repro.core.replication STORE --json``: the emitted
    JSON carries every report field plus the derived verdict, and the
    exit code is the health bit (0 healthy, 1 degraded) so the CLI
    works as an operator probe."""
    import json

    spec = f"sharded:{tmp_path}?hosts=3&replicate=1"
    be = ShardedBackend(str(tmp_path), n_hosts=3, replicate=True)
    mgr = CheckpointManager(be, async_save=False)
    mgr.save(1, _mk_upper(8), OpLog())

    rc = replication.main([spec, "--json"])
    rep = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert set(rep) == {"hosts", "blobs", "missing_primaries",
                        "missing_replicas", "restored", "unrecoverable",
                        "degraded"}
    assert rep["degraded"] is False and rep["blobs"] > 0

    census = _blob_census(be)
    h, name = next((h, n) for h, names in census.items()
                   for n in names if not n.startswith("replica_"))
    (be.root / f"host_{h:03d}" / name).unlink()
    rc = replication.main([spec, "--json"])
    rep = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert rep["degraded"] is True and rep["missing_primaries"] == 1

    rc = replication.main([spec, "--repair", "--json"])
    rep = json.loads(capsys.readouterr().out)
    assert rc == 0 and rep["restored"] == 1


def test_scan_cli_rejects_non_sharded_store(tmp_path, capsys):
    """A localfs store has no replicas to scan — the CLI says so on
    stderr and exits 2 (usage), instead of reporting fake health."""
    rc = replication.main([f"localfs:{tmp_path}", "--json"])
    err = capsys.readouterr().err
    assert rc == 2 and "sharded" in err


def test_save_through_manager_fails_loudly_on_down_host(tmp_path):
    """End-to-end: a snapshot through the async pipeline with a down
    (unreplicated) host raises at save time and publishes nothing."""
    be = ShardedBackend(str(tmp_path), n_hosts=2, replicate=False)
    mgr = CheckpointManager(be, async_save=False)
    be.fail_host(1)
    with pytest.raises(IOError, match="down"):
        mgr.save(1, _mk_upper(6), OpLog())
    assert be.list_steps() == []
