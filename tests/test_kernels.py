"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp
ref.py oracle, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

RNG = jax.random.PRNGKey(7)


# --- flash attention ----------------------------------------------------------

@pytest.mark.parametrize("B,H,Hkv,Sq,Skv,hd", [
    (2, 4, 2, 128, 128, 64),
    (1, 8, 1, 256, 256, 32),     # MQA
    (2, 4, 4, 96, 96, 64),       # MHA, ragged seq (pad path)
    (1, 6, 2, 64, 320, 128),     # cross-length
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, Hkv, Sq, Skv, hd, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    q = jax.random.normal(RNG, (B, H, Sq, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(RNG, 1), (B, Hkv, Skv, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(RNG, 2), (B, Hkv, Skv, hd), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_window(window):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    q = jax.random.normal(RNG, (1, 4, 128, 64), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(RNG, 1), (1, 2, 128, 64))
    v = jax.random.normal(jax.random.fold_in(RNG, 2), (1, 2, 128, 64))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=32, block_k=32, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# --- ssd scan -----------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,hd,N,chunk", [
    (2, 256, 3, 32, 16, 64),
    (1, 128, 2, 64, 128, 128),   # full-size state dims
    (2, 100, 2, 32, 16, 64),     # pad path
])
def test_ssd_scan_sweep(B, S, H, hd, N, chunk):
    from repro.kernels.ssd_scan.ops import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_sequential
    x = jax.random.normal(RNG, (B, S, H, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(RNG, 1),
                                           (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(RNG, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(RNG, 3), (B, S, N))
    Cm = jax.random.normal(jax.random.fold_in(RNG, 4), (B, S, N))
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ys = ssd_sequential(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ys),
                               rtol=2e-3, atol=2e-3)


def test_ssd_kernel_matches_model_path():
    """Kernel and the model's own chunked implementation agree (the
    model path is what the dry-run lowers; the kernel is the TPU twin)."""
    from repro.kernels.ssd_scan.ops import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_ref
    B, S, H, hd, N = 2, 192, 4, 16, 32
    x = jax.random.normal(RNG, (B, S, H, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(RNG, 5),
                                           (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(RNG, 6), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(RNG, 7), (B, S, N))
    Cm = jax.random.normal(jax.random.fold_in(RNG, 8), (B, S, N))
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=64, interpret=True)
    yr = ssd_ref(x, dt, A, Bm, Cm, chunk=64)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)


# --- rg-lru -------------------------------------------------------------------

@pytest.mark.parametrize("B,S,W,chunk,bw", [
    (2, 128, 96, 32, 32),
    (1, 64, 256, 64, 128),
    (2, 100, 48, 32, 48),        # pad path
])
def test_rg_lru_sweep(B, S, W, chunk, bw):
    from repro.kernels.rg_lru.ops import rg_lru_scan
    from repro.kernels.rg_lru.ref import rg_lru_ref
    x = jax.random.normal(RNG, (B, S, W), jnp.float32)
    r = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(RNG, 1),
                                         (B, S, W)))
    i = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(RNG, 2),
                                         (B, S, W)))
    lam = jax.random.normal(jax.random.fold_in(RNG, 3), (W,))
    h = rg_lru_scan(x, r, i, lam, chunk=chunk, block_w=bw, interpret=True)
    hr = rg_lru_ref(x, r, i, lam)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)


# --- ckpt codec ----------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1000, 333), (7,), (512, 256), (100000,)])
def test_codec_quantize_matches_ref(shape):
    from repro.kernels.ckpt_codec.ops import quantize, dequantize
    from repro.kernels.ckpt_codec.ref import quantize_jnp, dequantize_jnp
    x = jax.random.normal(RNG, shape, jnp.float32) * 3.0
    q, s = quantize(x, interpret=True)
    qr, sr = quantize_jnp(x)
    assert bool(jnp.all(q == qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    xd = dequantize(q, s, interpret=True)
    xr = dequantize_jnp(qr, sr)
    np.testing.assert_allclose(np.asarray(xd), np.asarray(xr), rtol=1e-6)


@pytest.mark.parametrize("n,chunk_bytes", [
    (4096 * 3, 4096),        # exact chunk multiple
    (4096 * 3 + 123, 4096),  # padded tail chunk
    (257, 1024),             # single partial chunk
])
def test_codec_fingerprint_kernel_matches_ref(n, chunk_bytes):
    """Pallas fingerprint kernel (interpret) vs the numpy multiply-mix
    oracle, and single-byte sensitivity: flipping one byte flips exactly
    that chunk's fingerprint."""
    from repro.kernels.ckpt_codec.ops import chunk_fingerprints
    from repro.kernels.ckpt_codec.ref import fingerprint_ref
    rng = np.random.RandomState(0)
    x = rng.randn(n).astype(np.float32)
    fk = np.asarray(chunk_fingerprints(x, chunk_bytes,
                                       interpret=True)).view(np.uint32)
    fr = fingerprint_ref(x, chunk_bytes)
    np.testing.assert_array_equal(fk, fr)

    y = x.copy()
    pos = (n // 2) * 4 + 1
    y.view(np.uint8)[pos] ^= 0x40
    fy = np.asarray(chunk_fingerprints(y, chunk_bytes,
                                       interpret=True)).view(np.uint32)
    changed = np.any(fy != fk, axis=1)
    assert changed.sum() == 1 and changed[pos // chunk_bytes]


def test_fingerprint_host_sensitivity():
    """The fast host fingerprint (segment sums) catches any single-word
    change and agrees with itself across chunk-aligned splits (the
    threaded capture path fingerprints ranges independently)."""
    from repro.kernels.ckpt_codec.ref import fingerprint_host
    rng = np.random.RandomState(1)
    buf = rng.randint(0, 256, size=3 * 4096 + 100, dtype=np.uint8)
    fp = fingerprint_host(buf, 4096, seg_bytes=1024)
    for pos in (0, 5000, buf.size - 1):
        b2 = buf.copy()
        b2[pos] ^= 1
        fp2 = fingerprint_host(b2, 4096, seg_bytes=1024)
        changed = np.any(fp2 != fp, axis=1)
        assert changed.sum() == 1 and changed[pos // 4096]
    split = 2 * 4096  # chunk-aligned: per-range fingerprints must agree
    joined = np.vstack([fingerprint_host(buf[:split], 4096, seg_bytes=1024),
                        fingerprint_host(buf[split:], 4096, seg_bytes=1024)])
    np.testing.assert_array_equal(joined, fp)


def test_codec_error_bound():
    """Blockwise int8: per-element error <= scale/2 <= max|block|/254."""
    from repro.kernels.ckpt_codec.ref import quantize_ref, dequantize_ref
    x = np.random.RandomState(0).randn(4096).astype(np.float32)
    q, s = quantize_ref(x)
    xd = dequantize_ref(q, s)[:x.size]
    bound = np.repeat(s, 256)[:x.size] * 0.5 + 1e-7
    assert np.all(np.abs(xd - x) <= bound)


@pytest.mark.parametrize("B,H,Hkv,S,hd,causal,win", [
    (1, 4, 2, 128, 32, True, 0),    # GQA
    (2, 2, 1, 96, 64, True, 0),     # MQA, pad path
    (1, 4, 4, 64, 32, True, 16),    # windowed
    (1, 2, 2, 64, 32, False, 0),    # bidirectional
])
def test_flash_attention_backward(B, H, Hkv, S, hd, causal, win):
    """custom_vjp over the Pallas fwd/bwd kernels vs jax.grad of the
    naive oracle."""
    from repro.kernels.flash_attention.ops import flash_attention_diff
    from repro.kernels.flash_attention.ref import attention_ref
    q = jax.random.normal(RNG, (B, H, S, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(RNG, 1), (B, Hkv, S, hd))
    v = jax.random.normal(jax.random.fold_in(RNG, 2), (B, Hkv, S, hd))

    def loss_k(q, k, v):
        return jnp.sum(jnp.sin(flash_attention_diff(
            q, k, v, causal, win, 32, 32, True)))

    def loss_r(q, k, v):
        return jnp.sum(jnp.sin(attention_ref(q, k, v, causal=causal,
                                             window=win)))

    gk = jax.grad(loss_k, (0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, (0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)
