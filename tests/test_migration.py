"""Live migration: session moves between engines through the C/R move
channel — token identity, zero loss, source liveness, planned moves.

The claims under test, end to end:
  * a session moved mid-generation continues token-identically on the
    target (including across an N-slot -> M-slot re-slot), with zero
    dropped or duplicated responses under traffic;
  * the source keeps serving its unaffected slots while a move runs;
  * a move racing the source's periodic snapshot leaves the source's
    delta chain intact (the move channel is a separate store);
  * requests that arrive for a draining engine are held and replayed
    on the target, exactly once;
  * ``ClusterSupervisor.planned_move`` keeps the logical coordinate's
    vid stable across the rebind and returns the drained host to the
    spare pool.
"""
import numpy as np
import pytest

import jax

from repro.api import (CheckpointSession, FleetRouter, MigrationError,
                       Policy, PolicyError, UpperHalf,
                       register_app_kind)
from repro.configs import get_smoke_config
from repro.core.migration import SessionBundle, migrate_sessions
from repro.core.supervisor import ClusterSupervisor, SupervisorError
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.serving.traffic import TrafficGenerator

ARCH = "phi4-mini-3.8b"


@pytest.fixture(scope="module")
def small_model():
    cfg = get_smoke_config(ARCH)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mesh11():
    return jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _engine(small_model, n_slots, max_seq=32, **kw):
    cfg, params = small_model
    return ServingEngine(cfg, params, _mesh11(), n_slots=n_slots,
                         max_seq=max_seq, **kw)


def _prompts(cfg, n, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, cfg.vocab_size,
                        size=int(rng.randint(3, 8))).astype(np.int32)
            for _ in range(n)]


@pytest.fixture(scope="module")
def reference_outs(small_model):
    """Uninterrupted run of the shared prompt set on one engine — the
    oracle every migrated run must match token-for-token."""
    cfg, _ = small_model
    eng = _engine(small_model, 3)
    reqs = [Request(rid=i + 1, prompt=p, max_new=6)
            for i, p in enumerate(_prompts(cfg, 4))]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_steps=200)
    return {r.rid: list(r.out) for r in reqs}


# --- the bundle: migration's unit of state ------------------------------

def test_session_bundle_roundtrips_requests(tmp_path):
    reqs = [Request(rid=7, prompt=np.array([3, 5, 8], np.int32),
                    max_new=9, out=[2, 4]),
            Request(rid=9, prompt=np.array([1], np.int32), max_new=3)]
    with CheckpointSession(f"localfs:{tmp_path}/chan",
                           Policy(chain=1, async_save=False)) as chan:
        chan.attach(SessionBundle(reqs, source_step=42))
        chan.snapshot(block=True)
        back = chan.restore("latest", expect_kind="serving-move")
    assert back.source_step == 42
    assert [(r.rid, r.max_new, list(r.prompt), r.out) for r in back.requests] \
        == [(r.rid, r.max_new, list(r.prompt), r.out) for r in reqs]


# --- token identity through a move --------------------------------------

def test_midgeneration_move_is_token_identical(small_model, reference_outs,
                                               tmp_path):
    """3-slot source -> 2-slot target (re-slot), moved mid-generation:
    every response matches the uninterrupted reference, nothing drops,
    nothing duplicates, and the router's ownership follows the move."""
    cfg, _ = small_model
    router = FleetRouter({"a": _engine(small_model, 3),
                          "b": _engine(small_model, 2)},
                         via=f"localfs:{tmp_path}")
    rids = [router.submit(p, 6, engine="a") for p in _prompts(cfg, 4)]
    for _ in range(3):
        router.step()         # mid-generation: partial outputs exist
    assert any(router.inflight[r].out for r in rids)

    res = router.migrate("a", "b", include_queue=True)
    assert sorted(res.moved) == sorted(rids)
    assert res.batches and res.blackout_s > 0
    assert all(router.owner[r] == "b" for r in rids)
    assert not router.engines["a"].live_requests()

    for _ in range(100):
        if not router.inflight:
            break
        router.step()
    assert router.dropped() == []
    assert router.duplicates == 0
    got = {rid: list(router.completed[rid].out) for rid in rids}
    assert got == reference_outs


def test_migrate_batched_bounds_the_freeze(small_model, reference_outs,
                                           tmp_path):
    """batch=1 moves one session per round — per-batch blackouts are
    recorded separately and the outcome is still token-identical."""
    cfg, _ = small_model
    router = FleetRouter({"a": _engine(small_model, 3),
                          "b": _engine(small_model, 3)},
                         via=f"localfs:{tmp_path}", migrate_batch=1)
    rids = [router.submit(p, 6, engine="a") for p in _prompts(cfg, 4)]
    for _ in range(2):
        router.step()
    res = router.migrate("a", "b", include_queue=True)
    assert len(res.batches) == 3    # 3 occupied slots, one per batch
    for _ in range(100):
        if not router.inflight:
            break
        router.step()
    assert router.dropped() == [] and router.duplicates == 0
    assert {r: list(router.completed[r].out) for r in rids} \
        == reference_outs


# --- source liveness -----------------------------------------------------

def test_source_serves_unaffected_slots_during_move(small_model):
    """Extracting one slot never stops the other slots' decode."""
    cfg, _ = small_model
    eng = _engine(small_model, 2)
    r0 = Request(rid=1, prompt=np.array([3, 5, 7], np.int32), max_new=20)
    r1 = Request(rid=2, prompt=np.array([2, 4, 6], np.int32), max_new=20)
    eng.submit(r0)
    eng.submit(r1)
    for _ in range(2):
        eng.step()
    s1 = eng.slot_req.index(r1)
    frozen = eng.extract_sessions([1 - s1])
    assert frozen == [r0]
    assert eng.slot_req[1 - s1] is None
    assert eng.slot_pos[1 - s1] == 0 and eng.slot_tok[1 - s1, 0] == 0

    before = len(r1.out)
    moved_out = list(r0.out)
    eng.step()
    assert len(r1.out) == before + 1     # the survivor kept decoding
    assert r0.out == moved_out           # the frozen session did not


def test_move_and_periodic_snapshot_share_the_engine(small_model,
                                                     tmp_path):
    """A move racing the source's periodic snapshot chain: the move
    channel is a separate store, so the chain stays restorable and the
    moved sessions are simply absent from the next snapshot."""
    cfg, params = small_model
    sess = CheckpointSession(f"localfs:{tmp_path}/src",
                             Policy(interval=2, chain=3))
    src = ServingEngine.create(f"{ARCH}-smoke", params, (1, 1),
                               n_slots=2, max_seq=32,
                               manager=sess.manager)
    sess.attach(src)
    dst = _engine(small_model, 2)
    reqs = [Request(rid=i + 1, prompt=p, max_new=8)
            for i, p in enumerate(_prompts(cfg, 3, seed=1))]
    for r in reqs:
        src.submit(r)
    for _ in range(3):
        src.step()
        sess.maybe_snapshot()
    sess.snapshot()                # async capture in flight...
    res = sess.migrate(dst, slots=[0])   # ...races the move
    assert len(res.moved) == 1
    moved_rid = res.moved[0]
    sess.wait()

    # both sides drain; every request finishes exactly once
    for _ in range(100):
        if not (src.live_requests() or dst.live_requests()):
            break
        src.step()
        dst.step()
        sess.maybe_snapshot()
    assert all(r.done for r in reqs if r.rid != moved_rid)
    assert all(r.done for r in res.requests)

    # the source's chain survived the race: it restores, without the
    # moved session (it left before the next snapshot)
    sess.wait()
    eng2 = sess.restore("latest", expect_kind="serving", params=params,
                        n_slots=2)
    assert moved_rid not in [r.rid for r in eng2.live_requests()]


# --- routing: held requests, accounting, validation ----------------------

def test_requests_for_draining_engine_replay_on_target(small_model,
                                                       tmp_path):
    cfg, _ = small_model
    router = FleetRouter({"a": _engine(small_model, 2),
                          "b": _engine(small_model, 2)},
                         via=f"localfs:{tmp_path}")
    rid0 = router.submit(np.array([3, 5, 7], np.int32), 4, engine="a")
    router.step()
    router.drain("a", "b")
    assert "a" in router.draining
    # pinned to the draining engine -> held, not lost, not served there
    rid1 = router.submit(np.array([2, 4], np.int32), 3, engine="a")
    assert router.stats()["held"] == 1
    # unpinned traffic routes around the draining engine
    rid2 = router.submit(np.array([9, 9], np.int32), 3)
    assert router.owner[rid2] == "b"

    res = router.migrate("a", "b")       # cutover: held requests flush
    assert res.replayed == 1
    assert router.owner[rid1] == "b"
    for _ in range(100):
        if not router.inflight:
            break
        router.step()
    assert router.dropped() == [] and router.duplicates == 0
    assert {rid0, rid1, rid2} <= set(router.completed)


def test_poisson_traffic_is_deterministic_and_bounded(small_model,
                                                      tmp_path):
    cfg, _ = small_model
    a, b = (TrafficGenerator(2.0, seed=5, vocab=cfg.vocab_size, limit=9)
            for _ in range(2))

    class _Sink:
        def __init__(self):
            self.calls = []

        def submit(self, prompt, max_new):
            self.calls.append((list(prompt), max_new))
            return len(self.calls)

    sa, sb = _Sink(), _Sink()
    while not a.drained():
        a.tick(sa)
        b.tick(sb)
    assert sa.calls == sb.calls          # same seed, same traffic
    assert len(sa.calls) == 9            # the limit is a hard cap


def test_move_deadline_is_reported_not_silent(small_model, tmp_path):
    src = _engine(small_model, 1)
    dst = _engine(small_model, 1)
    src.submit(Request(rid=1, prompt=np.array([3, 5], np.int32),
                       max_new=6))
    src.step()
    res = migrate_sessions(src, dst, via=f"localfs:{tmp_path}",
                           deadline_s=1e-9)
    assert not res.within_deadline
    assert res.deadline_s == 1e-9


def test_policy_migration_knob_validation():
    with pytest.raises(PolicyError, match="drain_deadline_s"):
        Policy(drain_deadline_s=0)
    with pytest.raises(PolicyError, match="migrate_batch"):
        Policy(migrate_batch=0)
    p = Policy(drain_deadline_s=0.5, migrate_batch=2)
    assert (p.drain_deadline_s, p.migrate_batch) == (0.5, 2)


def test_migration_error_paths(small_model, tmp_path):
    sess = CheckpointSession(f"localfs:{tmp_path}/s")
    with pytest.raises(PolicyError, match="no app attached"):
        sess.migrate(_engine(small_model, 1))
    eng = _engine(small_model, 1)
    with pytest.raises(MigrationError, match="extract_sessions"):
        migrate_sessions(object(), eng, via=f"localfs:{tmp_path}")
    with pytest.raises(MigrationError, match="unknown engine"):
        FleetRouter({"a": eng}, via=f"localfs:{tmp_path}") \
            .migrate("a", "nope")
    with pytest.raises(MigrationError, match="itself"):
        FleetRouter({"a": eng}, via=f"localfs:{tmp_path}") \
            .migrate("a", "a")
    with pytest.raises(MigrationError, match="at least one engine"):
        FleetRouter({}, via=f"localfs:{tmp_path}")


# --- supervisor: planned moves -------------------------------------------

def test_planned_move_keeps_vid_stable_and_recycles_the_host():
    sup = ClusterSupervisor([0, 1, 2], spares=[7])
    logical = sup.hostmap.logical_of(1)
    vid = sup.hostmap.vid_of(1) if hasattr(sup.hostmap, "vid_of") else None
    target = sup.planned_move(1)
    assert sup.world == [0, 7, 2]
    assert sup.hostmap.logical_of(7) == logical
    assert sup.hostmap.logical_of(1) is None
    if vid is not None:
        assert sup.hostmap.vid_of(7) == vid     # the rebind IS the vid
    assert sup.policy.spares == [1]             # drained, not dead
    assert sorted(sup.monitor.hosts) == [0, 2, 7]
    assert target.mapping == {1: 7}
    inc = sup.incidents[-1]
    assert inc.action == "planned_move" and inc.dead == []


def test_planned_move_rejects_bad_worlds():
    sup = ClusterSupervisor([0, 1], spares=[5])
    with pytest.raises(SupervisorError, match="not part of this job"):
        sup.planned_move(9)
    with pytest.raises(SupervisorError, match="already serves"):
        sup.planned_move(0, to=1)


class _Counter:
    """Minimal CheckpointableApp for the planned-drain (shrink) path."""
    kind = "migration-test-counter"

    def __init__(self, step=0):
        self.step = step

    def checkpoint_state(self):
        up = UpperHalf()
        up.register("step", "step", np.int64(self.step))
        return up

    def checkpoint_step(self):
        return self.step

    def job_meta(self):
        return {"kind": self.kind}

    def bind(self, restore):
        self.step = int(restore.scalar("step"))
        restore.release()


@register_app_kind(_Counter.kind)
def _restore_counter(restore):
    app = _Counter()
    app.bind(restore)
    return app


def test_planned_drain_without_spare_shrinks_on_purpose(tmp_path):
    sess = CheckpointSession(f"localfs:{tmp_path}/job",
                             Policy(async_save=False))
    app = sess.attach(_Counter(step=3))
    sess.snapshot(block=True)
    sup = sess.supervise([0, 1], spares=[], heartbeat_timeout=3.0)
    target = sup.planned_move(1)        # no spare: the world shrinks
    assert sup.world == [0]
    assert target.hosts == [0] and target.step == 3
    assert sup.runner is not app        # rebuilt through the binder
    assert sup.runner.step == 3
    assert sup.incidents[-1].action == "planned_drain"


def test_planned_drain_refuses_to_empty_the_world():
    sup = ClusterSupervisor([0])
    with pytest.raises(SupervisorError, match="empty the world"):
        sup.planned_move(0)


# --- launchers -----------------------------------------------------------

def test_serve_launcher_migrate_to(tmp_path, capsys):
    from repro.launch import serve
    rc = serve.main(["--arch", f"{ARCH}-smoke", "--requests", "3",
                     "--max-new", "4", "--max-seq", "32", "--slots", "2",
                     "--store", f"localfs:{tmp_path}/svc",
                     "--migrate-to", "3@2"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "migrated" in out and "3-slot engine" in out
    # every request finished with its full budget after the move
    assert out.count("rid=") == 3


def test_serve_launcher_migrate_to_needs_store(capsys):
    from repro.launch import serve
    rc = serve.main(["--arch", f"{ARCH}-smoke", "--migrate-to", "2@1"])
    assert rc == 2
    assert "--migrate-to needs --store" in capsys.readouterr().err


def test_drain_flag_validation(capsys):
    from repro.launch import serve
    rc = serve.main(["--arch", f"{ARCH}-smoke", "--drain", "0@3"])
    assert rc == 2      # --drain without --supervise
    rc = serve.main(["--arch", f"{ARCH}-smoke", "--supervise",
                     "--store", "localfs:/tmp/x", "--drain", "9@3"])
    assert rc == 2      # out-of-world host
    assert "not in the simulated world" in capsys.readouterr().err


def test_fleet_launcher_end_to_end(tmp_path, capsys):
    from repro.launch import fleet
    rc = fleet.main(["--arch", f"{ARCH}-smoke", "--engines", "2",
                     "--slots", "2", "--max-seq", "32", "--rate", "1.5",
                     "--requests", "5", "--seed", "3",
                     "--store", f"localfs:{tmp_path}/fleet",
                     "--migrate", "e0:e1@3"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "migrate e0 -> e1" in out
    assert "0 dropped, 0 duplicated" in out
