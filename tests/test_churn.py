"""The churn subsystem: traces record/replay bit-for-bit, the seeded
generators are deterministic, and the ChurnEngine drives a real
supervised Trainer through deaths / grace-window preemptions / returns
with the invariant the whole design hangs on: however the topology
churns (preempt-drain, timeout-shrink, grow back), the continuation is
bit-identical to the unchurned oracle — and the goodput accounting
says exactly what the churn cost."""
import json

import pytest

from repro.api import CheckpointSession, Policy
from repro.core import FailureAction
from repro.core.churn import (ChurnEngine, ChurnEvent, ChurnTrace,
                              IncidentLog, parse_churn_spec,
                              read_incident_log)
from repro.train.loop import Trainer, TrainJob

JOB = TrainJob(arch="starcoder2-3b-matrix", shape_key="train_s8_b2")
STEPS = 14


@pytest.fixture(scope="module")
def oracle():
    t = Trainer(JOB, (1, 1), ("data", "model"))
    t.init_state()
    for _ in range(STEPS):
        t.train_steps(1)
    return t.params_digest()


# --- the trace: record/replay + generators -----------------------------------

def test_trace_jsonl_roundtrip(tmp_path):
    trace = ChurnTrace([
        ChurnEvent(t=3, kind="preempt", host=1, grace_s=2.5),
        ChurnEvent(t=1, kind="die", host=0),
        ChurnEvent(t=9, kind="return", host=0),
        ChurnEvent(t=5, kind="drain", host=2),
    ])
    # construction sorts by time, stably
    assert [e.t for e in trace] == [1, 3, 5, 9]
    path = tmp_path / "trace.jsonl"
    trace.save(path)
    back = ChurnTrace.load(path)
    assert back.to_jsonl() == trace.to_jsonl()
    # grace survives the roundtrip; non-preempts don't carry it
    lines = [json.loads(l) for l in trace.to_jsonl().splitlines()]
    assert lines[1]["grace_s"] == 2.5
    assert "grace_s" not in lines[0]


def test_trace_rejects_bad_input():
    with pytest.raises(ValueError, match="unknown churn event kind"):
        ChurnEvent(t=0, kind="explode", host=0)
    with pytest.raises(ValueError, match="not JSON"):
        ChurnTrace.from_jsonl('{"t": 0, "kind": "die", "host": 0}\nwat\n')
    with pytest.raises(ValueError, match="bad churn event"):
        ChurnTrace.from_jsonl('{"t": 0, "kind": "die"}\n')


def test_poisson_generator_is_deterministic_and_sane():
    kw = dict(rate=0.4, seed=11, horizon=50, preempt=0.5, grace=3.0,
              return_after=6.0)
    a = ChurnTrace.poisson([0, 1, 2, 3], **kw)
    b = ChurnTrace.poisson([0, 1, 2, 3], **kw)
    assert a.to_jsonl() == b.to_jsonl()
    assert len(a) > 0
    assert all(e.t < 50 for e in a)
    assert all(e.host in (0, 1, 2, 3) for e in a)
    # a host only becomes a victim again after its return
    gone = set()
    for e in a:
        if e.kind in ("die", "preempt"):
            assert e.host not in gone, (e, "victim while absent")
            gone.add(e.host)
        elif e.kind == "return":
            gone.discard(e.host)
    # different seed, different trace
    c = ChurnTrace.poisson([0, 1, 2, 3], **{**kw, "seed": 12})
    assert c.to_jsonl() != a.to_jsonl()


def test_poisson_max_events_caps_the_trace():
    t = ChurnTrace.poisson([0, 1, 2, 3], rate=2.0, seed=1,
                           horizon=10_000, max_events=50)
    assert len(t) == 50


def test_correlated_racks_die_together():
    t = ChurnTrace.correlated_racks([0, 1, 2, 3], rate=0.2, rack_size=2,
                                    seed=5, horizon=40)
    deaths = [e for e in t if e.kind == "die"]
    assert deaths
    by_t = {}
    for e in deaths:
        by_t.setdefault(e.t, set()).add(e.host)
    # every incident takes a whole (present) rack at one instant
    for t_, hosts in by_t.items():
        assert hosts in ({0, 1}, {2, 3}), (t_, hosts)


def test_racks_spec_parses():
    kind, params = parse_churn_spec("racks:rate=0.1,size=2,seed=4")
    assert kind == "racks"
    assert params == {"rate": 0.1, "rack_size": 2, "seed": 4}


# --- the engine against a real supervised trainer ----------------------------

def _supervised(tmp_path, trace, *, hosts, spares=(), steps=STEPS,
                sink=None, min_grace=1.0):
    sess = CheckpointSession(f"localfs:{tmp_path}",
                             Policy(interval=4, async_save=False))
    tr = sess.attach(Trainer(JOB, (1, 1), ("data", "model"),
                             manager=sess.manager))
    tr.init_state()
    engine = ChurnEngine(trace, min_grace=min_grace,
                         snapshot=lambda: sess.snapshot(block=True))
    sup = sess.supervise(list(hosts), spares=list(spares),
                         heartbeat_timeout=3.0, clock=engine.clock,
                         n_shards=tr.shape.global_batch, event_sink=sink)
    engine.attach(sup)
    sess.snapshot(block=True)
    step = tr.checkpoint_step()
    while step < steps:
        tr = sup.runner
        tr.train_steps(1)
        step = tr.checkpoint_step()
        sess.maybe_snapshot(final=step == steps)
        if engine.tick(step):
            step = sup.runner.checkpoint_step()
    sess.wait()
    return sess, sup, engine


def test_graceful_preempt_avoids_timeout_and_grow_reuses_return(
        tmp_path, oracle):
    """The acceptance story in one run: a preemption notice with enough
    grace drains proactively (the heartbeat-timeout path never fires
    for it), a death shrinks the world, the returned host re-enters the
    spare pool and a grow puts it back to work — and the continuation
    is bit-identical to the unchurned oracle."""
    trace = ChurnTrace([
        ChurnEvent(t=3, kind="preempt", host=2, grace_s=3.0),
        ChurnEvent(t=6, kind="die", host=1),
        ChurnEvent(t=10, kind="return", host=1),
    ])
    sess, sup, engine = _supervised(tmp_path, trace, hosts=[0, 1, 2])
    rep = engine.report()
    actions = [r["action"] for r in rep.incidents]
    # preempt -> planned_drain (no spare: deliberate shrink), never a
    # timeout death of host 2
    assert rep.proactive_preempts == 1
    assert "planned_drain" in actions
    assert all(2 not in r["dead"] for r in rep.incidents)
    # the death of host 1 WAS a timeout incident…
    assert any(r["dead"] == [1] for r in rep.incidents)
    # …and its return re-admitted it: the grow consumed it
    assert rep.grows >= 1
    assert 1 in sup.world
    assert sup.runner.params_digest() == oracle
    # accounting: every step was eventually retired, rollbacks cost work
    assert rep.useful_steps == STEPS
    assert rep.attempted_steps >= rep.useful_steps
    assert rep.lost_steps == rep.attempted_steps - rep.useful_steps
    assert 0.0 < rep.goodput <= 1.0
    sess.close()


def test_insufficient_grace_degrades_to_timeout_death(tmp_path, oracle):
    """A notice shorter than min_grace is not actionable: the host just
    dies at its deadline and the ordinary detect->decide path handles
    it — counted as a degraded preemption."""
    trace = ChurnTrace([
        ChurnEvent(t=4, kind="preempt", host=1, grace_s=0.25),
    ])
    sess, sup, engine = _supervised(tmp_path, trace, hosts=[0, 1])
    rep = engine.report()
    assert rep.degraded_preempts == 1
    assert rep.proactive_preempts == 0
    assert any(r["dead"] == [1] for r in rep.incidents)
    assert sup.runner.params_digest() == oracle
    sess.close()


def test_seeded_poisson_trace_end_to_end(tmp_path, oracle):
    """A generated Poisson trace (deaths + preemptions + returns) over
    a 3-host world with one spare: whatever the seed throws at the
    fleet, the run finishes bit-identical to the unchurned oracle."""
    trace = ChurnTrace.poisson([0, 1, 2], rate=0.25, seed=7,
                               horizon=STEPS, preempt=0.5, grace=3.0,
                               return_after=5.0)
    assert len(trace) > 0
    sess, sup, engine = _supervised(tmp_path, trace, hosts=[0, 1, 2],
                                    spares=[7])
    assert sup.runner.params_digest() == oracle
    rep = engine.report()
    assert rep.useful_steps == STEPS
    sess.close()


def test_incident_log_matches_event_stream(tmp_path, oracle):
    """--incident-log's sink: replay a trace with the JSONL log
    attached and the file must carry the supervisor's event stream,
    event for event, in order, as valid JSONL."""
    trace = ChurnTrace([
        ChurnEvent(t=3, kind="die", host=1),
        ChurnEvent(t=9, kind="return", host=1),
    ])
    path = tmp_path / "incidents.jsonl"
    sink = IncidentLog(path)
    sess, sup, engine = _supervised(tmp_path / "store", trace,
                                    hosts=[0, 1], sink=sink)
    sink.close()
    logged = read_incident_log(path)
    assert len(logged) == len(sup.events)
    for row, (t, kind, detail) in zip(logged, sup.events):
        assert row["t"] == t
        assert row["event"] == kind
        for k, v in detail.items():
            got = row[k]
            got = tuple(map(tuple, got)) if k == "assignment" else got
            assert got == v or str(v) == got, (kind, k, got, v)
    # the interesting kinds made it to disk
    kinds = [r["event"] for r in logged]
    assert "decision" in kinds and "host_return" in kinds \
        and "restored" in kinds
    assert sup.runner.params_digest() == oracle
    sess.close()


def test_engine_spare_death_and_absent_drain_are_absorbed(tmp_path):
    """Edge events must not wedge the engine: a spare dying just leaves
    the pool (and is never handed a workload), draining an absent host
    is a logged no-op, preempting a spare reclaims it."""
    trace = ChurnTrace([
        ChurnEvent(t=2, kind="die", host=7),       # spare dies
        ChurnEvent(t=3, kind="drain", host=9),     # not in world
        ChurnEvent(t=4, kind="preempt", host=8, grace_s=5.0),  # spare
    ])
    sess, sup, engine = _supervised(tmp_path, trace, hosts=[0, 1],
                                    spares=[7, 8], steps=8)
    assert sup.policy.spares == []
    assert sup.world == [0, 1]
    kinds = [k for _, k, _ in sup.events]
    assert "spare_lost" in kinds
    assert "drain_skipped" in kinds
    assert "spare_preempted" in kinds
    assert engine.report().incidents == []
    sess.close()
