"""The agnosticism claim, enforced by AST scan rather than convention.

Three tiers:
  - matrix cells (driver, enumeration, tests, gate script): may import
    ``repro.api`` and nothing else from ``repro`` — the torture
    sequence itself must be expressible on the public surface;
  - the app side (``families.py``): may additionally import the
    *application* layer it is standing in for (trainer, serving engine,
    configs, models) but NEVER ``repro.core`` — apps built on the
    session API must not need the internals;
  - the shipped examples: public API only, like any third party.
"""
from __future__ import annotations

import ast
import os

import pytest

PKG = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(os.path.dirname(PKG))

API_ONLY = ("repro.api",)
APP_SIDE = {
    "families.py": ("repro.api", "repro.train.loop",
                    "repro.serving.engine", "repro.configs",
                    "repro.models"),
}
EXAMPLES = ("checkpointable_pipeline.py", "rl_actor_learner.py")


def _repro_imports(path):
    with open(path) as f:
        tree = ast.parse(f.read(), filename=path)
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            names |= {a.name for a in node.names
                      if a.name == "repro" or a.name.startswith("repro.")}
        elif isinstance(node, ast.ImportFrom) and node.level == 0 \
                and node.module \
                and (node.module == "repro"
                     or node.module.startswith("repro.")):
            names.add(node.module)
    return sorted(names)


def _allowed(name: str, allowlist) -> bool:
    return any(name == a or name.startswith(a + ".") for a in allowlist)


def _cell_modules():
    return sorted(fn for fn in os.listdir(PKG)
                  if fn.endswith(".py") and fn not in APP_SIDE)


@pytest.mark.parametrize("fn", _cell_modules())
def test_matrix_cells_import_only_the_public_api(fn):
    bad = [n for n in _repro_imports(os.path.join(PKG, fn))
           if not _allowed(n, API_ONLY)]
    assert not bad, (
        f"{fn} imports {bad}: matrix cells may import only repro.api — "
        "if a scenario needs more, that is a hole in the public surface")


@pytest.mark.parametrize("fn", sorted(APP_SIDE))
def test_app_side_stays_out_of_core(fn):
    names = _repro_imports(os.path.join(PKG, fn))
    core = [n for n in names if n == "repro.core"
            or n.startswith("repro.core.")]
    assert not core, (
        f"{fn} imports {core}: the app side must never reach repro.core "
        "— apps on the session API do not need the internals")
    bad = [n for n in names if not _allowed(n, APP_SIDE[fn])]
    assert not bad, (
        f"{fn} imports {bad}, outside its application-layer allowlist "
        f"{sorted(APP_SIDE[fn])}")


@pytest.mark.parametrize("fn", EXAMPLES)
def test_examples_are_api_only(fn):
    path = os.path.join(REPO, "examples", fn)
    bad = [n for n in _repro_imports(path) if not _allowed(n, API_ONLY)]
    assert not bad, (
        f"examples/{fn} imports {bad}: the shipped examples are the "
        "third-party proof and may import only repro.api")
