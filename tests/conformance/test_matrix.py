"""The conformance matrix: every config family × every failure mode,
driven through ``repro.api`` alone.

Each cell asserts bit-identical state/token continuation (digests over
the complete semantic state, or per-request token streams for the
elastic re-slot cells). Cells are independent — any one runs standalone
via ``-k`` — but share per-family reference digests within a process,
so the expensive uninterrupted runs are paid once.
"""
from __future__ import annotations

import json
import os

import pytest

from . import driver, families, matrix


def _store(backend: str, tmp_path, tag: str = "s") -> str:
    if backend == "localfs":
        return f"localfs:{tmp_path}/{tag}"
    return f"sharded:{tmp_path}/{tag}?hosts=3"


def _run(cell: matrix.Cell, tmp_path) -> None:
    if cell.mode == "midchain":
        # chain-shape cell: the driver's own growing app, no family
        driver.run_midchain(_store(cell.backend, tmp_path))
        return
    spec = families.get_spec(cell.family)
    if cell.mode == "swap":
        driver.run_swap(spec, _store("localfs", tmp_path, "a"),
                        _store("sharded", tmp_path, "b"))
    elif cell.mode == "kill":
        driver.run_kill(spec, _store(cell.backend, tmp_path))
    elif cell.mode == "reslot":
        driver.run_reslot(spec, _store(cell.backend, tmp_path))
    elif cell.mode == "shrink":
        driver.run_shrink(spec, _store(cell.backend, tmp_path))
    elif cell.mode == "commit":
        driver.run_commit(spec, _store(cell.backend, tmp_path))
    elif cell.mode == "churn-grow":
        driver.run_churn_grow(spec, _store(cell.backend, tmp_path))
    elif cell.mode == "degraded":
        # a dead peer only has surviving copies to serve when the
        # store replicates — the cell pins the replicated package
        driver.run_degraded(
            spec, f"sharded:{tmp_path}/d?hosts=3&replicate=1")
    else:  # pragma: no cover — the enumeration owns the mode list
        raise AssertionError(f"unknown mode {cell.mode}")


@pytest.mark.parametrize("cell", matrix.fast_cells(), ids=lambda c: c.id)
def test_cell(cell, tmp_path):
    _run(cell, tmp_path)


@pytest.mark.slow
@pytest.mark.parametrize("cell", matrix.slow_cells(), ids=lambda c: c.id)
def test_cell_full(cell, tmp_path):
    _run(cell, tmp_path)


def test_expected_cells_manifest_in_sync():
    """The CI gate's pin and the live enumeration must agree — adding a
    family without regenerating ``expected_cells.json`` fails HERE, not
    silently in the artifact check."""
    path = os.path.join(os.path.dirname(__file__), "expected_cells.json")
    with open(path) as f:
        pinned = json.load(f)
    live = sorted(c.id for c in matrix.fast_cells())
    assert pinned == live, (
        "expected_cells.json is out of sync with matrix.fast_cells(); "
        "regenerate it:\n  PYTHONPATH=src:tests python -c \"import json, "
        "conformance.matrix as m; print(json.dumps(sorted(c.id for c in "
        "m.fast_cells()), indent=2))\" > tests/conformance/expected_cells.json")
