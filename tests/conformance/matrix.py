"""Cell enumeration for the conformance matrix.

A cell is ``family×mode×backend``. The fast subset — every family ×
every failure mode on the localfs package (swap cells cross packages by
definition) — runs in tier-1; the sharded backend axis and the second
MoE family (top-k>1 routing) ride behind the ``slow`` marker.

``expected_cells.json`` pins the fast subset's IDs; ``check_report.py``
fails CI when a previously-green cell goes missing or skipped, and
``test_matrix.test_expected_cells_manifest_in_sync`` keeps the pin from
drifting out from under a family addition.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

FAMILIES = ("attention", "moe", "ssm", "rglru", "encdec", "thirdparty")
SLOW_FAMILIES = ("moe-topk",)        # kimi-k2 class: top-k>1 routing
MODES = ("kill", "reslot", "shrink", "commit", "swap")


@dataclass(frozen=True)
class Cell:
    family: str
    mode: str
    backend: str

    @property
    def id(self) -> str:
        return f"{self.family}×{self.mode}×{self.backend}"


def _backend_for(mode: str, backend: str) -> str:
    return "localfs↔sharded" if mode == "swap" else backend


def fast_cells() -> List[Cell]:
    # one targeted degradation cell rides in the fast tier: the
    # replicated sharded package loses a peer and the streaming restore
    # must route around it. Degradation is a store property, not a
    # family property, so the full family×degraded product would be
    # redundant — one family stands in for all of them.
    # two more targeted cells ride in the fast tier: degradation is a
    # store property (one family stands in for all), and the mid-chain
    # new-entry cell is a chain-shape property — an app whose semantic
    # state grows mid-run, so an entry's first appearance is a non-base
    # delta link that both restore schedules must handle
    # and one churn-grow cell: shrink-then-grow through the supervisor
    # is a *sequencing* property of the restore primitive (elastic
    # re-shard both directions), not a family property — one family
    # stands in for all of them
    return [Cell(f, m, _backend_for(m, "localfs"))
            for f in FAMILIES for m in MODES] \
        + [Cell("attention", "degraded", "sharded"),
           Cell("dynamic-entry", "midchain", "localfs"),
           Cell("attention", "churn-grow", "localfs")]


def slow_cells() -> List[Cell]:
    cells = [Cell(f, m, "sharded")
             for f in FAMILIES for m in MODES if m != "swap"]
    cells += [Cell(f, m, _backend_for(m, "localfs"))
              for f in SLOW_FAMILIES for m in MODES]
    return cells
