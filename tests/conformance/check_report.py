#!/usr/bin/env python3
"""CI gate over the conformance-matrix report (stdlib only).

    python tests/conformance/check_report.py CONFORMANCE_matrix.json \
        [expected_cells.json]

Reads the per-cell JSON the pytest plugin wrote (``--conformance-report``)
and fails when any pinned — previously green — cell is missing from the
run (deleted, deselected, collection error) or did not pass (failed OR
skipped: a skip on a pinned cell is a silent coverage hole, which is
exactly what this gate exists to catch). Failures on unpinned cells
(e.g. the slow axis, when it ran) fail too; unpinned passes are ignored.
"""
import json
import os
import sys


def main(argv):
    if len(argv) < 2 or argv[1] in ("-h", "--help"):
        print(__doc__.strip(), file=sys.stderr)
        return 2
    report_path = argv[1]
    expected_path = argv[2] if len(argv) > 2 else os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "expected_cells.json")
    with open(report_path) as f:
        report = json.load(f)
    with open(expected_path) as f:
        expected = json.load(f)
    cells = report.get("cells", {})

    bad = []
    for cid in expected:
        rec = cells.get(cid)
        if rec is None:
            bad.append((cid, "MISSING — not collected (deleted, "
                             "deselected, or collection error)"))
        elif rec.get("outcome") != "passed":
            bad.append((cid, str(rec.get("outcome")).upper()))
    for cid, rec in sorted(cells.items()):
        if cid not in expected and rec.get("outcome") \
                not in ("passed", "skipped"):
            bad.append((cid, f"{str(rec.get('outcome')).upper()} "
                             "(unpinned cell)"))

    n_pass = sum(1 for r in cells.values() if r.get("outcome") == "passed")
    print(f"conformance matrix: {n_pass}/{len(cells)} cells passed, "
          f"{len(expected)} pinned")
    if bad:
        print("\nGATE FAILED:", file=sys.stderr)
        for cid, why in bad:
            print(f"  {cid}: {why}", file=sys.stderr)
        return 1
    print("all pinned cells green")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
