"""The conformance scenario driver: one torture sequence, any app kind.

Every matrix cell runs through the functions here, and this module
imports ONLY ``repro.api`` (enforced by ``test_import_scan``): if a
failure mode needs anything beyond the public session surface, that is
a hole in the API, not a gap for a test helper to paper over. The app
side of each family (how to build a trainer / serving engine / RL
learner, how to advance it, how to hash its semantic state) arrives as
a ``FamilySpec`` of plain callables from ``families.py``.

Failure modes:

  kill     snapshot cadence → drop the app object → restore latest →
           continue → bit-identical to the uninterrupted run
  reslot   elastic restore onto a different topology (serving slots,
           RL actor pool) with work in flight → identical outputs
  shrink   supervisor detects a silent host, decides SHRINK, restores
           onto the survivors → continuation bit-identical
  commit   a crash *between blob writes and the manifest rename* is
           simulated byte-for-byte; reopen → the torn step is invisible,
           the previous step restores, the store still accepts commits
  swap     the same kill sequence under the other checkpoint package —
           the swap is one spec string; state digests agree across
           packages and with the reference
"""
from __future__ import annotations

import glob
import hashlib
import os
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.api import (CheckpointSession, Policy, UpperHalf,
                       parse_store_spec, register_app_kind)

# --- family contract --------------------------------------------------------


@dataclass(frozen=True)
class TrainDrive:
    """How to run a family's stateful workload (drives kill / shrink /
    commit / swap). ``advance`` must be deterministic given the app's
    state alone; ``digest`` must hash every semantic entry."""
    fresh: Callable[[], Any]
    advance: Callable[[Any, int], None]
    digest: Callable[[Any], str]
    step_of: Callable[[Any], int]
    total: int = 6
    interval: int = 2
    restore_kwargs: Callable[[], Dict[str, Any]] = dict


@dataclass(frozen=True)
class ElasticDrive:
    """How to run the family's elastic re-slot scenario: warm leaves
    work in flight, restore re-slots onto a different topology, and
    ``outcome`` must match the uninterrupted ``reference``."""
    fresh: Callable[[], Any]
    warm: Callable[[CheckpointSession, Any], None]
    outcome: Callable[[Any], Any]
    reference: Callable[[], Any]
    reslot_kwargs: Callable[[], Dict[str, Any]]


@dataclass(frozen=True)
class ShrinkDrive:
    """Supervisor world for the shrink scenario."""
    hosts: Tuple[int, ...] = (0, 1, 2)
    dead: int = 0
    n_shards: Optional[int] = None
    restore_kwargs: Any = None          # dict | callable(target) -> dict
    prepare: Optional[Callable[[Any], None]] = None
    check: Optional[Callable[[Any, Any], None]] = None


@dataclass(frozen=True)
class FamilySpec:
    family: str
    train: TrainDrive
    elastic: ElasticDrive
    shrink: ShrinkDrive


# --- reference / cross-package digest caches --------------------------------

_REF: Dict[str, str] = {}
_KILL: Dict[Tuple[str, str], str] = {}


def reference_digest(spec: FamilySpec) -> str:
    """The uninterrupted run's digest, computed once per family (the
    expensive part of every cell; identical across modes by design)."""
    d = _REF.get(spec.family)
    if d is None:
        app = spec.train.fresh()
        spec.train.advance(app, spec.train.total)
        d = spec.train.digest(app)
        _REF[spec.family] = d
    return d


# --- failure modes ----------------------------------------------------------

def run_kill(spec: FamilySpec, store: str) -> str:
    """snapshot → hard kill → restore → continue → bit-identical."""
    dr = spec.train
    want = reference_digest(spec)
    policy = Policy(interval=dr.interval, chain=3, keep_last=4)
    with CheckpointSession(store, policy) as sess:
        app = sess.attach(dr.fresh())
        half = dr.total // 2
        for _ in range(half):
            dr.advance(app, 1)
            sess.maybe_snapshot()
        sess.wait()
        boundary = (half // dr.interval) * dr.interval
        assert 0 < boundary < half, \
            f"{spec.family}: the kill must lose real progress " \
            f"(boundary {boundary}, died at {half})"
        del app                                   # hard kill
        app2 = sess.restore("latest", **dr.restore_kwargs())
        at = dr.step_of(app2)
        assert at == boundary, \
            f"{spec.family}: restored at step {at}, wanted {boundary}"
        dr.advance(app2, dr.total - at)
        got = dr.digest(app2)

        # same cell, streaming schedule: restore the same step with the
        # pipelined materializer (hot tier eager, cold leaves paged in
        # on first touch) and continue — streaming is a schedule, not a
        # different restore, so the digest must not move
        app3 = sess.restore("latest", streaming=True,
                            **dr.restore_kwargs())
        at3 = dr.step_of(app3)
        assert at3 == boundary, \
            f"{spec.family}: streaming restored at step {at3}, " \
            f"wanted {boundary}"
        dr.advance(app3, dr.total - at3)
        got_streamed = dr.digest(app3)
    assert got == want, \
        f"{spec.family}: post-restore digest {got} != reference {want}"
    assert got_streamed == want, \
        f"{spec.family}: streaming restore digest {got_streamed} != " \
        f"reference {want}"
    _KILL[(spec.family, store.split(":", 1)[0])] = got
    return got


def run_degraded(spec: FamilySpec, store: str) -> str:
    """One dead peer per shard ring: the replicated package loses a
    host wholesale after the checkpoint commits. The streaming restore
    must route its fetches through the surviving copies — same digest
    as the reference run AND as the degraded eager restore (fallback is
    a routing decision, never a correctness relaxation)."""
    dr = spec.train
    want = reference_digest(spec)
    policy = Policy(interval=dr.interval, chain=3, keep_last=4)
    with CheckpointSession(store, policy) as sess:
        app = sess.attach(dr.fresh())
        half = dr.total // 2
        for _ in range(half):
            dr.advance(app, 1)
            sess.maybe_snapshot()
        sess.wait()
        boundary = (half // dr.interval) * dr.interval
        del app                               # hard kill
        sess.backend.fail_host(1)             # ... and a dead peer

        app2 = sess.restore("latest", streaming=True,
                            **dr.restore_kwargs())
        at = dr.step_of(app2)
        assert at == boundary, \
            f"{spec.family}: degraded streaming restored at {at}, " \
            f"wanted {boundary}"
        dr.advance(app2, dr.total - at)
        got = dr.digest(app2)

        app3 = sess.restore("latest", **dr.restore_kwargs())
        dr.advance(app3, dr.total - boundary)
        got_eager = dr.digest(app3)
    assert got == want, \
        f"{spec.family}: degraded streaming digest {got} != " \
        f"reference {want}"
    assert got_eager == got, \
        f"{spec.family}: degraded eager {got_eager} != streaming {got}"
    return got


def run_reslot(spec: FamilySpec, store: str) -> None:
    """Elastic restore onto a different topology with work in flight."""
    el = spec.elastic
    want = el.reference()
    with CheckpointSession(store, Policy(async_save=False)) as sess:
        app = sess.attach(el.fresh())
        el.warm(sess, app)
        sess.snapshot(block=True)
        del app                                   # hard kill mid-flight
        app2 = sess.restore("latest", **el.reslot_kwargs())
        got = el.outcome(app2)
    assert got == want, \
        f"{spec.family}: re-slotted outcome diverged\n got={got}\nwant={want}"


def run_shrink(spec: FamilySpec, store: str) -> None:
    """Detect a silent host, decide SHRINK, restore onto survivors."""
    dr, sh = spec.train, spec.shrink
    want = reference_digest(spec)
    with CheckpointSession(store, Policy(async_save=False)) as sess:
        app = sess.attach(dr.fresh())
        if sh.prepare is not None:
            sh.prepare(app)
        half = dr.total // 2
        dr.advance(app, half)
        sess.snapshot(block=True)

        clock = [0.0]
        sup = sess.supervise(list(sh.hosts), heartbeat_timeout=3.0,
                             clock=lambda: clock[0], n_shards=sh.n_shards,
                             restore_kwargs=sh.restore_kwargs)

        def tick(alive: List[int]) -> None:
            clock[0] += 1.0
            for h in alive:
                sup.beat(h, half)

        tick(list(sh.hosts))
        tick(list(sh.hosts))
        assert sup.poll() is None, "healthy world produced a decision"

        survivors = [h for h in sh.hosts if h != sh.dead]
        target = None
        for _ in range(8):
            tick(survivors)
            target = sup.poll()
            if target is not None:
                break
        assert target is not None, \
            f"{spec.family}: silent host {sh.dead} never detected"
        assert target.action.name == "SHRINK", \
            f"{spec.family}: decided {target.action.name}, wanted SHRINK"
        assert sorted(target.hosts) == sorted(survivors)

        app2 = sess.app
        assert app2 is not app, "shrink must rebuild the runner"
        at = dr.step_of(app2)
        assert at == half, \
            f"{spec.family}: shrink restored at {at}, wanted {half}"
        if sh.check is not None:
            sh.check(app2, target)
        dr.advance(app2, dr.total - at)
        got = dr.digest(app2)
    assert got == want, \
        f"{spec.family}: post-shrink digest {got} != reference {want}"


def run_churn_grow(spec: FamilySpec, store: str) -> None:
    """The churn round trip, cell-sized: a silent host SHRINKs the
    world, real progress lands on the survivors, the host comes back
    and GROW re-admits it — post-grow continuation bit-identical to the
    uninterrupted reference. Shrink and grow are the same restore
    primitive pointed in opposite directions, and this cell pins that
    the direction flip loses nothing."""
    dr, sh = spec.train, spec.shrink
    want = reference_digest(spec)
    with CheckpointSession(store, Policy(async_save=False)) as sess:
        app = sess.attach(dr.fresh())
        if sh.prepare is not None:
            sh.prepare(app)
        half = dr.total // 2
        dr.advance(app, half)
        sess.snapshot(block=True)

        clock = [0.0]
        sup = sess.supervise(list(sh.hosts), heartbeat_timeout=3.0,
                             clock=lambda: clock[0], n_shards=sh.n_shards,
                             restore_kwargs=sh.restore_kwargs)

        def tick(alive: List[int]) -> None:
            clock[0] += 1.0
            for h in alive:
                sup.beat(h, half)

        survivors = [h for h in sh.hosts if h != sh.dead]
        target = None
        for _ in range(8):
            tick(survivors)
            target = sup.poll()
            if target is not None:
                break
        assert target is not None and target.action.name == "SHRINK", \
            f"{spec.family}: wanted SHRINK, got {target}"

        # real progress on the shrunk world, checkpointed — the grow
        # must pick up *newer* state than the shrink restored
        app2 = sess.app
        dr.advance(app2, 1)
        sess.snapshot(block=True)

        gt = sup.grow(sh.dead)                    # the host came back
        assert gt.action.name == "GROW"
        assert sorted(sup.world) == sorted(sh.hosts), \
            f"{spec.family}: grow left world {sup.world}"
        app3 = sess.app
        assert app3 is not app2, "grow must rebuild the runner"
        at = dr.step_of(app3)
        assert at == half + 1, \
            f"{spec.family}: grow restored at {at}, wanted {half + 1}"
        dr.advance(app3, dr.total - at)
        got = dr.digest(app3)
    assert got == want, \
        f"{spec.family}: post-grow digest {got} != reference {want}"


class _GrowingApp:
    """Protocol citizen whose semantic state GROWS mid-run: a cold-tier
    entry first exists at step 3, so inside a delta chain its first
    appearance is a non-base manifest. Stands in for every app that
    allocates state lazily — optimizer moments on the first update, a
    serving engine's per-session tables."""
    kind = "conformance-growing"

    def __init__(self) -> None:
        self.step = 0
        self.base = np.zeros(64, np.float64)
        self.late: Optional[np.ndarray] = None

    def advance(self, n: int) -> None:
        for _ in range(n):
            self.step += 1
            self.base += float(self.step)
            if self.step >= 3:
                z = self.late if self.late is not None \
                    else np.full(32, 7.0)
                self.late = z * 1.25 + self.step

    def digest(self) -> str:
        h = hashlib.sha256(self.base.tobytes())
        if self.late is not None:
            h.update(self.late.tobytes())
        h.update(str(self.step).encode())
        return h.hexdigest()

    def checkpoint_state(self):
        up = UpperHalf()
        up.register("base", "params", {"b": self.base.copy()})
        if self.late is not None:
            up.register("late", "opt_state", {"z": self.late.copy()})
        up.register("step", "step", np.int64(self.step))
        return up

    def checkpoint_step(self) -> int:
        return self.step

    def job_meta(self) -> Dict[str, Any]:
        return {"kind": self.kind}

    def bind(self, restore) -> None:
        self.base = np.asarray(restore.tree("base")["b"],
                               np.float64).copy()
        self.late = (np.asarray(restore.tree("late")["z"],
                                np.float64).copy()
                     if restore.has("late") else None)
        self.step = int(restore.scalar("step"))
        restore.release()


@register_app_kind(_GrowingApp.kind)
def _restore_growing(restore) -> _GrowingApp:
    app = _GrowingApp()
    app.bind(restore)
    return app


def run_midchain(store: str) -> None:
    """An entry first introduced mid-chain — its first appearance is a
    non-base delta link — must checkpoint and restore bit-identically,
    eager AND streaming, through the public API alone."""
    ref = _GrowingApp()
    ref.advance(6)
    want = ref.digest()
    with CheckpointSession(store, Policy(interval=1, chain=8,
                                         keep_last=8)) as sess:
        app = sess.attach(_GrowingApp())
        for _ in range(4):
            app.advance(1)
            sess.maybe_snapshot()
        sess.wait()
        assert app.late is not None, \
            "the late entry must exist before the kill for the cell " \
            "to exercise a mid-chain introduction"
        del app                                   # hard kill
        for streaming in (False, True):
            app2 = sess.restore("latest", streaming=streaming)
            assert app2.step == 4, \
                f"restored at step {app2.step}, wanted 4"
            app2.advance(2)
            got = app2.digest()
            assert got == want, (
                "mid-chain-new-entry: "
                f"{'streaming' if streaming else 'eager'} digest {got} "
                f"!= reference {want}")


def tear_last_commit(store: str) -> int:
    """Recreate the crash-during-commit disk state, byte for byte.

    The protocol writes blobs first, then the manifest via temp-file +
    fsync + rename; a crash between those leaves the manifest as an
    uncommitted temp file. Renaming the newest committed manifest to a
    temp-style name IS that state (the backends' startup sweep ignores
    young temp files). Returns the torn step number."""
    _, path, _ = parse_store_spec(store)
    cands: List[str] = []
    for sub in ("manifests", "coordinator"):    # localfs / sharded layout
        cands += glob.glob(os.path.join(path, sub, "step_*.json"))
    assert cands, f"no committed manifests under {path}"
    latest = max(cands)                  # zero-padded: lexicographic order
    d, name = os.path.split(latest)
    os.rename(latest, os.path.join(d, ".tmp_crash_" + name))
    return int(name[len("step_"):-len(".json")])


def run_commit(spec: FamilySpec, store: str) -> None:
    """Crash during commit → reopen → torn step invisible, previous
    step restores, continuation bit-identical, store still writable."""
    dr = spec.train
    want = reference_digest(spec)
    policy = Policy(chain=2, keep_last=4, async_save=False)
    with CheckpointSession(store, policy) as sess:
        app = sess.attach(dr.fresh())
        dr.advance(app, dr.interval)
        sess.snapshot(block=True)
        survivor = dr.step_of(app)
        dr.advance(app, dr.interval)
        sess.snapshot(block=True)
        assert sess.latest_step() == dr.step_of(app)
        del app

    torn = tear_last_commit(store)

    with CheckpointSession(store, policy) as sess:
        steps = sess.restorable_steps()
        assert torn not in steps and survivor in steps, \
            f"{spec.family}: reopened store sees {steps}; torn step " \
            f"{torn} must be invisible, {survivor} restorable"
        app2 = sess.restore("latest", **dr.restore_kwargs())
        at = dr.step_of(app2)
        assert at == survivor, \
            f"{spec.family}: restored at {at}, wanted {survivor}"
        dr.advance(app2, dr.total - at)
        got = dr.digest(app2)
        assert got == want, \
            f"{spec.family}: post-reopen digest {got} != reference {want}"
        sess.snapshot(block=True)        # the torn file is inert: the
        assert sess.latest_step() == dr.total  # store still commits


def run_swap(spec: FamilySpec, store_a: str, store_b: str) -> None:
    """The full kill sequence under BOTH checkpoint packages — swapping
    is one spec string — with digests identical across packages."""
    da = _KILL.get((spec.family, store_a.split(":", 1)[0]))
    if da is None:
        da = run_kill(spec, store_a)
    db = run_kill(spec, store_b)
    assert da == db == reference_digest(spec), \
        f"{spec.family}: packages disagree ({da} vs {db})"
