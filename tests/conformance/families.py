"""The application side of the conformance matrix: how each config
family builds, advances and hashes its apps.

This module is deliberately the ONLY one in ``tests/conformance`` that
may import beyond ``repro.api`` — and even here the allowlist stops at
the *application* layer (``repro.train.loop``, ``repro.serving.engine``,
``repro.configs``, ``repro.models``): touching ``repro.core`` anywhere
in this package is an import-scan failure, because apps going through
the public session surface must never need the internals.

Every family uses its ``<arch>-matrix`` config (1-layer, d_model=32
class) so a full cell — build, train, snapshot, restore, continue —
is XLA-compile-bound, not step-bound, and the fast subset stays inside
tier-1.
"""
from __future__ import annotations

import functools
import hashlib
import os
import sys
from typing import Any, Dict, List, Tuple

import jax
import numpy as np

from repro.train.loop import Trainer, TrainJob
from repro.serving.engine import Request, ServingEngine
from repro.configs import resolve_config
from repro.models import model as M

from .driver import ElasticDrive, FamilySpec, ShrinkDrive, TrainDrive

_EXAMPLES = os.path.join(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))), "examples")
if _EXAMPLES not in sys.path:
    sys.path.insert(0, _EXAMPLES)
import rl_actor_learner as rl  # noqa: E402  (registers its app kind)

# family -> registry arch (the -matrix suffix resolves the tiny config)
ARCHS: Dict[str, str] = {
    "attention": "starcoder2-3b-matrix",
    "moe": "llama4-scout-17b-a16e-matrix",
    "moe-topk": "kimi-k2-1t-a32b-matrix",
    "ssm": "mamba2-780m-matrix",
    "rglru": "recurrentgemma-9b-matrix",
    "encdec": "whisper-base-matrix",
}
THIRD_PARTY = "thirdparty"
SHAPE_KEY = "train_s8_b2"            # parses as seq=8, global_batch=2
N_SHARDS = 2                          # == global_batch (data-layout law)


# --- trainer side -----------------------------------------------------------

def _fresh_trainer(arch: str) -> Trainer:
    t = Trainer(TrainJob(arch=arch, shape_key=SHAPE_KEY), (1, 1),
                ("data", "model"))
    t.init_state()
    return t


def _advance_trainer(t: Trainer, n: int) -> None:
    t.train_steps(n)


def _digest_trainer(t: Trainer) -> str:
    """Params + optimizer + counters: the complete semantic state, so a
    cell can't pass on params alone while the data cursor drifted."""
    h = hashlib.blake2b(digest_size=16)
    for entry in ("params", "opt_state"):
        leaves = jax.tree_util.tree_flatten_with_path(
            t.upper.get(entry))[0]
        for path, leaf in leaves:
            h.update(jax.tree_util.keystr(path).encode())
            h.update(np.ascontiguousarray(
                np.asarray(jax.device_get(leaf))).tobytes())
    h.update(str(int(t.upper.get("step"))).encode())
    h.update(str(int(t.upper.get("data_cursor"))).encode())
    return h.hexdigest()


def _round_robin(hosts: Tuple[int, ...]) -> List[Tuple[int, int]]:
    return [(hosts[i % len(hosts)], i) for i in range(N_SHARDS)]


def _check_shrink_assignment(t2: Trainer, target: Any) -> None:
    # the logged DataReassign must have been rewritten onto survivors
    got = sorted(map(tuple, t2.lower.data_assignment))
    want = sorted(_round_robin(tuple(target.hosts)))
    assert got == want, f"shard assignment {got}, wanted {want}"


# --- serving side (elastic re-slot) -----------------------------------------

@functools.lru_cache(maxsize=None)
def _params(arch: str):
    return M.init_params(resolve_config(arch), jax.random.PRNGKey(0))


_N_REQS, _PROMPT, _MAX_NEW, _MAX_SEQ = 3, 4, 6, 32


def _requests(arch: str) -> List[Request]:
    vocab = resolve_config(arch).vocab_size
    rng = np.random.RandomState(7)
    return [Request(rid=i, prompt=rng.randint(0, vocab, size=_PROMPT),
                    max_new=_MAX_NEW) for i in range(_N_REQS)]


def _fresh_engine(arch: str, n_slots: int) -> ServingEngine:
    eng = ServingEngine.create(arch, _params(arch), (1, 1),
                               n_slots=n_slots, max_seq=_MAX_SEQ)
    for r in _requests(arch):
        eng.submit(r)
    return eng


def _warm_engine(sess, eng: ServingEngine) -> None:
    # 3 of max_new=6 tokens: every request is strictly mid-flight
    for _ in range(3):
        eng.step()


def _outcome_engine(eng: ServingEngine) -> Dict[int, Tuple[int, ...]]:
    live = eng.live_requests()
    assert len(live) == _N_REQS, \
        f"re-slot dropped sessions: {len(live)}/{_N_REQS} survive"
    eng.run_until_drained(max_steps=500)
    return {r.rid: tuple(int(t) for t in r.out) for r in live}


@functools.lru_cache(maxsize=None)
def _reference_serving(arch: str) -> Dict[int, Tuple[int, ...]]:
    eng = _fresh_engine(arch, n_slots=2)
    reqs = eng.live_requests()
    eng.run_until_drained(max_steps=500)
    return {r.rid: tuple(int(t) for t in r.out) for r in reqs}


# --- RL third-party side ----------------------------------------------------

def _fresh_rl() -> "rl.RLActorLearner":
    return rl.RLActorLearner(n_actors=2, n_streams=8, dim=16, seed=5)


def _check_rl_shrink(app2: Any, target: Any) -> None:
    assert app2.n_actors == len(target.hosts), \
        f"restored onto {app2.n_actors} actors, wanted {len(target.hosts)}"


# --- spec assembly ----------------------------------------------------------

@functools.lru_cache(maxsize=None)
def get_spec(family: str) -> FamilySpec:
    if family == THIRD_PARTY:
        return FamilySpec(
            family=family,
            train=TrainDrive(
                fresh=_fresh_rl,
                advance=lambda a, n: a.collect_and_learn(n),
                digest=lambda a: a.digest(),
                step_of=lambda a: a.t),
            elastic=ElasticDrive(
                fresh=_fresh_rl,
                warm=lambda sess, a: a.collect_and_learn(3),
                outcome=lambda a: (a.n_actors,
                                   a.collect_and_learn(3) or a.digest()),
                reference=lambda: (3, _rl_reference_digest()),
                reslot_kwargs=lambda: {"n_actors": 3}),
            shrink=ShrinkDrive(
                hosts=(0, 1, 2), dead=0, n_shards=None,
                restore_kwargs=lambda tgt: {"n_actors": len(tgt.hosts)},
                check=_check_rl_shrink))
    arch = ARCHS[family]
    return FamilySpec(
        family=family,
        train=TrainDrive(
            fresh=lambda: _fresh_trainer(arch),
            advance=_advance_trainer,
            digest=_digest_trainer,
            step_of=lambda t: t.checkpoint_step()),
        elastic=ElasticDrive(
            fresh=lambda: _fresh_engine(arch, n_slots=2),
            warm=_warm_engine,
            outcome=_outcome_engine,
            reference=lambda: _reference_serving(arch),
            reslot_kwargs=lambda: {"params": _params(arch), "n_slots": 1}),
        shrink=ShrinkDrive(
            hosts=(0, 1, 2), dead=0, n_shards=N_SHARDS,
            prepare=lambda t: t.apply_reassignment(
                _round_robin((0, 1, 2))),
            check=_check_shrink_assignment))


@functools.lru_cache(maxsize=None)
def _rl_reference_digest() -> str:
    app = _fresh_rl()
    app.collect_and_learn(6)
    return app.digest()
