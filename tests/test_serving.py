"""Serving engine: continuous batching, prefill/decode step builders,
cache C/R as upper-half state."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.models import model as M
from repro.parallel import context as pctx
from repro.serving.engine import Request, ServingEngine, jit_prefill, \
    jit_decode_step


@pytest.fixture(scope="module")
def small_model():
    cfg = get_smoke_config("phi4-mini-3.8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mesh11():
    return jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_engine_continuous_batching(small_model):
    cfg, params = small_model
    eng = ServingEngine(cfg, params, _mesh11(), n_slots=2, max_seq=32)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i, prompt=rng.randint(0, cfg.vocab_size, size=4),
                    max_new=5) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_steps=200)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 5 for r in reqs)
    # more requests than slots => batching actually interleaved
    assert eng.steps < 5 * 5


def test_engine_greedy_matches_forward(small_model):
    """Engine's greedy continuation equals argmax teacher-forcing."""
    cfg, params = small_model
    eng = ServingEngine(cfg, params, _mesh11(), n_slots=1, max_seq=32)
    prompt = np.array([3, 5, 7, 11], np.int32)
    req = Request(rid=0, prompt=prompt, max_new=4)
    eng.submit(req)
    eng.run_until_drained(max_steps=100)

    # reference: repeated argmax with full forward
    with pctx.single_device_context():
        toks = list(prompt)
        for _ in range(4):
            batch = {"tokens": jnp.asarray([toks], jnp.int32)}
            logits, _ = M.forward_train(cfg, params, batch)
            toks.append(int(jnp.argmax(logits[0, -1])))
    assert req.out == toks[len(prompt):], (req.out, toks)


def test_prefill_step_jit(small_model):
    cfg, params = small_model
    shape = ShapeConfig("t", 16, 2, "prefill")
    fn, info = jit_prefill(cfg, shape, _mesh11())
    cache = M.init_cache(cfg, 2, 16)
    toks = jnp.zeros((2, 16), jnp.int32)
    last, cache2 = fn(params, toks, cache)
    assert last.shape == (2, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(last, np.float32)))


def _run_reference(cfg, params, prompts, max_new=5, n_slots=2, max_seq=32):
    eng = ServingEngine(cfg, params, _mesh11(), n_slots=n_slots,
                        max_seq=max_seq)
    reqs = [Request(rid=i, prompt=p.copy(), max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_steps=400)
    return {r.rid: list(r.out) for r in reqs}


@pytest.mark.parametrize("new_slots", [1, 2, 3])
def test_live_serving_restore_reslot(small_model, tmp_path, new_slots):
    """The acceptance round-trip: snapshot an engine mid-generation with
    queued + in-flight requests, restore onto a *different* slot count
    (or the same — the direct-rebind fast path), and every request's
    completed output is token-identical to the uninterrupted run."""
    from repro.core import CheckpointManager, LocalFSBackend
    cfg, params = small_model
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab_size, size=4) for _ in range(5)]
    ref = _run_reference(cfg, params, prompts)

    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    eng = ServingEngine.create("phi4-mini-3.8b-smoke", params, (1, 1),
                               n_slots=2, max_seq=32, manager=mgr)
    reqs = [Request(rid=i, prompt=p.copy(), max_new=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    for _ in range(4):
        eng.step()
    assert eng.queue and any(eng.slot_req), "snapshot must be mid-flight"
    eng.snapshot(block=True)
    finished_before = {r.rid: list(r.out) for r in reqs if r.done}
    del eng  # crash: engine, cache buffers, executables all gone

    eng2 = ServingEngine.restore(mgr, params, n_slots=new_slots)
    assert eng2.n_slots == new_slots
    live = eng2.live_requests()
    assert {r.rid for r in live} | set(finished_before) == set(ref)
    eng2.run_until_drained(max_steps=400)
    for r in live:
        assert r.done and r.out == ref[r.rid], \
            (new_slots, r.rid, r.out, ref[r.rid])
    for rid, out in finished_before.items():
        assert out == ref[rid]


def test_restored_engine_snapshot_chain(small_model, tmp_path):
    """A restored (re-slotted) engine is itself checkpointable: its
    rewritten op-log is self-consistent, so snapshot -> restore works a
    second generation deep."""
    from repro.core import CheckpointManager, LocalFSBackend
    cfg, params = small_model
    rng = np.random.RandomState(5)
    prompts = [rng.randint(0, cfg.vocab_size, size=4) for _ in range(4)]
    ref = _run_reference(cfg, params, prompts, max_new=6)

    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    eng = ServingEngine.create("phi4-mini-3.8b-smoke", params, (1, 1),
                               n_slots=2, max_seq=32, manager=mgr)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p.copy(), max_new=6))
    for _ in range(3):
        eng.step()
    eng.snapshot(block=True)
    del eng

    eng2 = ServingEngine.restore(mgr, params, n_slots=3)   # 2 -> 3
    for _ in range(2):
        eng2.step()
    eng2.snapshot(block=True)
    del eng2

    eng3 = ServingEngine.restore(mgr, params)              # stays at 3
    assert eng3.n_slots == 3
    live = eng3.live_requests()
    eng3.run_until_drained(max_steps=400)
    for r in live:
        assert r.out == ref[r.rid], (r.rid, r.out, ref[r.rid])


def test_admission_prefill_no_full_batch_decodes(small_model):
    """Admission runs one bucketed prefill per request, not O(prompt)
    full-slot-batch decode steps."""
    cfg, params = small_model
    eng = ServingEngine(cfg, params, _mesh11(), n_slots=2, max_seq=32)
    decode_calls = []
    orig = eng.decode

    def counting_decode(*a, **kw):
        decode_calls.append(1)
        return orig(*a, **kw)

    eng.decode = counting_decode
    eng.submit(Request(rid=0, prompt=np.arange(1, 13, dtype=np.int32),
                       max_new=2))
    eng.run_until_drained(max_steps=50)
    # 2 generation steps only; the 12-token prompt went through prefill
    assert len(decode_calls) == 2, len(decode_calls)


def test_decode_cache_as_upper_half_entry(small_model, tmp_path):
    """Serving-session C/R: cache contents checkpoint/restore as an
    upper-half entry (semantic conversation state)."""
    from repro.core import (CheckpointManager, LocalFSBackend, OpLog,
                            UpperHalf)
    from repro.core.split_state import flatten_with_paths, fill_like
    cfg, params = small_model
    shape = ShapeConfig("t", 32, 1, "decode")
    fn, _ = jit_decode_step(cfg, shape, _mesh11())
    cache = M.init_cache(cfg, 1, 32)
    # run a few decode steps to populate the cache
    tok = jnp.asarray([[1]], jnp.int32)
    for t in range(3):
        lg, cache = fn(params, cache, tok, jnp.asarray([t], jnp.int32))
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    up = UpperHalf()
    up.register("kv_cache", "cache", cache)
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False)
    mgr.save(3, up, OpLog())
    r = mgr.restore()
    cache_back = fill_like(cache, {
        p: v for p, v in r.entries["kv_cache"].items()})
    lg1, _ = fn(params, jax.tree.map(jnp.asarray, cache_back), tok,
                jnp.asarray([3], jnp.int32))
    lg2, _ = fn(params, cache, tok, jnp.asarray([3], jnp.int32))
    np.testing.assert_allclose(np.asarray(lg1, np.float32),
                               np.asarray(lg2, np.float32), atol=1e-5)
