"""Multi-device tests (subprocess with virtual host devices): sharded
training, elastic restore across topologies, MoE expert parallelism,
dry-run machinery. See conftest.run_with_devices.

Marked slow (each case spawns a fresh jax process): excluded from the
default tier-1 run; opt in with  pytest -m slow  or  pytest -m ""."""
import pytest

pytestmark = pytest.mark.slow


def test_sharded_train_matches_single_device(subproc):
    """Same job on a (2,2) mesh and a (1,1) mesh: identical losses —
    the logical/physical split the C/R design relies on."""
    out = subproc("""
    import jax, numpy as np
    from repro.train.loop import Trainer, TrainJob
    job = TrainJob(arch="phi4-mini-3.8b-smoke", shape_key="train_s16_b4")
    losses = {}
    for shape in [(1,1),(2,2),(4,2)]:
        t = Trainer(job, shape, ("data","model"))
        t.init_state()
        m = [t.train_steps(1)["loss"] for _ in range(3)]
        losses[shape] = m
    base = losses[(1,1)]
    for shape, m in losses.items():
        np.testing.assert_allclose(m, base, rtol=2e-2, atol=2e-3), shape
    print("OK", losses)
    """, n_devices=8)
    assert "OK" in out


def test_elastic_restore_different_mesh(subproc):
    """Checkpoint on a (2,4) mesh, restore on (4,2) and (1,1): logical
    shardings rebind; continuation losses match across topologies."""
    out = subproc("""
    import tempfile, numpy as np
    from repro.core import CheckpointManager, LocalFSBackend
    from repro.train.loop import Trainer, TrainJob
    job = TrainJob(arch="qwen2.5-32b-smoke", shape_key="train_s16_b4")
    root = tempfile.mkdtemp()
    mgr = CheckpointManager(LocalFSBackend(root), async_save=False)
    t = Trainer(job, (2,4), ("data","model"), manager=mgr)
    t.init_state()
    t.train_steps(2)
    t.save(block=True)
    d0 = t.params_digest()
    del t
    import jax
    results = {}
    for shape in [(4,2),(2,2),(1,1)]:
        t2 = Trainer.restore(mgr, mesh_factory=lambda s=shape: jax.make_mesh(s, ("data","model")))
        assert int(t2.upper.get("step")) == 2
        assert t2.params_digest() == d0, (shape, "restore must be exact")
        results[shape] = t2.train_steps(1)["loss"]
    vals = list(results.values())
    np.testing.assert_allclose(vals, vals[0], rtol=2e-2, atol=2e-3)
    print("ELASTIC OK", results)
    """, n_devices=8)
    assert "ELASTIC OK" in out


def test_moe_expert_parallel_matches_local(subproc):
    """MoE with experts sharded over the model axis == single-shard MoE."""
    out = subproc("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.parallel import context as pctx
    # capacity high enough that no token drops: capacity-factor MoE
    # output is otherwise legitimately sharding-dependent (which tokens
    # overflow depends on per-shard ranking — GShard semantics)
    cfg = get_smoke_config("kimi-k2-1t-a32b").replace(capacity_factor=16.0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 4, 16
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B,S), 0, cfg.vocab_size)}
    mesh1 = jax.make_mesh((1,1), ("data","model"), devices=jax.devices()[:1])
    with pctx.mesh_context(mesh1):
        ref, _ = jax.jit(lambda p,b: M.forward_train(cfg,p,b))(params, batch)
    mesh = jax.make_mesh((2,4), ("data","model"))
    with pctx.mesh_context(mesh):
        out, _ = jax.jit(lambda p,b: M.forward_train(cfg,p,b))(params, batch)
    np.testing.assert_allclose(np.asarray(ref,np.float32), np.asarray(out,np.float32), rtol=5e-2, atol=5e-2)
    print("MOE EP OK")
    """, n_devices=8)
    assert "MOE EP OK" in out


def test_dryrun_machinery_small_mesh(subproc):
    """The dry-run path (abstract lower + compile + analysis) works on a
    small mesh for train, prefill and decode kinds."""
    out = subproc("""
    import jax, jax.numpy as jnp
    from repro.configs import registry as R
    from repro.models import model as M
    from repro.optim import abstract_opt_state
    from repro.train import step as step_lib
    from repro.serving import engine as engine_lib
    from repro.launch.hlo_analysis import analyze_hlo, roofline_terms
    mesh = jax.make_mesh((2,4), ("data","model"))
    cfg = R.get_smoke_config("qwen1.5-110b")
    for shape_key in ["train_s64_b8", "prefill_s64_b8", "decode_s64_b8"]:
        shape = R.get_shape(shape_key)
        ab = M.init_abstract(cfg)
        if shape.kind == "train":
            fn, info = step_lib.jit_train_step(cfg, shape, mesh)
            abo = abstract_opt_state(ab, info["opt_cfg"])
            lowered = fn.lower(ab, abo, info["input_specs"],
                               jax.ShapeDtypeStruct((), jnp.int32),
                               jax.ShapeDtypeStruct((), jnp.float32))
        elif shape.kind == "prefill":
            fn, _ = engine_lib.jit_prefill(cfg, shape, mesh)
            sp = engine_lib.serve_input_specs(cfg, shape)
            lowered = fn.lower(ab, sp["tokens"], sp["cache"])
        else:
            fn, _ = engine_lib.jit_decode_step(cfg, shape, mesh)
            sp = engine_lib.serve_input_specs(cfg, shape)
            lowered = fn.lower(ab, sp["cache"], sp["tokens"], sp["pos"])
        compiled = lowered.compile()
        assert compiled.memory_analysis().temp_size_in_bytes >= 0
        counts = analyze_hlo(compiled.as_text())
        terms = roofline_terms(counts)
        assert counts.flops > 0, shape_key
        print("CELL OK", shape_key, terms["dominant"])
    print("DRYRUN OK")
    """, n_devices=8, timeout=900)
    assert "DRYRUN OK" in out


def test_grad_compression_shard_map(subproc):
    """int8+EF gradient psum inside shard_map: mean of per-shard grads
    within quantization error of the exact mean."""
    out = subproc("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.optim.compression import compressed_psum, init_error_feedback
    from repro.parallel.context import shard_map_compat
    mesh = jax.make_mesh((8,), ("data",))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 1024), jnp.float32)
    ef = jnp.zeros((8, 1024), jnp.float32)
    def f(gl, el):
        red, e2 = compressed_psum({"w": gl[0]}, {"w": el[0]}, "data")
        return red["w"][None], e2["w"][None]
    red, e2 = jax.jit(shard_map_compat(f, mesh=mesh, in_specs=(P("data"), P("data")),
                      out_specs=(P("data"), P("data"))))(g, ef)
    exact = jnp.mean(g, axis=0)
    got = np.asarray(red[0])
    err = np.abs(got - np.asarray(exact)).max()
    scale = np.abs(g).max() / 127
    assert err < 2*scale, (err, scale)
    print("COMPRESS OK", err)
    """, n_devices=8)
    assert "COMPRESS OK" in out


def test_pipeline_parallel_matches_scan(subproc):
    """GPipe over a stage axis == plain scan over layers (toy blocks)."""
    out = subproc("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import pipeline_forward, bubble_fraction
    mesh = jax.make_mesh((4, 2), ("pod", "data"))
    L, M, mb, D = 8, 6, 4, 16
    rng = jax.random.PRNGKey(0)
    W = jax.random.normal(rng, (L, D, D), jnp.float32) * 0.2
    X = jax.random.normal(jax.random.fold_in(rng, 1), (M, mb, D))
    def block(w, x):
        return jnp.tanh(x @ w)
    ref = X
    for l in range(L):
        ref = block(W[l], ref)
    out = jax.jit(lambda w, x: pipeline_forward(
        block, w, x, mesh, stage_axis="pod"))(W, X)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert 0 < bubble_fraction(4, 6) < 0.5
    print("PP OK")
    """, n_devices=8)
    assert "PP OK" in out
