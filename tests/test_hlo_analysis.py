"""Unit tests for the HLO roofline analyzer (parser, trip counts,
collective accounting, kernel adjustment)."""
import textwrap

import pytest

from repro.launch.hlo_analysis import (analyze_hlo, parse_hlo,
                                       roofline_terms, shape_bytes)


HLO = textwrap.dedent("""
    HloModule jit_step

    %add.clone (x.1: f32[], y.1: f32[]) -> f32[] {
      %x.1 = f32[] parameter(0)
      %y.1 = f32[] parameter(1)
      ROOT %add.2 = f32[] add(%x.1, %y.1)
    }

    %wrapped_compare_computation (p0: s32[], p1: s32[]) -> pred[] {
      %p0 = s32[] parameter(0)
      %p1 = s32[] parameter(1)
      ROOT %cmp = pred[] compare(%p0, %p1), direction=LT
    }

    %cond.1 (param.0: (s32[], f32[8,16])) -> pred[] {
      %param.0 = (s32[], f32[8,16]) parameter(0)
      %constant.9 = s32[] constant(12)
      %gte.0 = s32[] get-tuple-element(%param.0), index=0
      ROOT %wrapped_compare = pred[] fusion(%gte.0, %constant.9), kind=kLoop, calls=%wrapped_compare_computation
    }

    %exp_fusion (p.9: f32[8,16]) -> f32[8,16] {
      %p.9 = f32[8,16]{1,0} parameter(0)
      ROOT %e.1 = f32[8,16]{1,0} exponential(%p.9)
    }

    %body.1 (param.1: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %param.1 = (s32[], f32[8,16]) parameter(0)
      %gte.1 = s32[] get-tuple-element(%param.1), index=0
      %gte.2 = f32[8,16]{1,0} get-tuple-element(%param.1), index=1
      %dot.1 = f32[8,16]{1,0} dot(%gte.2, %gte.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %dot.2 = f32[8,16]{1,0} dot(%dot.1, %gte.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %fe = f32[8,16]{1,0} fusion(%dot.2), kind=kLoop, calls=%exp_fusion
      %c1 = s32[] constant(1)
      %next = s32[] add(%gte.1, %c1)
      ROOT %tuple.1 = (s32[], f32[8,16]) tuple(%next, %fe)
    }

    ENTRY %main.1 (arg0.1: f32[8,16], arg1.1: f32[128,16]) -> f32[8,16] {
      %arg0.1 = f32[8,16]{1,0} parameter(0)
      %arg1.1 = f32[128,16]{1,0} parameter(1)
      %dot.3 = f32[8,128]{1,0} dot(%arg0.1, %arg1.1), lhs_contracting_dims={1}, rhs_contracting_dims={1}
      %ar.1 = f32[8,128]{1,0} all-reduce(%dot.3), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add.clone
      %c0 = s32[] constant(0)
      %t0 = (s32[], f32[8,16]) tuple(%c0, %arg0.1)
      %while.1 = (s32[], f32[8,16]) while(%t0), condition=%cond.1, body=%body.1
      ROOT %out.1 = f32[8,16]{1,0} get-tuple-element(%while.1), index=1
    }
""")


def test_shape_bytes():
    assert shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert shape_bytes("bf16[4,4]") == 32
    assert shape_bytes("(f32[2], s8[8])") == 16
    assert shape_bytes("f32[]") == 4


def test_trip_count_and_flops():
    counts = analyze_hlo(HLO, assume_bf16=False)
    assert counts.while_trips == [12]
    # entry dot: 2*8*128*16; loop dots: 2 * (2*8*16*16) * 12 trips
    expect = 2 * 8 * 128 * 16 + 12 * 2 * (2 * 8 * 16 * 16)
    assert counts.flops == expect
    assert len(counts.loops) == 1
    lp = counts.loops[0]
    assert lp.trips == 12 and lp.has_exp and lp.n_dots == 2
    assert lp.fusable


def test_collective_accounting():
    counts = analyze_hlo(HLO, assume_bf16=False)
    # one all-reduce of f32[8,128] over groups of 4: 2*(n-1)/n * bytes
    expect = 2 * 3 / 4 * (8 * 128 * 4)
    assert counts.collective_bytes == pytest.approx(expect)
    # bf16 fix halves it
    counts2 = analyze_hlo(HLO, assume_bf16=True)
    assert counts2.collective_bytes == pytest.approx(expect / 2)


def test_kernel_adjustment_reduces_memory():
    counts = analyze_hlo(HLO, assume_bf16=False)
    assert counts.hbm_bytes_kernel_adjusted() < counts.hbm_bytes


def test_roofline_terms_shape():
    counts = analyze_hlo(HLO)
    t = roofline_terms(counts)
    assert set(t) == {"compute_s", "memory_s", "collective_s", "dominant",
                      "bound_s", "roofline_fraction"}
    assert 0 <= t["roofline_fraction"] <= 1.0


def test_parse_computations():
    comps = parse_hlo(HLO)
    assert "main.1" in comps and "body.1" in comps
    assert comps["body.1"].ops["dot.1"].kind == "dot"
