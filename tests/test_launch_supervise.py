"""The launchers' --supervise surface: flag validation (including the
repeatable --kill-host/--drain and the --churn[-trace] forms), the
shared SimWorldDriver mechanics, and (slow) one end-to-end supervised
train CLI run — so a regression in the glue between argparse and
ClusterSupervisor can't ship silently."""
import argparse

import pytest

from repro.launch.supervise import (SimWorldDriver, add_supervise_args,
                                    parse_churn_args, parse_drain_arg,
                                    parse_supervise_args)


def _parse(argv):
    ap = argparse.ArgumentParser()
    add_supervise_args(ap)
    return ap.parse_args(argv)


# --- flag validation ---------------------------------------------------------

def test_defaults_fill_in_under_supervise():
    args = _parse(["--supervise"])
    kill, err = parse_supervise_args(args, "t")
    assert err is None and kill == []
    assert args.hosts == 2 and args.heartbeat_timeout == 3.0


def test_kill_host_parses_and_validates_world():
    args = _parse(["--supervise", "--hosts", "4", "--kill-host", "2@8"])
    kill, err = parse_supervise_args(args, "t")
    assert err is None and kill == [(2, 8)]

    args = _parse(["--supervise", "--hosts", "4", "--kill-host", "4@8"])
    kill, err = parse_supervise_args(args, "t")
    assert kill == [] and "not in the simulated world" in err

    args = _parse(["--supervise", "--kill-host", "nope"])
    kill, err = parse_supervise_args(args, "t")
    assert kill == [] and "expected H@STEP" in err


def test_repeated_kill_and_drain_flags():
    """The single-event limitation is gone: repeated occurrences become
    a multi-event trace."""
    args = _parse(["--supervise", "--hosts", "4",
                   "--kill-host", "1@3", "--kill-host", "2@9",
                   "--drain", "0@5", "--drain", "3@7"])
    kill, err = parse_supervise_args(args, "t")
    assert err is None and kill == [(1, 3), (2, 9)]
    drain, err = parse_drain_arg(args, "t")
    assert err is None and drain == [(0, 5), (3, 7)]


def test_drain_rejects_killed_host_in_any_occurrence():
    args = _parse(["--supervise", "--hosts", "4",
                   "--kill-host", "1@3", "--drain", "1@5"])
    kill, err = parse_supervise_args(args, "t")
    assert err is None
    drain, err = parse_drain_arg(args, "t")
    assert drain == [] and "same host 1" in err


@pytest.mark.parametrize("argv", [
    ["--kill-host", "1@2"], ["--spares", "1"], ["--no-shrink"],
    ["--hosts", "8"], ["--heartbeat-timeout", "1"],
    ["--churn", "poisson:rate=0.1"], ["--churn-trace", "/tmp/x.jsonl"],
    ["--incident-log", "/tmp/x.jsonl"],
])
def test_supervise_flags_without_supervise_rejected(argv):
    kill, err = parse_supervise_args(_parse(argv), "t")
    assert kill == []
    assert err is not None and "--supervise" in err


def test_churn_args_generate_and_replay(tmp_path):
    from repro.core.churn import ChurnTrace
    args = _parse(["--supervise", "--hosts", "4", "--churn",
                   "poisson:rate=0.5,seed=3"])
    assert parse_supervise_args(args, "t")[1] is None
    trace, err = parse_churn_args(args, "t", horizon=20)
    assert err is None and len(trace) > 0
    assert all(0 <= e.host < 4 for e in trace)

    path = tmp_path / "trace.jsonl"
    trace.save(path)
    args = _parse(["--supervise", "--hosts", "4",
                   "--churn-trace", str(path)])
    assert parse_supervise_args(args, "t")[1] is None
    replay, err = parse_churn_args(args, "t", horizon=20)
    assert err is None and replay.to_jsonl() == trace.to_jsonl()


def test_churn_args_errors_are_actionable(tmp_path):
    args = _parse(["--supervise", "--churn", "poisson:wat=1"])
    assert parse_supervise_args(args, "t")[1] is None
    trace, err = parse_churn_args(args, "t", horizon=10)
    assert trace is None and "wat" in err

    bad = tmp_path / "bad.jsonl"
    bad.write_text("not json\n")
    args = _parse(["--supervise", "--churn-trace", str(bad)])
    assert parse_supervise_args(args, "t")[1] is None
    trace, err = parse_churn_args(args, "t", horizon=10)
    assert trace is None and "not JSON" in err

    args = _parse(["--supervise", "--churn", "poisson:rate=1",
                   "--churn-trace", str(bad)])
    assert parse_supervise_args(args, "t")[1] is None
    trace, err = parse_churn_args(args, "t", horizon=10)
    assert trace is None and "mutually exclusive" in err


# --- the world driver --------------------------------------------------------

class _FakeSup:
    """Just enough ClusterSupervisor surface for the driver."""

    class _Policy:
        def __init__(self):
            self.spares = []

    def __init__(self, world):
        self.world = list(world)
        self.beats = []
        self.poll_results = []
        self.incidents = []
        self.policy = self._Policy()

    def beat(self, host, step):
        self.beats.append((host, step))

    def poll(self):
        return self.poll_results.pop(0) if self.poll_results else None

    def _event(self, kind, **detail):
        pass


def test_driver_excludes_killed_host_from_its_step_on():
    sup = _FakeSup([0, 1, 2])
    d = SimWorldDriver(kill=(1, 5)).attach(sup)
    assert d.tick(4) == []
    assert d.tick(5) == []
    assert (1, 4) in sup.beats and (1, 5) not in sup.beats
    assert (0, 5) in sup.beats and (2, 5) in sup.beats
    assert d.clock() == 2.0                       # one tick per step


def test_driver_clears_kill_after_incident(capsys):
    class _T:
        class action:
            value = "shrink"
        dead = [1]
        hosts = [0, 2]

    class _I:
        action = "shrink"
        dead = [1]
        step = 0
        wall_s = 0.5

    sup = _FakeSup([0, 1, 2])
    sup.poll_results = [_T()]
    d = SimWorldDriver(kill=(1, 0)).attach(sup)
    sup.incidents.append(_I())   # as poll() would
    assert d.tick(1) != []
    d.warn_if_kill_pending()                      # resolved: no warning
    assert "WARNING" not in capsys.readouterr().err


def test_driver_warns_when_kill_never_fires(capsys):
    d = SimWorldDriver(kill=(1, 99)).attach(_FakeSup([0, 1]))
    d.tick(1)
    d.warn_if_kill_pending()
    assert "never fired" in capsys.readouterr().err


def test_driver_warns_on_undetected_death(capsys):
    d = SimWorldDriver(kill=(1, 1)).attach(_FakeSup([0, 1]))
    d.tick(1)                    # fires, host goes silent…
    d.warn_if_kill_pending()     # …but no incident before the run ended
    assert "never produced an incident" in capsys.readouterr().err


# --- end-to-end CLI (slow: trains a smoke model in-process) ------------------

@pytest.mark.slow
def test_train_cli_supervised_shrink_end_to_end(tmp_path, capsys):
    """The full --supervise surface through the real entry point: an
    injected death shrinks the world mid-run and the job finishes."""
    from repro.launch.train import main
    rc = main(["--arch", "starcoder2-3b-smoke", "--steps", "8",
               "--ckpt-every", "2", "--ckpt-dir", str(tmp_path),
               "--backend", "sharded", "--supervise", "--hosts", "4",
               "--kill-host", "1@3"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "shrink: dead=[1]" in out
    assert "done at step 8" in out


# --- the shared store surface (launch.common) --------------------------------

def _store_parse(argv):
    from repro.launch.common import add_store_args
    ap = argparse.ArgumentParser()
    add_store_args(ap)
    return ap.parse_args(argv)


def test_store_uri_passes_through():
    from repro.launch.common import resolve_store
    spec, err = resolve_store(_store_parse(["--store", "sharded:/x?hosts=4"]),
                              "t")
    assert err is None and spec == "sharded:/x?hosts=4"


def test_legacy_ckpt_dir_folds_into_spec():
    from repro.launch.common import resolve_store
    spec, err = resolve_store(
        _store_parse(["--ckpt-dir", "/x", "--backend", "sharded"]), "t")
    assert err is None and spec == "sharded:/x"
    spec, err = resolve_store(_store_parse(["--ckpt-dir", "/x"]), "t")
    assert err is None and spec == "localfs:/x"


def test_store_and_ckpt_dir_conflict_rejected():
    from repro.launch.common import resolve_store
    spec, err = resolve_store(
        _store_parse(["--store", "localfs:/a", "--ckpt-dir", "/b"]), "t")
    assert spec is None and "not both" in err


def test_bad_store_spec_exits_with_actionable_message(tmp_path):
    from repro.launch.common import build_session
    sess, err = build_session("s3:/nope", "t")
    assert sess is None and "register_backend" in err


def test_bad_policy_flags_become_exit_messages(tmp_path):
    """Invalid cadence/retention flags are one-line launcher errors, not
    tracebacks — and interval=0 means 'cadence disabled' on BOTH
    launchers (the shared boundary owns the normalization)."""
    from repro.launch.common import build_session
    sess, err = build_session(f"localfs:{tmp_path}", "t", keep_last=0)
    assert sess is None and err.startswith("[t]") and "keep_last" in err
    sess, err = build_session(f"localfs:{tmp_path}", "t", interval=-1)
    assert sess is None and err.startswith("[t]") and "interval" in err
    sess, err = build_session(f"localfs:{tmp_path}", "t", interval=0)
    assert err is None and sess.policy.interval is None
    sess.close()


def test_resume_parsing_shared():
    from repro.launch.common import parse_resume_arg
    assert parse_resume_arg(_store_parse([]), "t") == (False, None, None)
    assert parse_resume_arg(_store_parse(["--resume"]), "t") == \
        (True, None, None)
    assert parse_resume_arg(_store_parse(["--resume", "7"]), "t") == \
        (True, 7, None)
    ok, step, err = parse_resume_arg(_store_parse(["--resume", "x"]), "t")
    assert ok and step is None and "expected 'latest'" in err
