"""core.streaming + the cached: tier: streaming restore is a schedule,
not a different restore.

The pipeline (parallel fetch -> decode-while-fetch -> first-touch cold
leaves) must produce bit-identical state to the barrier materializer
across delta chains; the hot tier must come back before the cold tier
is even fetchable; the ``workers=`` knob must thread from the public
session API down to the manager; and the ``cached:`` read-through
store must serve the second restore from local bytes."""
import threading
import time

import numpy as np
import pytest

from repro.api import (CheckpointSession, Policy, PolicyError,
                       UpperHalf, parse_store_spec, register_app_kind,
                       resolve_backend)
from repro.core import CheckpointManager, OpLog, ShardedBackend
from repro.core import delta as deltamod
from repro.core.backends.cached import CachedBackend
from repro.core.backends.localfs import LocalFSBackend
from repro.core.streaming import (DEFAULT_LAZY_KINDS, LazyLeaves,
                                  StreamingMaterializer)


def _upper(seed=0, n=20_000):
    rng = np.random.RandomState(seed)
    up = UpperHalf()
    up.register("params", "params",
                {"w": rng.randn(n).astype(np.float32),
                 "b": rng.randn(128).astype(np.float32)})
    up.register("opt_state", "opt_state",
                {"m": rng.randn(n).astype(np.float32),
                 "v": rng.randn(n).astype(np.float32)})
    up.register("step", "step", np.int64(seed))
    return up


def _save_chain(backend, steps=3, base_interval=4):
    """A delta chain: steps after the base xor-encode against it."""
    mgr = CheckpointManager(backend, async_save=False,
                            delta_base_interval=base_interval)
    rng = np.random.RandomState(42)
    up = _upper(1)
    for s in range(1, steps + 1):
        # perturb a slice so deltas are small but real
        w = up.get("params")["w"]
        w[rng.randint(0, len(w), 64)] += 0.5
        up.register("step", "step", np.int64(s))
        mgr.save(s, up, OpLog())
    return mgr


def _assert_same_entries(eager, streamed):
    for name, by_path in eager.entries.items():
        got = streamed.entries[name]
        assert set(got) == set(by_path)
        for path, want in by_path.items():
            np.testing.assert_array_equal(np.asarray(got[path]),
                                          np.asarray(want))


# --- bit-identity ------------------------------------------------------------

@pytest.mark.parametrize("step", [1, 3])
def test_streaming_matches_eager_across_delta_chain(tmp_path, step):
    """Base step and deepest xor step both restore bit-identically
    under the streaming schedule (localfs, chain=3)."""
    be = LocalFSBackend(str(tmp_path))
    mgr = _save_chain(be, steps=3)
    eager = mgr.restore(step)
    streamed = mgr.restore(step, streaming=True)
    assert isinstance(streamed.entries["opt_state"], LazyLeaves)
    assert isinstance(streamed.entries["params"], dict)  # hot: plain
    _assert_same_entries(eager, streamed)
    assert streamed.streamer.complete


def test_streaming_custom_lazy_kinds(tmp_path):
    """lazy_kinds is a policy, not a hardcode: making params the cold
    tier flips which entries come back as lazy mappings — values
    unchanged either way."""
    be = LocalFSBackend(str(tmp_path))
    mgr = _save_chain(be)
    eager = mgr.restore(3)
    streamed = mgr.restore(3, streaming=True, lazy_kinds=("params",))
    assert isinstance(streamed.entries["params"], LazyLeaves)
    assert isinstance(streamed.entries["opt_state"], dict)
    _assert_same_entries(eager, streamed)


# --- the hot tier does not wait for the cold tier ---------------------------

class _GatedStore:
    """Blocks reads of a chosen blob set until the gate opens — the
    deterministic way to prove the hot tier binds while the cold tier
    is still in flight (no sleeps, no races)."""

    def __init__(self, inner, blocked):
        self._inner = inner
        self._blocked = set(blocked)
        self.gate = threading.Event()

    def get_blob(self, name):
        if name in self._blocked:
            assert self.gate.wait(20), "cold gate never opened"
        return self._inner.get_blob(name)

    def __getattr__(self, attr):
        return getattr(self._inner, attr)


def _entry_blobs(manifest, entry):
    out = set()
    for meta in manifest["entries"][entry]["leaves"].values():
        out |= set(deltamod.leaf_blob_names(meta))
    return out


def test_hot_tier_binds_while_cold_blobs_unreadable(tmp_path):
    be = LocalFSBackend(str(tmp_path))
    _save_chain(be, steps=1)
    m = be.get_manifest(1)
    cold_blobs = _entry_blobs(m, "opt_state") - _entry_blobs(m, "params")
    assert cold_blobs, "opt_state must own blobs for the gate to bite"

    gated = _GatedStore(be, cold_blobs)
    mgr = CheckpointManager(gated, async_save=False)
    t0 = time.monotonic()
    streamed = mgr.restore(1, streaming=True)
    assert time.monotonic() - t0 < 10, "hot tier waited on cold blobs"
    sm = streamed.streamer
    assert not sm.complete
    np.testing.assert_array_equal(
        np.asarray(streamed.entries["step"][""]), np.int64(1))

    cold = streamed.entries["opt_state"]
    assert not cold.ready("['m']")
    gated.gate.set()
    cold.wait()                      # bulk page-in
    sm.wait_all()
    assert sm.complete
    want = CheckpointManager(be, async_save=False).restore(1)
    _assert_same_entries(want, streamed)


def test_first_touch_fault_promotes_and_counts(tmp_path):
    """Indexing a cold leaf before the background fetch reaches it is a
    lazy fault: the value is served (promoted to the front of the fetch
    queue) and the fault is counted in the timings."""
    be = LocalFSBackend(str(tmp_path))
    _save_chain(be, steps=1)
    m = be.get_manifest(1)
    cold_blobs = _entry_blobs(m, "opt_state") - _entry_blobs(m, "params")
    gated = _GatedStore(be, cold_blobs)
    mgr = CheckpointManager(gated, async_save=False)
    streamed = mgr.restore(1, streaming=True)

    got = {}
    def touch():
        got["m"] = np.asarray(streamed.entries["opt_state"]["['m']"])
    t = threading.Thread(target=touch)
    t.start()
    time.sleep(0.05)                 # the touch is now blocked on fetch
    gated.gate.set()
    t.join(20)
    assert not t.is_alive()
    want = CheckpointManager(be, async_save=False).restore(1)
    np.testing.assert_array_equal(got["m"],
                                  np.asarray(want.entries["opt_state"]
                                             ["['m']"]))
    assert streamed.streamer.timings()["lazy_faults"] >= 1


def test_missing_blob_fails_loudly_not_lazily(tmp_path):
    """A blob no source can serve fails the dependent leaves with a
    RestoreError carrying the cause — never a silent zero tensor."""
    from repro.api.errors import RestoreError
    be = LocalFSBackend(str(tmp_path))
    _save_chain(be, steps=1)
    m = be.get_manifest(1)
    victim = sorted(_entry_blobs(m, "params"))[0]
    (be.root / "blobs" / victim[:2] / victim).unlink()
    mgr = CheckpointManager(be, async_save=False)
    with pytest.raises(RestoreError):
        mgr.restore(1, streaming=True)


# --- failure-path accounting -------------------------------------------------
# a failed leaf must not leak bytes or keep fetching blobs nobody wants

def _w_chain_blobs(be):
    """(full blob of params['w'] at step 1, its xor-link blobs at 2)."""
    m1, m2 = be.get_manifest(1), be.get_manifest(2)
    full = deltamod.leaf_blob_names(
        m1["entries"]["params"]["leaves"]["['w']"])[0]
    sibs = set(deltamod.leaf_blob_names(
        m2["entries"]["params"]["leaves"]["['w']"]))
    assert sibs, "the xor link must own blobs for the regression to bite"
    return full, sibs


def _drain(sm):
    """Wait until every leaf resolved (value or error)."""
    for fut in sm._futures.values():
        try:
            fut.result(timeout=20)
        except Exception:
            pass
    deadline = time.monotonic() + 20
    while not sm.complete:
        assert time.monotonic() < deadline, "materializer never drained"
        time.sleep(0.01)


def test_failed_leaf_drops_queued_sibling_blobs(tmp_path):
    """When a leaf fails on one blob, its sibling blobs — owned by that
    leaf alone — must leave the fetch queue, not keep being read into
    bytes no decode will ever consume."""
    from repro.api.errors import RestoreError
    be = LocalFSBackend(str(tmp_path))
    _save_chain(be, steps=2)
    w_full, w_sibs = _w_chain_blobs(be)

    reads = []

    class _Failing:
        def get_blob(self, name):
            reads.append(name)
            if name == w_full:
                raise IOError("injected: every source lost this blob")
            return be.get_blob(name)

        def __getattr__(self, attr):
            return getattr(be, attr)

    sm = StreamingMaterializer(_Failing(), 2, fetch_workers=1,
                               decode_workers=1)
    sm.start()
    _drain(sm)
    with pytest.raises(RestoreError):
        sm._futures[("params", "['w']")].result()
    # the single fetch worker walked the queue in order: the xor link
    # sat behind the failed full blob and must have been dropped
    assert not (set(reads) & w_sibs), \
        f"orphaned sibling blobs still fetched: {set(reads) & w_sibs}"
    # every byte buffer found an owner or was freed
    assert not sm._blobs, f"leaked blob bytes: {sorted(sm._blobs)}"
    assert not sm._blob_refs and not sm._queue


def test_inflight_blob_of_failed_leaf_is_not_retained(tmp_path):
    """The in-flight variant: the sibling blob is already being read
    when its only owner fails — the landed bytes must be discarded, not
    stored ownerless in ``_blobs`` forever."""
    from repro.api.errors import RestoreError
    be = LocalFSBackend(str(tmp_path))
    _save_chain(be, steps=2)
    w_full, w_sibs = _w_chain_blobs(be)

    fail_gate, sib_gate = threading.Event(), threading.Event()

    class _Gated:
        def get_blob(self, name):
            if name == w_full:
                assert fail_gate.wait(20), "fail gate never opened"
                raise IOError("injected: every source lost this blob")
            if name in w_sibs:
                assert sib_gate.wait(20), "sibling gate never opened"
            return be.get_blob(name)

        def __getattr__(self, attr):
            return getattr(be, attr)

    sm = StreamingMaterializer(_Gated(), 2, fetch_workers=2,
                               decode_workers=1)
    sm.start()
    # one worker is now blocked inside the doomed read, the other holds
    # a sibling blob in flight; fail the leaf first, then land the
    # sibling bytes into a materializer that no longer wants them
    fail_gate.set()
    w_fut = sm._futures[("params", "['w']")]
    deadline = time.monotonic() + 20
    while not w_fut.done():
        assert time.monotonic() < deadline, "leaf never failed"
        time.sleep(0.01)
    sib_gate.set()
    _drain(sm)
    with pytest.raises(RestoreError):
        w_fut.result()
    assert not (w_sibs & set(sm._blobs)), \
        "ownerless sibling bytes retained after the leaf failed"
    assert not sm._blobs and not sm._blob_refs
    # unaffected leaves still decoded from the same pipeline
    want = CheckpointManager(be, async_save=False).restore(2)
    np.testing.assert_array_equal(
        np.asarray(sm._futures[("params", "['b']")].result()),
        np.asarray(want.entries["params"]["['b']"]))


def test_hot_ready_first_writer_wins(tmp_path):
    """``hot_ready_s`` is written once, under the lock: later
    ``hot_result()`` calls and ``timings()`` readers see one stable
    value (decode workers and the empty-hot fallback share the same
    first-writer-wins discipline)."""
    be = LocalFSBackend(str(tmp_path))
    mgr = _save_chain(be, steps=1)
    streamed = mgr.restore(1, streaming=True)
    sm = streamed.streamer
    sm.wait_all()
    t1 = sm.timings()["hot_ready_s"]
    time.sleep(0.02)
    sm.hot_result()                          # fallback must not rewrite
    assert sm.timings()["hot_ready_s"] == t1

    # empty hot tier: every entry is cold, so the value comes from the
    # hot_result fallback — N racing callers must agree on one value
    streamed2 = mgr.restore(1, streaming=True,
                            lazy_kinds=("params", "opt_state", "step"))
    sm2 = streamed2.streamer
    sm2.wait_all()
    seen = set()
    barrier = threading.Barrier(8)

    def racer():
        barrier.wait()
        sm2.hot_result()
        seen.add(sm2.timings()["hot_ready_s"])

    threads = [threading.Thread(target=racer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(20)
    assert len(seen) == 1, f"hot_ready_s rewritten under race: {seen}"


# --- chains written by someone else ------------------------------------------

def test_entry_introduced_mid_chain_matches_eager(tmp_path):
    """An entry that first appears in a non-base link (the writer
    encodes its first appearance as ``full``) streams bit-identically
    to the eager restore — the planner's run-start walk stops at the
    introduction instead of KeyError-ing off the chain's base."""
    be = LocalFSBackend(str(tmp_path))
    mgr = CheckpointManager(be, async_save=False, delta_base_interval=8)
    rng = np.random.RandomState(7)
    up = _upper(1)
    mgr.save(1, up, OpLog())
    up.register("late", "opt_state", {"z": rng.randn(256).astype(np.float32)})
    for s in (2, 3):
        up.get("params")["w"][rng.randint(0, 20_000, 64)] += 0.5
        up.get("late")["z"][rng.randint(0, 256, 16)] += 1.0
        up.register("step", "step", np.int64(s))
        mgr.save(s, up, OpLog())
    m3 = be.get_manifest(3)
    assert m3["entries"]["late"]["leaves"]["['z']"].get("mode") == "xor", \
        "the introduced entry must ride a delta link for the cell to bite"
    eager = mgr.restore(3)
    streamed = mgr.restore(3, streaming=True)
    _assert_same_entries(eager, streamed)


def test_foreign_chain_missing_mid_link_fails_loudly_per_leaf(tmp_path):
    """A chain whose mid manifest lacks an entry a later link xor's
    against (a foreign writer, a hand-damaged store) must not KeyError
    the whole streaming plan before any leaf decodes: planning succeeds,
    unaffected entries restore, and only the broken leaf surfaces a
    RestoreError naming what it needed."""
    import glob as globmod
    import json
    from repro.api.errors import RestoreError
    be = LocalFSBackend(str(tmp_path))
    mgr = _save_chain(be, steps=3)
    mid = sorted(globmod.glob(str(be.root / "manifests" / "step_*.json")))[1]
    with open(mid) as f:
        m = json.load(f)
    del m["entries"]["opt_state"]
    with open(mid, "w") as f:
        json.dump(m, f)

    # eager: a loud RestoreError (the xor link has no base), not KeyError
    with pytest.raises(RestoreError, match="base-step"):
        mgr.restore(3)

    # streaming: the plan builds, the hot tier restores, only the broken
    # cold entry faults loudly on touch
    streamed = mgr.restore(3, streaming=True)
    want = CheckpointManager(be, async_save=False).restore(
        3, skip_entries=("opt_state",))
    np.testing.assert_array_equal(
        np.asarray(streamed.entries["params"]["['w']"]),
        np.asarray(want.entries["params"]["['w']"]))
    with pytest.raises(RestoreError, match="base-step"):
        np.asarray(streamed.entries["opt_state"]["['m']"])


# --- multi-source fetch ------------------------------------------------------

def test_streaming_fetches_from_multiple_hosts(tmp_path):
    """Against a sharded store the fetcher reads per-placement sources,
    not the backend's serialized get_blob: the per-source byte counters
    show more than one host serving."""
    be = ShardedBackend(str(tmp_path), n_hosts=3, replicate=True)
    mgr = _save_chain(be, steps=2)
    eager = mgr.restore(2)
    streamed = mgr.restore(2, streaming=True)
    _assert_same_entries(eager, streamed)
    served = streamed.streamer.timings()["fetch_bytes_per_source"]
    assert len(served) >= 2, f"single-source fetch: {served}"


# --- workers= threads through the public API --------------------------------

def test_session_threads_workers_and_streaming(tmp_path, monkeypatch):
    import repro.core.checkpoint as ckpt
    seen = {}
    orig = ckpt.CheckpointManager.restore

    def spy(self, *a, **kw):
        seen.update(kw)
        return orig(self, *a, **kw)

    monkeypatch.setattr(ckpt.CheckpointManager, "restore", spy)
    with CheckpointSession(f"localfs:{tmp_path}",
                           Policy(streaming_restore=True)) as sess:
        app = sess.attach(_TinyOpt())
        app.step()
        sess.snapshot(block=True)
        del app
        app2 = sess.restore("latest", workers=3)
    assert seen["workers"] == 3
    assert seen["streaming"] is True      # policy default applied
    assert app2.n == 1

    with CheckpointSession(f"localfs:{tmp_path}", Policy()) as sess:
        seen.clear()
        app3 = sess.restore("latest", decode_workers=2, streaming=False)
        assert app3.n == 1
        assert seen["workers"] == 2       # alias folds into workers
        assert not seen.get("streaming", False)
        with pytest.raises(PolicyError, match="same knob"):
            sess.restore("latest", workers=1, decode_workers=4)


class _TinyOpt:
    """Protocol citizen with a cold-tier entry (opt_state)."""
    kind = "tinyopt"

    def __init__(self):
        self.x = np.zeros(8, np.float64)
        self.m = np.zeros(8, np.float64)
        self.n = 0

    def step(self):
        self.x += 1.0
        self.m = 0.9 * self.m + self.x
        self.n += 1

    def checkpoint_state(self):
        up = UpperHalf()
        up.register("x", "params", self.x.copy())
        up.register("opt_state", "opt_state", {"m": self.m.copy()})
        up.register("n", "step", np.int64(self.n))
        return up

    def checkpoint_step(self):
        return self.n

    def job_meta(self):
        return {"kind": self.kind}

    def bind(self, restore):
        self.x = np.asarray(restore.tree("x"), np.float64).copy()
        self.m = np.asarray(restore.tree("opt_state")["m"],
                            np.float64).copy()
        self.n = int(restore.scalar("n"))
        restore.release()


@register_app_kind("tinyopt")
def _restore_tinyopt(restore):
    app = _TinyOpt()
    app.bind(restore)
    return app


def test_policy_validation():
    p = Policy(streaming_restore=True, lazy_kinds=["cache"])
    assert p.lazy_kinds == ("cache",)     # coerced to tuple
    with pytest.raises(PolicyError, match="streaming_restore"):
        Policy(lazy_kinds=("opt_state",))
    with pytest.raises(PolicyError):
        Policy(streaming_restore=True, lazy_kinds="opt_state")


# --- the cached: tier --------------------------------------------------------

def test_parse_store_spec_nested_over():
    scheme, path, params = parse_store_spec(
        "cached:/ssd/cache?over=sharded:/remote?hosts=4&replicate=1")
    assert (scheme, path) == ("cached", "/ssd/cache")
    # everything after over= belongs to the inner spec, verbatim
    assert params == {"over": "sharded:/remote?hosts=4&replicate=1"}


def test_cached_needs_over():
    with pytest.raises(PolicyError, match="cached"):
        resolve_backend("cached:/tmp/nowhere")


def test_cached_warms_then_serves_locally(tmp_path):
    """First restore reads through (misses, warms); second restore is
    served from the cache — the inner store sees no blob reads."""
    remote = tmp_path / "remote"
    cache = tmp_path / "cache"
    _save_chain(LocalFSBackend(str(remote)), steps=1)

    spec = f"cached:{cache}?over=localfs:{remote}"
    cb = resolve_backend(spec)
    assert isinstance(cb, CachedBackend)
    mgr = CheckpointManager(cb, async_save=False)
    first = mgr.restore(1, streaming=True)
    first.streamer.wait_all()        # cold tier warmed too
    assert cb.stats["warmed"] > 0 and cb.stats["hits"] == 0

    class _Dead:
        def get_blob(self, name):
            raise AssertionError(f"cache miss leaked to remote: {name}")

        def __getattr__(self, attr):
            return getattr(cb.inner, attr)

    cb2 = CachedBackend(str(cache), _Dead())
    second = CheckpointManager(cb2, async_save=False).restore(
        1, streaming=True)
    _assert_same_entries(first, second)
    assert cb2.stats["hits"] > 0 and cb2.stats["misses"] == 0
    served = second.streamer.timings()["fetch_bytes_per_source"]
    assert set(served) == {"cache"}


def test_cached_writes_through(tmp_path):
    """Snapshots taken through the cached front land durably in the
    inner store (cache loss must never lose data)."""
    spec = (f"cached:{tmp_path / 'c'}?over=sharded:{tmp_path / 'r'}"
            "?hosts=2&replicate=1")
    cb = resolve_backend(spec)
    mgr = CheckpointManager(cb, async_save=False)
    mgr.save(1, _upper(9), OpLog())
    # the inner store alone can serve the checkpoint
    inner_only = CheckpointManager(cb.inner, async_save=False)
    got = inner_only.restore(1)
    np.testing.assert_array_equal(
        np.asarray(got.entries["step"][""]), np.int64(9))
