"""Property-based tests (hypothesis) for the op-log and virtual ids —
the paper's §III invariants."""
import json

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (LowerHalf, OpLog, VirtualId, HandleTable,
                        StaleHandleError)
from repro.core.oplog import (CacheAlloc, CacheFree, Compile, DataAdvance,
                              ScheduleSet, DataReassign)


# --- strategies: random op sequences ----------------------------------------

@st.composite
def op_sequences(draw):
    """A plausible random runtime history."""
    n = draw(st.integers(1, 40))
    log = OpLog()
    live_caches = []
    arches = ["a1", "a2"]
    for _ in range(n):
        kind = draw(st.sampled_from(
            ["advance", "compile", "alloc", "free", "sched", "reassign"]))
        if kind == "advance":
            log.append(DataAdvance, n=draw(st.integers(1, 5)))
        elif kind == "compile":
            log.append(Compile, vexec=VirtualId("exec", draw(st.integers(1, 5))),
                       fn_name="f", arch=draw(st.sampled_from(arches)),
                       shape_key=draw(st.sampled_from(["s1", "s2"])),
                       plan_key="")
        elif kind == "alloc":
            vid = VirtualId("cache", 100 + len(log.ops))
            live_caches.append(vid)
            log.append(CacheAlloc, vcache=vid, arch="a1", batch=1, max_seq=8)
        elif kind == "free" and live_caches:
            log.append(CacheFree, vcache=live_caches.pop())
        elif kind == "sched":
            log.append(ScheduleSet, key=draw(st.sampled_from(["lr", "wd"])),
                       value=draw(st.floats(0.1, 2.0, allow_nan=False)))
        elif kind == "reassign":
            log.append(DataReassign,
                       assignment=((0, draw(st.integers(0, 3))),))
    return log


class FakeRuntime:
    """Duck-typed LowerHalf recording observable state (no jax)."""

    def __init__(self):
        self.compiled = set()
        self.caches = set()
        self.cursor = 0
        self.sched = {}
        self.assignment = None

    def apply_op(self, op):
        if isinstance(op, Compile):
            self.compiled.add((op.fn_name, op.arch, op.shape_key, op.plan_key))
        elif isinstance(op, CacheAlloc):
            self.caches.add(op.vcache)
        elif isinstance(op, CacheFree):
            self.caches.discard(op.vcache)
        elif isinstance(op, DataAdvance):
            self.cursor += op.n
        elif isinstance(op, ScheduleSet):
            self.sched[op.key] = op.value
        elif isinstance(op, DataReassign):
            self.assignment = op.assignment

    def state(self):
        return (frozenset(self.compiled), frozenset(self.caches),
                self.cursor, tuple(sorted(self.sched.items())),
                self.assignment)


@given(op_sequences())
@settings(max_examples=200, deadline=None)
def test_prune_preserves_replay_semantics(log):
    """replay(prune(log)) == replay(log) on observable state — the
    record-prune-replay correctness invariant."""
    a, b = FakeRuntime(), FakeRuntime()
    log.replay(a)
    log.prune().replay(b)
    assert a.state() == b.state()


@given(op_sequences())
@settings(max_examples=100, deadline=None)
def test_prune_never_grows(log):
    assert len(log.prune()) <= len(log)


@given(op_sequences())
@settings(max_examples=100, deadline=None)
def test_prune_idempotent(log):
    once = log.prune()
    twice = once.prune()
    assert [type(o).__name__ for o in once.ops] == \
        [type(o).__name__ for o in twice.ops]


@given(op_sequences())
@settings(max_examples=100, deadline=None)
def test_json_roundtrip(log):
    back = OpLog.from_json(log.to_json())
    assert len(back) == len(log)
    a, b = FakeRuntime(), FakeRuntime()
    log.replay(a)
    back.replay(b)
    assert a.state() == b.state()


# --- virtual id table --------------------------------------------------------

def test_handle_table_generation_invalidates():
    t = HandleTable()
    vid = t.create("exec", "real1")
    assert t.translate(vid) == "real1"
    t.new_incarnation()
    with pytest.raises(StaleHandleError):
        t.translate(vid)
    t.bind(vid, "real2")
    assert t.translate(vid) == "real2"


@given(st.lists(st.integers(0, 4), min_size=1, max_size=50))
def test_handle_table_uids_unique(kinds):
    t = HandleTable()
    seen = set()
    for k in kinds:
        vid = t.create(f"k{k}", object())
        assert vid not in seen
        seen.add(vid)


def test_adopted_vids_bump_counter():
    """Binding a vid from a previous process must not cause collisions."""
    t = HandleTable()
    foreign = VirtualId("exec", 100)
    t.bind(foreign, "x")
    fresh = t.create("exec", "y")
    assert fresh.uid > 100
