"""Device-resident dirty-chunk capture: the sparse capture/encode
contract (manifest format 3) — fingerprint dirty detection, identity
skips, sparse chain application, failure re-baselining, and equivalence
with the dense format-2 path.

Tests use a small ``sparse_chunk_bytes`` so modest arrays span many
chunks; the production default is 256 KiB (kernels/ckpt_codec/ref.py).
"""
import numpy as np
import pytest

from repro.core import (CheckpointManager, Incarnation, LocalFSBackend,
                        OpLog, UpperHalf)
from repro.core import delta as deltamod
from repro.core.restore import restorable_steps

CB = 4096  # sparse chunk bytes for tests


def _mgr(path, **kw):
    kw.setdefault("async_save", False)
    kw.setdefault("delta_base_interval", 8)
    kw.setdefault("sparse_chunk_bytes", CB)
    kw.setdefault("sparse_min_bytes", 2 * CB)
    return CheckpointManager(LocalFSBackend(str(path)), **kw)


def _upper(rng, n=64 * 1024):
    up = UpperHalf()
    up.register("params", "params",
                {"w": rng.randn(n).astype(np.float32),
                 "b": rng.randn(64).astype(np.float32)})  # below min: dense
    up.register("step", "step", np.int64(0))
    return up


# ---------------------------------------------------------------------------
# sparse chain roundtrip + manifest shape
# ---------------------------------------------------------------------------

def test_sparse_chain_roundtrip_bit_identical(tmp_path):
    """Scattered in-place updates: every link is a sparse format-3
    manifest recording only dirty chunks, and every step restores to
    the exact bytes that were live at its capture."""
    rng = np.random.RandomState(0)
    mgr = _mgr(tmp_path)
    up = _upper(rng)
    want = {}
    for s in range(1, 7):
        w = up.get("params")["w"]
        idx = rng.randint(0, w.size, size=40)
        w[idx] += rng.randn(idx.size).astype(np.float32)
        up.update("step", np.int64(s))
        mgr.save(s, up, OpLog())
        want[s] = w.copy()

    be = mgr.backend
    assert be.get_manifest(1)["format"] == 2      # full base, no sparse
    for s in range(2, 7):
        m = be.get_manifest(s)
        assert m["format"] == 3
        raw = m["entries"]["params"]["leaves"]["['w']"]["parts"]["raw"]
        assert raw["chunk_bytes"] == CB
        assert 0 < len(raw["dirty"]) < raw["n_chunks"]
    assert mgr.stats["dirty_chunks"] > 0
    assert mgr.stats["clean_chunks"] > mgr.stats["dirty_chunks"]

    for s in range(1, 7):
        r = mgr.restore(s)
        np.testing.assert_array_equal(r.entries["params"]["['w']"], want[s])
        assert int(r.entries["step"][""]) == s


def test_sparse_capture_moves_fewer_bytes_than_dense(tmp_path):
    """The point of the PR: capture traffic and encode work scale with
    the change rate, not the state size."""
    results = {}
    for sparse in (True, False):
        rng = np.random.RandomState(1)
        mgr = _mgr(tmp_path / str(sparse), sparse_capture=sparse)
        up = _upper(rng, n=128 * 1024)
        mgr.save(1, up, OpLog())
        base = dict(mgr.stats)
        for s in (2, 3, 4):
            w = up.get("params")["w"]
            w[:w.size // 50] += 1.0   # ~2% of chunks dirty
            mgr.save(s, up, OpLog())
        results[sparse] = {
            k: mgr.stats[k] - base[k]
            for k in ("capture_bytes", "bytes_encoded")}
        r = mgr.restore(4)
        np.testing.assert_array_equal(r.entries["params"]["['w']"],
                                      up.get("params")["w"])
    assert results[True]["capture_bytes"] < \
        results[False]["capture_bytes"] / 4
    assert results[True]["bytes_encoded"] < \
        results[False]["bytes_encoded"] / 4


def test_identity_skip_for_immutable_jax_leaves(tmp_path):
    """A leaf that is the same jax Array object as last capture is
    skipped without reading a byte (immutability makes identity a proof
    of byte-equality); restores stay exact."""
    jnp = pytest.importorskip("jax.numpy")
    rng = np.random.RandomState(2)
    frozen = jnp.asarray(rng.randn(16 * 1024).astype(np.float32))
    mgr = _mgr(tmp_path)
    up = UpperHalf()
    hot0 = rng.randn(16 * 1024).astype(np.float32)
    up.register("params", "params", {"frozen": frozen, "hot": None})
    for s in (1, 2, 3):
        hot = hot0.copy()
        hot[::101] += s
        up.update("params", {"frozen": frozen, "hot": jnp.asarray(hot)})
        mgr.save(s, up, OpLog())
    assert mgr.stats["identity_skips"] == 2   # frozen at steps 2 and 3
    r = mgr.restore(3)
    np.testing.assert_array_equal(r.entries["params"]["['frozen']"],
                                  np.asarray(frozen))
    np.testing.assert_array_equal(r.entries["params"]["['hot']"], hot)


def test_gc_keeps_sparse_chain_blobs(tmp_path):
    """referenced_hashes must see sparse dirty-chunk blobs, or GC would
    tear restorable chains apart."""
    rng = np.random.RandomState(3)
    mgr = _mgr(tmp_path, keep_last=2)
    up = _upper(rng)
    for s in range(1, 5):
        up.get("params")["w"][:64] += 1.0
        mgr.save(s, up, OpLog())
        want = up.get("params")["w"].copy()
    assert restorable_steps(mgr.backend) == [1, 2, 3, 4]
    r = mgr.restore(4)
    np.testing.assert_array_equal(r.entries["params"]["['w']"], want)


def test_encode_failure_rebaselines_chain(tmp_path):
    """A snapshot that dies mid-commit invalidates the fingerprint
    baseline: the next snapshot is a dense full base (no sparse capture
    may XOR against a half-patched mirror), and the chain then
    resumes."""
    class Crashing(LocalFSBackend):
        crash = False

        def put_blob(self, name, data):
            if self.crash:
                raise OSError("injected crash")
            super().put_blob(name, data)

    be = Crashing(str(tmp_path))
    mgr = CheckpointManager(be, async_save=False, delta_base_interval=8,
                            sparse_chunk_bytes=CB, sparse_min_bytes=2 * CB)
    rng = np.random.RandomState(4)
    up = _upper(rng)
    mgr.save(1, up, OpLog())
    up.get("params")["w"][:32] += 1.0
    be.crash = True
    with pytest.raises(OSError, match="injected crash"):
        mgr.save(2, up, OpLog())
    be.crash = False
    up.get("params")["w"][100:132] += 1.0
    mgr.save(3, up, OpLog())
    m3 = be.get_manifest(3)
    assert m3["base_step"] is None and m3["format"] == 2
    np.testing.assert_array_equal(mgr.restore(3).entries["params"]["['w']"],
                                  up.get("params")["w"])
    up.get("params")["w"][200:232] += 1.0
    mgr.save(4, up, OpLog())
    assert be.get_manifest(4)["base_step"] == 3   # chain resumed
    np.testing.assert_array_equal(mgr.restore(4).entries["params"]["['w']"],
                                  up.get("params")["w"])


def test_format2_checkpoint_restores_through_incarnation(tmp_path):
    """Backward compatibility: a dense format-2 chain written with
    sparse capture disabled restores through the Incarnation lifecycle
    unchanged."""
    rng = np.random.RandomState(5)
    mgr = _mgr(tmp_path, sparse_capture=False)
    up = _upper(rng)
    for s in (1, 2):
        up.get("params")["w"][:128] += 1.0
        up.update("step", np.int64(s))
        mgr.save(s, up, OpLog())
    assert mgr.backend.get_manifest(2)["format"] == 2
    inc = Incarnation(mgr, step=2)
    state = inc.materialize()
    inc.build_lower()   # empty log: fresh, hardware-free lower half
    np.testing.assert_array_equal(state.entries["params"]["['w']"],
                                  up.get("params")["w"])
    assert int(inc.scalar("step")) == 2


def test_unknown_manifest_format_is_rejected(tmp_path):
    """A manifest from a newer build fails loudly instead of being
    silently misread."""
    rng = np.random.RandomState(6)
    mgr = _mgr(tmp_path)
    mgr.save(1, _upper(rng), OpLog())
    m = mgr.backend.get_manifest(1)
    m["format"] = 99
    mgr.backend.commit_manifest(1, m)
    with pytest.raises(ValueError, match="format 99"):
        mgr.restore(1)


def test_invalid_sparse_chunk_bytes_rejected_at_construction(tmp_path):
    """Unsupported chunk geometry fails with a clear ValueError when the
    manager is built — not an AssertionError inside the first save."""
    with pytest.raises(ValueError, match="sparse_chunk_bytes"):
        _mgr(tmp_path, sparse_chunk_bytes=12 * 1024)   # not a seg multiple
    with pytest.raises(ValueError, match="sparse_chunk_bytes"):
        _mgr(tmp_path, sparse_chunk_bytes=100)         # not a lane multiple


def test_vanished_leaf_cannot_match_stale_baseline(tmp_path):
    """A leaf that disappears for one snapshot and reappears must not
    sparse-encode against a mirror that no longer holds it."""
    rng = np.random.RandomState(7)
    mgr = _mgr(tmp_path)
    w = rng.randn(16 * 1024).astype(np.float32)
    up = UpperHalf()
    up.register("params", "params", {"w": w.copy()})
    mgr.save(1, up, OpLog())
    up.update("params", {})                    # leaf vanishes
    mgr.save(2, up, OpLog())
    up.update("params", {"w": w.copy()})       # reappears, same bytes
    mgr.save(3, up, OpLog())
    r = mgr.restore(3)
    np.testing.assert_array_equal(r.entries["params"]["['w']"], w)


# ---------------------------------------------------------------------------
# SnapshotHandle.result(timeout) regression
# ---------------------------------------------------------------------------

def test_result_timeout_raises_builtin_timeout_error(tmp_path):
    """result(timeout) on an uncommitted snapshot raises the builtin
    TimeoutError — never returns partial state, and is catchable by
    ``except TimeoutError`` on every Python version."""
    import time

    class Slow(LocalFSBackend):
        def put_blob(self, name, data):
            time.sleep(0.2)
            super().put_blob(name, data)

    rng = np.random.RandomState(8)
    mgr = CheckpointManager(Slow(str(tmp_path)), async_save=True)
    up = _upper(rng, n=256 * 1024)
    h = mgr.save(1, up, OpLog())
    with pytest.raises(TimeoutError, match="step 1"):
        h.result(timeout=0.01)
    manifest = h.result()             # eventually commits fine
    assert manifest["step"] == 1
    mgr.close()
