"""Async snapshot pipeline: delta chains, commit atomicity under crashes,
backpressure, capture isolation — the capture/encode/commit contract."""
import threading
import time

import numpy as np
import pytest

from repro.core import (AsyncSnapshotter, CheckpointManager, LocalFSBackend,
                        OpLog, ShardedBackend, UpperHalf,
                        manifest_chain_steps, materialize_manifest_chain)
from repro.core.delta import CHUNK_BYTES, encode_leaf, decode_leaf
from repro.core.restore import restorable_steps


def _mk_upper(rng, n=50_000):
    up = UpperHalf()
    up.register("params", "params",
                {"w": rng.randn(n).astype(np.float32),
                 "b": rng.randn(64).astype(np.float32)})
    up.register("step", "step", np.int64(0))
    return up


# ---------------------------------------------------------------------------
# delta chain
# ---------------------------------------------------------------------------

def test_delta_chain_roundtrip_bit_identical(tmp_path):
    """base + N XOR deltas -> every intermediate step restores to the
    exact bytes that were live when it was captured."""
    rng = np.random.RandomState(0)
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)),
                            async_save=False, delta_base_interval=4)
    up = _mk_upper(rng)
    want = {}
    for s in range(1, 9):
        # sparse update: most bytes unchanged step-over-step
        w = up.get("params")["w"]
        idx = rng.randint(0, w.size, size=w.size // 100)
        w[idx] += rng.randn(idx.size).astype(np.float32)
        up.update("step", np.int64(s))
        mgr.save(s, up, OpLog())
        want[s] = {"w": w.copy(), "b": up.get("params")["b"].copy()}

    # manifests actually chain: steps 2-4 hang off 1, 6-8 off 5
    be = mgr.backend
    assert be.get_manifest(1)["base_step"] is None
    assert be.get_manifest(2)["base_step"] == 1
    assert be.get_manifest(4)["base_step"] == 3
    assert be.get_manifest(5)["base_step"] is None
    assert manifest_chain_steps(be, 4) == [1, 2, 3, 4]

    for s in range(1, 9):
        r = mgr.restore(s)
        np.testing.assert_array_equal(r.entries["params"]["['w']"],
                                      want[s]["w"])
        np.testing.assert_array_equal(r.entries["params"]["['b']"],
                                      want[s]["b"])
        assert int(r.entries["step"][""]) == s


def test_chain_unchanged_leaf_writes_nothing(tmp_path):
    """An untouched tensor's delta link stores nothing: all-clean in the
    sparse dirty-chunk path (format 3), all zero chunks elided in the
    dense xor path (format 2). Zero blob bytes either way."""
    rng = np.random.RandomState(1)
    for sparse in (True, False):
        mgr = CheckpointManager(LocalFSBackend(str(tmp_path / str(sparse))),
                                async_save=False, delta_base_interval=10,
                                sparse_capture=sparse)
        up = _mk_upper(rng, n=300_000)
        mgr.save(1, up, OpLog())
        first = mgr.stats["bytes_written"]
        mgr.save(2, up, OpLog())  # nothing changed: pure zero-delta link
        assert mgr.stats["bytes_written"] == first
        m = mgr.backend.get_manifest(2)
        leaf = m["entries"]["params"]["leaves"]["['w']"]
        assert leaf["mode"] == "xor"
        raw = leaf["parts"]["raw"]
        if sparse:
            assert m["format"] == 3
            assert raw["dirty"] == []       # not a single dirty chunk
        else:
            assert m["format"] == 2
            assert all(c is None for c in raw["chunks"])
        r = mgr.restore(2)
        np.testing.assert_array_equal(r.entries["params"]["['w']"],
                                      up.get("params")["w"])


def test_gc_keeps_base_closure(tmp_path):
    """keep_last must not break a kept checkpoint's chain: its full base
    (and intermediate links) survive GC even when older than the cut."""
    rng = np.random.RandomState(2)
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False,
                            delta_base_interval=5, keep_last=2)
    up = _mk_upper(rng)
    for s in range(1, 5):
        up.get("params")["w"][:100] += 1.0
        mgr.save(s, up, OpLog())
        want_w = up.get("params")["w"].copy()
    steps = mgr.backend.list_steps()
    # 3 and 4 kept; their chain back to base 1 must survive too
    assert set(steps) == {1, 2, 3, 4}
    assert restorable_steps(mgr.backend) == [1, 2, 3, 4]
    r = mgr.restore(4)
    np.testing.assert_array_equal(r.entries["params"]["['w']"], want_w)


def test_restorable_steps_excludes_broken_chain(tmp_path):
    rng = np.random.RandomState(3)
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False,
                            delta_base_interval=5)
    up = _mk_upper(rng)
    for s in (1, 2, 3):
        up.get("params")["w"][:10] += 1.0
        mgr.save(s, up, OpLog())
    mgr.backend.delete_step(2)  # sever the chain
    assert restorable_steps(mgr.backend) == [1]


# ---------------------------------------------------------------------------
# crash safety
# ---------------------------------------------------------------------------

class _CrashingBackend(LocalFSBackend):
    """Injects a crash after N successful blob writes."""

    def __init__(self, root, crash_after):
        super().__init__(root)
        self.crash_after = crash_after
        self.writes = 0
        self._lock = threading.Lock()

    def put_blob(self, name, data):
        with self._lock:
            if self.writes >= self.crash_after:
                raise OSError("injected crash: writer died mid-checkpoint")
            self.writes += 1
        super().put_blob(name, data)


def test_crash_during_commit_previous_checkpoint_survives(tmp_path):
    """A snapshot that dies mid-write publishes nothing: the previous
    manifest stays 'latest' and still restores; the failure surfaces on
    wait(); stray temp files are swept on reopen."""
    rng = np.random.RandomState(4)
    be = _CrashingBackend(str(tmp_path), crash_after=10**9)
    mgr = CheckpointManager(be, async_save=True)
    up = _mk_upper(rng, n=200_000)
    mgr.save(1, up, OpLog())
    mgr.wait()

    be.crash_after = be.writes  # die on the next save's first blob
    up.get("params")["w"][:] += 1.0
    mgr.save(2, up, OpLog())
    # let the failure fully retire before wait(): a fire-and-forget
    # caller must still see it (not only races that catch it in flight)
    deadline = time.monotonic() + 5
    while mgr.stats["failed"] == 0 and time.monotonic() < deadline:
        time.sleep(0.01)
    with pytest.raises(OSError, match="injected crash"):
        mgr.wait()
    mgr.wait()  # raised once, then cleared

    assert be.list_steps() == [1]          # step 2 never became visible
    r = mgr.restore()                       # latest == the survivor
    assert r.step == 1

    # the pipeline stays usable after a failed snapshot
    be.crash_after = 10**9
    mgr.save(3, up, OpLog())
    mgr.wait()
    assert mgr.restore().step == 3

    # a reopened backend sweeps stale torn temp files — but only stale
    # ones: a fresh .tmp may be a live writer in another process
    import os
    d = be.root / "blobs" / "aa"
    d.mkdir(parents=True, exist_ok=True)
    (d / ".tmp_torn").write_bytes(b"partial")
    (d / ".tmp_live").write_bytes(b"in flight")
    os.utime(d / ".tmp_torn", (1, 1))  # ancient
    be2 = LocalFSBackend(str(tmp_path))
    assert not (d / ".tmp_torn").exists()
    assert (d / ".tmp_live").exists()


def test_manifest_commit_is_atomic_publication(tmp_path):
    """Blobs without a manifest are invisible; the manifest rename is
    the single publication point (both backends)."""
    for be in (LocalFSBackend(str(tmp_path / "fs")),
               ShardedBackend(str(tmp_path / "sh"), n_hosts=3)):
        be.put_blob("aa" + "0" * 38, b"garbage from a crashed writer")
        assert be.list_steps() == []
        mgr = CheckpointManager(be, async_save=False)
        rng = np.random.RandomState(5)
        mgr.save(7, _mk_upper(rng), OpLog())
        assert mgr.restore().step == 7


# ---------------------------------------------------------------------------
# backpressure / overlap
# ---------------------------------------------------------------------------

class _SlowBackend(LocalFSBackend):
    def __init__(self, root, delay=0.05):
        super().__init__(root, fsync=False)
        self.delay = delay

    def put_blob(self, name, data):
        time.sleep(self.delay)
        super().put_blob(name, data)


def test_backpressure_skip_drops_when_saturated(tmp_path):
    """Snapshots requested faster than the writer drains: "skip" policy
    drops the excess (counted), never queues unboundedly."""
    rng = np.random.RandomState(6)
    mgr = CheckpointManager(_SlowBackend(str(tmp_path)), async_save=True,
                            backpressure="skip", writers=1)
    up = _mk_upper(rng, n=200_000)
    handles = []
    for s in range(1, 8):
        up.get("params")["w"][:10] += 1.0
        handles.append(mgr.save(s, up, OpLog()))
    mgr.wait()
    skipped = mgr.stats["skipped"]
    assert skipped == sum(h is None for h in handles)
    assert skipped >= 1, "slow backend must saturate the 2-slot pipeline"
    assert mgr.stats["saves"] == 7 - skipped
    # committed ones restore fine
    r = mgr.restore()
    assert r.step == max(s for s, h in zip(range(1, 8), handles)
                         if h is not None)


def test_blocking_save_overrides_skip_policy(tmp_path):
    """save(block=True) under a "skip" policy must wait for a slot, not
    silently drop — e.g. the final checkpoint of a run."""
    rng = np.random.RandomState(12)
    mgr = CheckpointManager(_SlowBackend(str(tmp_path)), async_save=True,
                            backpressure="skip", writers=1)
    up = _mk_upper(rng, n=200_000)
    for s in range(1, 6):
        up.get("params")["w"][:10] += 1.0
        mgr.save(s, up, OpLog())
    mgr.save(6, up, OpLog(), block=True)
    assert mgr.backend.latest_step() == 6


def test_keep_last_zero_keeps_everything(tmp_path):
    """keep_last <= 0 means no retention limit — it must never mean
    'delete every checkpoint just committed'."""
    rng = np.random.RandomState(13)
    mgr = CheckpointManager(LocalFSBackend(str(tmp_path)), async_save=False,
                            keep_last=0)
    up = _mk_upper(rng, n=10_000)
    for s in (1, 2, 3):
        mgr.save(s, up, OpLog())
    assert mgr.backend.list_steps() == [1, 2, 3]
    assert mgr.restore(2).step == 2


def test_handled_blocking_failure_not_reraised_by_wait(tmp_path):
    """An error delivered to a blocking save() is consumed there; a
    later wait() after successful snapshots must not resurrect it."""
    rng = np.random.RandomState(14)
    be = _CrashingBackend(str(tmp_path), crash_after=0)
    mgr = CheckpointManager(be, async_save=False)
    up = _mk_upper(rng)
    with pytest.raises(OSError, match="injected crash"):
        mgr.save(1, up, OpLog())
    be.crash_after = 10**9
    mgr.save(2, up, OpLog())   # retry succeeds
    mgr.wait()                 # must NOT re-raise the handled failure
    assert mgr.restore().step == 2


def test_backpressure_block_commits_everything_in_order(tmp_path):
    rng = np.random.RandomState(7)
    mgr = CheckpointManager(_SlowBackend(str(tmp_path), delay=0.01),
                            async_save=True, backpressure="block")
    up = _mk_upper(rng, n=50_000)
    for s in range(1, 6):
        up.get("params")["w"][:10] += 1.0
        mgr.save(s, up, OpLog())
    mgr.wait()
    assert mgr.stats["skipped"] == 0
    assert mgr.backend.list_steps() == [1, 2, 3, 4, 5]


def test_capture_isolation_under_chaining(tmp_path):
    """Mutating state right after snapshot() must affect neither the
    in-flight snapshot nor the XOR base of the next one."""
    rng = np.random.RandomState(8)
    mgr = CheckpointManager(_SlowBackend(str(tmp_path), delay=0.01),
                            async_save=True, delta_base_interval=3)
    up = _mk_upper(rng, n=100_000)
    want = {}
    for s in (1, 2, 3):
        mgr.save(s, up, OpLog())
        want[s] = up.get("params")["w"].copy()
        up.get("params")["w"][:] += 1.0   # mutate while encode in flight
    mgr.wait()
    for s in (1, 2, 3):
        np.testing.assert_array_equal(
            mgr.restore(s).entries["params"]["['w']"], want[s])


def test_async_overlaps_caller_thread(tmp_path):
    """snapshot() returns before the backend finishes writing — the
    caller-side stall is the capture, not the commit."""
    rng = np.random.RandomState(9)
    slow = _SlowBackend(str(tmp_path), delay=0.05)
    mgr = CheckpointManager(slow, async_save=True)
    up = _mk_upper(rng, n=int(1.5 * CHUNK_BYTES / 4))  # several chunks
    t0 = time.monotonic()
    h = mgr.save(1, up, OpLog())
    returned = time.monotonic() - t0
    assert not h.done(), "commit should still be in flight"
    mgr.wait()
    total = time.monotonic() - t0
    assert returned < total, (returned, total)


def test_repeated_chunks_within_snapshot_dedup_once(tmp_path):
    """Identical chunks inside one async snapshot (e.g. zero-initialized
    weights spanning several chunks) must be written and counted once,
    even though the writer pool hasn't landed the first copy yet when
    the next one is encoded."""
    rng = np.random.RandomState(15)
    mgr = CheckpointManager(_SlowBackend(str(tmp_path), delay=0.05),
                            async_save=True, writers=1, compress=False)
    up = UpperHalf()
    n = 3 * CHUNK_BYTES // 4  # three identical all-zero 4 MiB chunks
    up.register("params", "params", {"w": np.zeros(n, np.float32)})
    mgr.save(1, up, OpLog())
    mgr.wait()
    assert mgr.stats["bytes_written"] == CHUNK_BYTES
    r = mgr.restore()
    assert not r.entries["params"]["['w']"].any()
    mgr.close()


# ---------------------------------------------------------------------------
# codec unit: xor leaf + pallas xor kernel vs numpy
# ---------------------------------------------------------------------------

def test_encode_leaf_xor_roundtrip_sub_chunk_tail():
    """XOR leaves with a non-chunk-aligned tail roundtrip exactly."""
    rng = np.random.RandomState(10)
    prev = rng.randn(CHUNK_BYTES // 4 + 123).astype(np.float32)
    cur = prev.copy()
    cur[::1000] += 2.0
    blobs = {}
    meta = encode_leaf(cur, lambda n, d: blobs.setdefault(n, d),
                       lambda n: n in blobs, prev=prev)
    assert meta["mode"] == "xor"
    back = decode_leaf(meta, blobs.__getitem__, prev=prev)
    np.testing.assert_array_equal(back, cur)


def test_pallas_xor_kernel_matches_numpy():
    ops = pytest.importorskip("repro.kernels.ckpt_codec.ops")
    rng = np.random.RandomState(11)
    x = rng.randn(3000).astype(np.float32)
    prev = x + rng.randn(3000).astype(np.float32)
    delta = ops.delta_encode(x, prev)
    ref = np.bitwise_xor(x.view(np.uint8), prev.view(np.uint8))
    np.testing.assert_array_equal(delta, ref)
    back = ops.delta_decode(delta, prev, np.float32, (3000,))
    np.testing.assert_array_equal(back, x)
