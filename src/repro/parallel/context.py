"""Ambient mesh context.

Models are pure functions, but expert-parallel dispatch needs to know the
mesh and axis names to emit shard_map/psum. Rather than threading mesh
handles through every call (which would also poison the upper-half state
with lower-half objects — see core.split_state), the *lower half* installs
a MeshContext for the duration of a step; models read it here.
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass
from typing import Optional, Tuple

import jax


def shard_map_compat(fn, *, mesh, in_specs, out_specs):
    """jax.shard_map with replication checking off, across jax versions:
    top-level `jax.shard_map(check_vma=...)` is 0.6+; older releases ship
    it as `jax.experimental.shard_map.shard_map(check_rep=...)`."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


@dataclass(frozen=True)
class MeshContext:
    mesh: object                      # jax.sharding.Mesh (or AbstractMesh)
    data_axes: Tuple[str, ...]        # ("data",) or ("pod", "data")
    model_axis: Optional[str]         # "model" (None = no tensor parallelism)

    @property
    def batch_spec_axes(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    def model_size(self) -> int:
        if self.model_axis is None:
            return 1
        return int(self.mesh.shape[self.model_axis])

    def data_size(self) -> int:
        n = 1
        for a in self.data_axes:
            n *= int(self.mesh.shape[a])
        return n


_ctx: contextvars.ContextVar[Optional[MeshContext]] = contextvars.ContextVar(
    "repro_mesh_context", default=None)


def current() -> Optional[MeshContext]:
    return _ctx.get()


@contextlib.contextmanager
def mesh_context(mesh, data_axes=("data",), model_axis="model"):
    tok = _ctx.set(MeshContext(mesh, tuple(data_axes), model_axis))
    try:
        yield _ctx.get()
    finally:
        _ctx.reset(tok)


def single_device_context():
    """Context for tests/examples on one device: a 1x1 mesh."""
    dev = jax.devices()[0]
    mesh = jax.sharding.Mesh([[dev]], ("data", "model"))
    return mesh_context(mesh)
