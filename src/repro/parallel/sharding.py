"""Logical-axis sharding: rules map logical axis names (declared once in
the parameter templates) to mesh axes, yielding NamedShardings.

This is the boundary the paper's split-state design depends on: the upper
half stores *logical* specs only; binding to a concrete mesh happens here,
at restore/lowering time, so a checkpoint taken on one topology
materializes on another (elastic restart).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

AxisTarget = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ParallelPlan:
    """A complete distribution decision for one (arch, shape, mesh) cell."""

    rules: Dict[str, AxisTarget]          # logical axis -> mesh axis target
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: Optional[str] = "model"
    remat: str = "full"                   # none | full
    seq_shard: bool = False               # sequence parallelism on residual
    cache_seq_axis: Optional[str] = None  # shard KV-cache seq dim (decode)
    grad_accum: int = 1
    # force Megatron-style interior activation resharding instead of
    # XLA's weight-gather choice. Measured a REGRESSION at B_local=16
    # on all three hillclimb cells (EXPERIMENTS §Perf iter3) — weight
    # gathers are cheaper than activation reshards at small per-chip
    # batch; kept as an opt-in for large-batch plans.
    interior_tp: bool = False
    notes: str = ""

    def rule(self, name: Optional[str]) -> AxisTarget:
        if name is None:
            return None
        return self.rules.get(name)

    def with_(self, **kw) -> "ParallelPlan":
        return replace(self, **kw)


def spec_for_axes(plan: ParallelPlan, axes: Sequence[Optional[str]],
                  shape: Optional[Sequence[int]] = None,
                  mesh: Optional[Mesh] = None) -> PartitionSpec:
    """logical axes tuple -> PartitionSpec, dropping assignments that do
    not divide the dimension (e.g. kv_heads=8 over model=16 falls back to
    replication, the standard GQA choice)."""
    used = set()
    out = []
    for i, name in enumerate(axes):
        tgt = plan.rule(name)
        if tgt is None:
            out.append(None)
            continue
        tgt_tuple = (tgt,) if isinstance(tgt, str) else tuple(tgt)
        # drop already-used axes (a mesh axis may appear once per spec)
        tgt_tuple = tuple(a for a in tgt_tuple if a not in used)
        if not tgt_tuple:
            out.append(None)
            continue
        if shape is not None and mesh is not None:
            div = int(np.prod([mesh.shape[a] for a in tgt_tuple]))
            if shape[i] % div != 0:
                out.append(None)
                continue
        used.update(tgt_tuple)
        out.append(tgt_tuple[0] if len(tgt_tuple) == 1 else tgt_tuple)
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def tree_shardings(plan: ParallelPlan, logical_tree, abstract_tree, mesh: Mesh):
    """Map (logical-axes pytree, ShapeDtypeStruct pytree) -> NamedShardings."""

    def f(axes, ab):
        spec = spec_for_axes(plan, axes, ab.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree.map(f, logical_tree, abstract_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


def tree_specs(plan: ParallelPlan, logical_tree, abstract_tree, mesh: Mesh):
    def f(axes, ab):
        return spec_for_axes(plan, axes, ab.shape, mesh)

    return jax.tree.map(f, logical_tree, abstract_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


# ---------------------------------------------------------------------------
# standard rule sets
# ---------------------------------------------------------------------------

def train_rules(fsdp: bool, batch_axes: Tuple[str, ...]) -> Dict[str, AxisTarget]:
    """Megatron-style TP (+ optional ZeRO-3 FSDP over the data axes)."""
    emb: AxisTarget = tuple(batch_axes) if fsdp else None
    return {
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",     # falls back to replication if indivisible
        "ff": "model",
        "experts": "model",
        "embed": emb,
        "layers": None,
    }


def serve_rules(depth: int, batch_axes: Tuple[str, ...]) -> Dict[str, AxisTarget]:
    """depth 1: TP only. depth 2: 2D weight sharding (TP + weight
    sharding over the data axes — activations all-reduce over data, the
    PaLM-style weight-stationary layout for models too big for TP=16)."""
    emb: AxisTarget = tuple(batch_axes) if depth >= 2 else None
    return {
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "experts": "model",
        "embed": emb,
        "layers": None,
    }


# ---------------------------------------------------------------------------
# activation / batch specs
# ---------------------------------------------------------------------------

def batch_spec(plan: ParallelPlan) -> PartitionSpec:
    """[B, S] token batches: batch over data(+pod)."""
    b = plan.batch_axes[0] if len(plan.batch_axes) == 1 else tuple(plan.batch_axes)
    return PartitionSpec(b, None)


def activation_spec(plan: ParallelPlan) -> PartitionSpec:
    """Residual stream [B, S, D]."""
    b = plan.batch_axes[0] if len(plan.batch_axes) == 1 else tuple(plan.batch_axes)
    seq = plan.model_axis if plan.seq_shard else None
    return PartitionSpec(b, seq, None)


def logits_spec(plan: ParallelPlan) -> PartitionSpec:
    b = plan.batch_axes[0] if len(plan.batch_axes) == 1 else tuple(plan.batch_axes)
    return PartitionSpec(b, None, "model")


def cache_entry_spec(plan: ParallelPlan, entry_shape, kv_heads: int,
                     mesh: Mesh):
    """KV cache [B, S, Hkv, hd] (+leading layer dim handled by caller)."""
    b = plan.batch_axes[0] if len(plan.batch_axes) == 1 else tuple(plan.batch_axes)
    bsz = entry_shape[0]
    bdiv = int(np.prod([mesh.shape[a] for a in plan.batch_axes]))
    if bsz % bdiv != 0:
        b = None
    m = plan.model_axis
    if m is not None and kv_heads % mesh.shape[m] == 0 and plan.cache_seq_axis is None:
        return PartitionSpec(b, None, m, None)
    if plan.cache_seq_axis is not None:
        return PartitionSpec(b, plan.cache_seq_axis, None, None)
    return PartitionSpec(b, None, None, None)
