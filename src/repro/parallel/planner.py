"""Auto-sharding planner: choose a ParallelPlan per (arch, shape, mesh)
cell from analytic memory estimates against the target HBM budget.

TPU v5e targets (per chip): 16 GiB HBM, 197 bf16 TFLOP/s, 819 GB/s HBM
bandwidth, ~50 GB/s ICI. The planner escalates sharding depth until the
estimate fits:

  train:  TP -> +FSDP(ZeRO-3) -> +seq-shard activations -> +grad_accum
  serve:  TP -> +2D weight sharding -> +KV-cache seq sharding
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.parallel.sharding import ParallelPlan, train_rules, serve_rules

HBM_BYTES = 16 * 1024**3
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _mesh_sizes(mesh) -> Tuple[int, int, int]:
    ax = dict(mesh.shape)
    pod = int(ax.get("pod", 1))
    return pod, int(ax["data"]), int(ax["model"])


def _bytes_per_param(dtype: str = "bfloat16") -> int:
    return 2 if "16" in dtype else 4


@dataclass
class MemoryEstimate:
    params: float
    opt_state: float
    activations: float
    kv_cache: float
    total: float

    def fits(self, budget: float = 0.9 * HBM_BYTES) -> bool:
        return self.total < budget


def estimate_train_memory(cfg: ModelConfig, shape: ShapeConfig, mesh,
                          fsdp: bool, seq_shard: bool, grad_accum: int,
                          moments_bytes: int = 1) -> MemoryEstimate:
    """Per-chip bytes. moments_bytes: 1 (int8 block-quantized AdamW
    moments, the default distributed-opt trick) or 4 (f32)."""
    pod, dp, tp = _mesh_sizes(mesh)
    n = cfg.n_params()
    bp = _bytes_per_param(cfg.dtype)
    model_shards = tp
    data_shards = pod * dp
    pshards = model_shards * (data_shards if fsdp else 1)
    params = n * bp / pshards
    # moments (m, v) + f32 grad accumulator only when grad_accum > 1
    opt = n * (2 * moments_bytes) / pshards
    grads = n * bp / pshards if grad_accum > 1 else 0.0

    # activations: with full remat we hold one residual per layer boundary
    # (+ the logits/softmax transient, counted at 3x logits bytes)
    b_local = shape.global_batch / data_shards / grad_accum
    toks = b_local * shape.seq_len
    seq_div = tp if seq_shard else 1
    resid = toks * cfg.d_model * bp / seq_div
    depth = cfg.n_layers + (cfg.n_encoder_layers or 0)
    acts = resid * (depth + 2)
    logits = toks * cfg.vocab_size * bp / tp * 3
    acts += logits
    total = params + opt + grads + acts
    return MemoryEstimate(params, opt + grads, acts, 0.0, total)


def estimate_serve_memory(cfg: ModelConfig, shape: ShapeConfig, mesh,
                          depth: int, cache_seq_shard: bool) -> MemoryEstimate:
    pod, dp, tp = _mesh_sizes(mesh)
    n = cfg.n_params()
    bp = _bytes_per_param(cfg.dtype)
    pshards = tp * ((pod * dp) if depth >= 2 else 1)
    params = n * bp / pshards

    # KV cache / recurrent state
    data_shards = pod * dp
    b_eff = max(shape.global_batch / data_shards, 1)
    if cfg.family == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_head_dim
        per_layer = b_eff * (nh * cfg.ssm_head_dim * cfg.ssm_state * 4 / tp
                             + 3 * d_in * bp)
        cache = per_layer * cfg.n_layers
    elif cfg.family == "hybrid":
        w = cfg.rglru_width or cfg.d_model
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if cfg.block_pattern[i % len(cfg.block_pattern)] == "attn")
        n_rec = cfg.n_layers - n_attn
        win = min(cfg.attn_window or shape.seq_len, shape.seq_len)
        kv = b_eff * win * cfg.n_kv_heads * cfg.resolved_head_dim * 2 * bp
        cache = n_attn * kv + n_rec * b_eff * w * (4 + 3 * bp)
    else:
        smax = min(cfg.attn_window, shape.seq_len) if cfg.attn_window else shape.seq_len
        seq_div = tp if cache_seq_shard else (
            tp if cfg.n_kv_heads % tp == 0 else 1)
        kv = b_eff * smax * cfg.n_kv_heads * cfg.resolved_head_dim * 2 * bp / seq_div
        depth_l = cfg.n_layers
        cache = kv * depth_l
        if cfg.is_encoder_decoder:
            cache += (b_eff * cfg.encoder_seq * cfg.n_kv_heads *
                      cfg.resolved_head_dim * 2 * bp) * cfg.n_layers

    acts = b_eff * max(shape.seq_len if shape.kind == "prefill" else 1, 1) \
        * cfg.d_model * bp * 4
    total = params + cache + acts
    return MemoryEstimate(params, 0.0, acts, cache, total)


def make_plan(cfg: ModelConfig, shape: ShapeConfig, mesh) -> ParallelPlan:
    """Escalating search for a fitting plan (see module docstring)."""
    pod, dp, tp = _mesh_sizes(mesh)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    if shape.kind == "train":
        # seq-shard (Megatron SP) is on from the start: it divides
        # activation residency by TP at no FLOP cost (ag/rs replaces the
        # TP all-reduce, same bytes), and dry-runs confirmed TP-only
        # plans blow the 16 GiB budget on activation temps. FSDP likewise:
        # any param whose model-parallel axis doesn't divide TP (e.g.
        # starcoder2's 24 heads on TP=16) falls back to replication, and
        # only the data-axis shard keeps its optimizer state bounded.
        #
        # grad_accum only shrinks *activations*; if params+opt alone
        # exceed the budget, escalating accum multiplies the FSDP
        # weight-gather collectives (16x observed on kimi-k2) for zero
        # memory benefit — so check the static part first.
        est1 = estimate_train_memory(cfg, shape, mesh, True, True, 1)
        static = est1.params + est1.opt_state
        budget = 0.9 * HBM_BYTES
        if static > budget:
            return ParallelPlan(
                rules=train_rules(True, batch_axes), batch_axes=batch_axes,
                remat="full", seq_shard=True, grad_accum=1,
                notes=f"train OVERBUDGET static={static/2**30:.1f}GiB "
                      f"est={est1.total/2**30:.1f}GiB (params+opt exceed "
                      f"HBM at this chip count; accum would only add "
                      f"gather traffic — needs the multi-pod mesh)",
            )
        for accum in (1, 4, 16):
            est = estimate_train_memory(cfg, shape, mesh, True, True, accum)
            if est.fits():
                return ParallelPlan(
                    rules=train_rules(True, batch_axes),
                    batch_axes=batch_axes,
                    remat="full",
                    seq_shard=True,
                    grad_accum=accum,
                    notes=f"train fsdp=True seq_shard=True "
                          f"accum={accum} est={est.total/2**30:.1f}GiB",
                )
        est = estimate_train_memory(cfg, shape, mesh, True, True, 16)
        return ParallelPlan(
            rules=train_rules(True, batch_axes), batch_axes=batch_axes,
            remat="full", seq_shard=True, grad_accum=16,
            notes=f"train OVERBUDGET est={est.total/2**30:.1f}GiB "
                  f"(needs more chips; fits on multi-pod? see EXPERIMENTS)",
        )

    # serving (prefill / decode)
    for depth, cache_seq in ((1, False), (2, False), (2, True)):
        est = estimate_serve_memory(cfg, shape, mesh, depth, cache_seq)
        if est.fits():
            break
    cache_axis = "model" if (
        cache_seq or (cfg.n_kv_heads and tp and cfg.n_kv_heads % tp != 0
                      and cfg.family not in ("ssm",))) else None
    return ParallelPlan(
        rules=serve_rules(depth, batch_axes),
        batch_axes=batch_axes,
        remat="none",
        seq_shard=False,
        cache_seq_axis=cache_axis,
        notes=f"serve depth={depth} cache_seq={cache_axis} "
              f"est={est.total/2**30:.1f}GiB",
    )
