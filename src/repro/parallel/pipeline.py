"""Pipeline parallelism over a mesh axis (GPipe schedule).

Maps the layer-stack dimension onto a mesh axis (on the multi-pod mesh,
the ``pod`` axis: stage boundaries align with the pod boundary, so the
only cross-pod traffic is one activation hand-off per microbatch per
step — the natural placement when inter-pod links are the scarcest).

Implementation: shard_map over the stage axis; each stage owns a
contiguous chunk of stacked layer parameters; a fori_loop runs the
classic (M + S - 1)-tick GPipe schedule with jax.lax.ppermute hand-offs.
Opt-in via ``pipeline_forward`` (the default multi-pod plan folds ``pod``
into data parallelism, which the dry-runs showed is collective-cheaper
for the assigned shapes; PP is the right trade once per-chip batch or
sequence length pushes activation memory past HBM).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_forward(
    block_fn: Callable,        # (layer_params, x) -> x
    stacked_params,            # pytree, leaves [L, ...]
    x_microbatches: jax.Array,  # [M, mb, ...] microbatched inputs
    mesh,
    stage_axis: str = "pod",
    extra_specs: P = P(),
) -> jax.Array:
    """Returns outputs [M, mb, ...] after all L layers, pipelined over
    ``stage_axis``. L must divide by the stage count; M >= stages for
    reasonable bubble fraction (bubble = (S-1)/(M+S-1))."""
    n_stages = int(mesh.shape[stage_axis])
    M = x_microbatches.shape[0]

    def stage_fn(wchunk, xs):
        s = jax.lax.axis_index(stage_axis)

        def run_chunk(x):
            def body(c, wl):
                return block_fn(wl, c), None
            out, _ = jax.lax.scan(body, x, wchunk)
            return out

        def tick(t, carry):
            inflight, outputs = carry
            # stage 0 injects microbatch t (if still in range)
            mb_idx = jnp.clip(t, 0, M - 1)
            fresh = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0,
                                                 keepdims=False)
            x_in = jnp.where(s == 0, fresh, inflight)
            y = run_chunk(x_in)
            # hand off to the next stage (ring; last stage's send wraps
            # to stage 0 and is ignored)
            y_next = jax.lax.ppermute(
                y, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            # last stage banks its result for microbatch t-(S-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
            bank = (s == n_stages - 1) & (t - (n_stages - 1) >= 0)
            outputs = jnp.where(
                bank,
                jax.lax.dynamic_update_index_in_dim(
                    outputs, y, out_idx, 0),
                outputs)
            return y_next, outputs

        outputs0 = jnp.zeros_like(xs)
        inflight0 = jnp.zeros_like(xs[0])
        _, outputs = jax.lax.fori_loop(
            0, M + n_stages - 1, tick, (inflight0, outputs0))
        # broadcast the last stage's outputs to every stage so the
        # result is replicated over the stage axis (loss runs anywhere)
        outputs = jax.lax.psum(
            jnp.where(s == n_stages - 1, outputs, jnp.zeros_like(outputs)),
            stage_axis)
        return outputs

    in_specs = (
        jax.tree.map(lambda _: P(stage_axis), stacked_params),
        P(*((None,) + tuple(extra_specs))),
    )
    out_specs = P(*((None,) + tuple(extra_specs)))
    from repro.parallel.context import shard_map_compat
    return shard_map_compat(
        stage_fn, mesh=mesh,
        in_specs=in_specs, out_specs=out_specs,
    )(stacked_params, x_microbatches)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
