"""Train / eval step construction: loss, grads, optimizer update, all
under pjit with plan-derived shardings; microbatch gradient accumulation
via lax.scan; registered in the C/R function registry so Compile ops can
rebuild the executable at restore.
"""
from __future__ import annotations

import functools
import json
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs import registry as cfg_registry
from repro.models import model as M
from repro.optim import (AdamWConfig, ScheduleConfig, init_opt_state,
                         abstract_opt_state, apply_updates, schedule_lr)
from repro.parallel import context as pctx
from repro.parallel.sharding import (ParallelPlan, activation_spec,
                                     batch_spec, logits_spec, tree_specs)
from repro.parallel.planner import make_plan
from repro.core.split_state import register_step_fn


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean CE over all tokens, f32. logits [B,S,V]; targets [B,S].

    Vocab-parallel formulation: the gold logit is extracted with a masked
    reduction over the (model-sharded) vocab axis rather than a gather —
    a gather along a sharded axis makes XLA all-gather the full [B,S,V]
    f32 logits (observed: +13 GiB/chip temp on starcoder2 train_4k); the
    reduction keeps every operand vocab-sharded and lowers to one tiny
    all-reduce (Megatron's vocab-parallel CE)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    v = lf.shape[-1]
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    gold_mask = vocab_ids == targets[..., None].astype(jnp.int32)
    gold = jnp.sum(jnp.where(gold_mask, lf, 0.0), axis=-1)
    return jnp.mean(lse - gold)


def make_call_options(plan: ParallelPlan, mesh) -> M.CallOptions:
    act = None
    logit = None
    if mesh is not None:
        aspec = activation_spec(plan)
        lspec = logits_spec(plan)

        def act_fn(x):
            if x.ndim != 3:
                return x
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, aspec))

        def logit_fn(x):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, lspec))

        act, logit = act_fn, logit_fn
    return M.CallOptions(remat=plan.remat, act_constraint=act,
                         logit_constraint=logit)


def make_tp_constraint(plan: ParallelPlan, mesh):
    """Interior TP constraint for layers._TP_CONSTRAINT: pin the
    model-parallel dim of MLP hidden / attention-head activations so the
    partitioner reshards activations (Megatron ag/rs) instead of
    all-gathering weights to full (EXPERIMENTS §Perf iter3)."""
    if mesh is None or plan.model_axis is None or not plan.interior_tp:
        return None
    m = plan.model_axis
    msize = int(mesh.shape[m])
    b = plan.batch_axes[0] if len(plan.batch_axes) == 1 \
        else tuple(plan.batch_axes)

    def fn(x, dim):
        nd = x.ndim
        if nd < 2:
            return x
        dim = dim % nd
        if x.shape[dim] % msize != 0:
            return x  # e.g. GQA kv heads < TP: stay replicated
        spec = [None] * nd
        spec[0] = b
        spec[dim] = m
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, PartitionSpec(*spec)))

    return fn


def make_loss_fn(cfg: ModelConfig, opts: M.CallOptions):
    def loss_fn(params, batch):
        logits, aux = M.forward_train(cfg, params, batch, opts)
        ce = cross_entropy(logits, batch["targets"])
        loss = ce + aux.get("moe_aux", 0.0)
        return loss, {"ce": ce, "moe_aux": aux.get("moe_aux", 0.0)}
    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    plan: ParallelPlan,
    opt_cfg: AdamWConfig,
    sched_cfg: ScheduleConfig,
    mesh=None,
) -> Callable:
    """Returns train_step(params, opt_state, batch, step, lr_scale)
    -> (params, opt_state, metrics). Pure; jit-able; grad accumulation
    per plan.grad_accum."""
    opts = make_call_options(plan, mesh)
    loss_fn = make_loss_fn(cfg, opts)
    accum = max(plan.grad_accum, 1)

    def train_step(params, opt_state, batch, step, lr_scale):
        if accum == 1:
            (loss, parts), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                     g_acc, g)
                return (g_acc, l_acc + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss_sum), _ = jax.lax.scan(micro, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            parts = {"ce": loss, "moe_aux": jnp.zeros((), jnp.float32)}

        lr = schedule_lr(sched_cfg, step) * lr_scale
        params, opt_state, om = apply_updates(params, grads, opt_state,
                                              opt_cfg, lr)
        metrics = {"loss": loss, **parts, **om,
                   "step": step.astype(jnp.int32) + 1}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, plan: ParallelPlan, mesh=None):
    opts = make_call_options(plan, mesh)
    loss_fn = make_loss_fn(cfg, opts)

    def eval_step(params, batch):
        loss, parts = loss_fn(params, batch)
        return {"loss": loss, **parts}

    return eval_step


# ---------------------------------------------------------------------------
# sharded jit assembly
# ---------------------------------------------------------------------------

def train_state_shardings(cfg: ModelConfig, plan: ParallelPlan, mesh,
                          opt_cfg: AdamWConfig):
    """(param_shardings, opt_shardings) NamedSharding pytrees."""
    ab_params = M.init_abstract(cfg)
    logical = M.logical_specs(cfg)
    pspecs = tree_specs(plan, logical, ab_params, mesh)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

    from repro.optim import opt_logical_specs
    ab_opt = abstract_opt_state(ab_params, opt_cfg)
    olog = opt_logical_specs(logical, opt_cfg)
    ospecs = tree_specs(plan, olog, ab_opt, mesh)
    oshard = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs)
    return pshard, oshard


def batch_shardings(plan: ParallelPlan, mesh, batch_spec_tree):
    bspec = batch_spec(plan)

    def f(ab):
        nd = len(ab.shape)
        spec = PartitionSpec(*(list(bspec) + [None] * (nd - 2))[:nd])
        return NamedSharding(mesh, spec)

    return jax.tree.map(f, batch_spec_tree)


class ContextualJit:
    """Wraps a jitted callable so that tracing/lowering always happens
    inside the mesh context (MoE shard_map and the interior TP constraint
    read it at trace time)."""

    def __init__(self, jitted, mesh, plan: ParallelPlan):
        self.jitted = jitted
        self.mesh = mesh
        self.plan = plan

    def _enter(self):
        from repro.models import layers as L
        tok = L.set_tp_constraint(make_tp_constraint(self.plan, self.mesh))
        return tok

    def __call__(self, *args, **kw):
        from repro.models import layers as L
        tok = self._enter()
        try:
            with pctx.mesh_context(self.mesh, self.plan.batch_axes,
                                   self.plan.model_axis):
                return self.jitted(*args, **kw)
        finally:
            L._TP_CONSTRAINT.reset(tok)

    def lower(self, *args, **kw):
        from repro.models import layers as L
        tok = self._enter()
        try:
            with pctx.mesh_context(self.mesh, self.plan.batch_axes,
                                   self.plan.model_axis):
                return self.jitted.lower(*args, **kw)
        finally:
            L._TP_CONSTRAINT.reset(tok)


def jit_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                   plan: Optional[ParallelPlan] = None,
                   opt_cfg: Optional[AdamWConfig] = None,
                   sched_cfg: Optional[ScheduleConfig] = None,
                   donate: bool = True):
    """Build the sharded, jittable train step + its input specs."""
    plan = plan or make_plan(cfg, shape, mesh)
    opt_cfg = opt_cfg or AdamWConfig(
        quantize_moments=cfg.n_params() > 5e10)
    sched_cfg = sched_cfg or ScheduleConfig()
    fn = make_train_step(cfg, plan, opt_cfg, sched_cfg, mesh)

    pshard, oshard = train_state_shardings(cfg, plan, mesh, opt_cfg)
    binputs = train_input_specs(cfg, shape)
    bshard = batch_shardings(plan, mesh, binputs)
    scalar = NamedSharding(mesh, PartitionSpec())

    jitted = jax.jit(
        fn,
        in_shardings=(pshard, oshard, bshard, scalar, scalar),
        out_shardings=(pshard, oshard, None),
        donate_argnums=(0, 1) if donate else (),
    )
    wrapped = ContextualJit(jitted, mesh, plan)
    return wrapped, dict(plan=plan, opt_cfg=opt_cfg, sched_cfg=sched_cfg,
                         param_shardings=pshard, opt_shardings=oshard,
                         batch_shardings=bshard, input_specs=binputs)


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for a training batch."""
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.is_encoder_decoder:
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.frontend_dim), jnp.float32)
    return specs


# ---------------------------------------------------------------------------
# C/R function-registry builders (Compile ops resolve here)
# ---------------------------------------------------------------------------

def _plan_from_key(cfg, shape, mesh, plan_key: str) -> ParallelPlan:
    plan = make_plan(cfg, shape, mesh)
    if plan_key:
        plan = plan.with_(**json.loads(plan_key))
    return plan


@register_step_fn("train_step")
def _build_train_step(arch: str, shape_key: str, plan_key: str, lower):
    cfg = cfg_registry.resolve_config(arch)
    shape = cfg_registry.get_shape(shape_key)
    mesh = lower.mesh
    plan = _plan_from_key(cfg, shape, mesh, plan_key)
    jitted, _ = jit_train_step(cfg, shape, mesh, plan=plan)
    return jitted
