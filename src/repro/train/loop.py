"""Trainer: the end-to-end integration of the paper's split-state C/R
with the training substrate.

Normal operation:  every runtime-mutating call (mesh, compile, data
advance, schedule touch) goes through the logged LowerHalf API; semantic
state lives in the UpperHalf; CheckpointManager snapshots the upper half
in the background.

Crash:             the process (or pod) dies. Nothing to do.

Restore:           Trainer.restore() = fresh LowerHalf + op-log replay
(recompiles the step executable, reapplies schedule/data ops) + upper
half rematerialized onto the (possibly different!) mesh. Continuation is
bitwise-identical to the uninterrupted run — tested.
"""
from __future__ import annotations

import json
import warnings
from dataclasses import asdict, dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.api import register_app_kind
from repro.api.app import RestoreContext
from repro.api.session import CheckpointSession
from repro.configs import registry as cfg_registry
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import CheckpointManager, LowerHalf, OpLog, UpperHalf
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import model as M
from repro.optim import (AdamWConfig, ScheduleConfig, abstract_opt_state,
                         init_opt_state, opt_logical_specs)
from repro.parallel.planner import make_plan
from repro.train import step as step_lib


@dataclass
class TrainJob:
    arch: str                  # registry id, or "<id>-smoke"
    shape_key: str
    init_seed: int = 0
    data_seed: int = 1234
    plan_overrides: Optional[Dict[str, Any]] = None

    @property
    def plan_key(self) -> str:
        return json.dumps(self.plan_overrides) if self.plan_overrides else ""


def _resolve_cfg(arch: str) -> ModelConfig:
    return cfg_registry.resolve_config(arch)


class Trainer:
    def __init__(self, job: TrainJob, mesh_shape, mesh_axes,
                 manager: Optional[CheckpointManager] = None,
                 _restored=None):
        self.job = job
        self.cfg = _resolve_cfg(job.arch)
        self.shape = cfg_registry.get_shape(job.shape_key)
        self.manager = manager

        if _restored is None:
            self.lower = LowerHalf()
            self.lower.mesh_create(mesh_shape, mesh_axes)
            self.vexec = self.lower.compile_step(
                "train_step", job.arch, job.shape_key, job.plan_key)
        else:
            self.lower, self.vexec = _restored

        mesh = self.lower.mesh
        self.plan = make_plan(self.cfg, self.shape, mesh)
        if job.plan_overrides:
            self.plan = self.plan.with_(**job.plan_overrides)
        self.opt_cfg = AdamWConfig(quantize_moments=self.cfg.n_params() > 5e10)
        self.pshard, self.oshard = step_lib.train_state_shardings(
            self.cfg, self.plan, mesh, self.opt_cfg)

        # n_shards is a DATA-layout constant (one shard per batch row),
        # never a topology property: batches must be bit-identical across
        # mesh shapes or elastic restore would silently change the data
        # stream (caught by tests/test_elastic_multidev.py).
        dcfg = DataConfig(
            seed=job.data_seed, vocab_size=self.cfg.vocab_size,
            seq_len=self.shape.seq_len, global_batch=self.shape.global_batch,
            n_shards=self.shape.global_batch,
            frames=self.cfg.encoder_seq if self.cfg.is_encoder_decoder else 0,
            frame_dim=self.cfg.frontend_dim)
        self.pipeline = TokenPipeline(dcfg)
        if self.lower.data_assignment:
            self.pipeline.reassign(self.lower.data_assignment)

        self.upper = UpperHalf()
        self._binputs = step_lib.train_input_specs(self.cfg, self.shape)
        self._bshard = step_lib.batch_shardings(self.plan, mesh, self._binputs)

    # --- state construction -------------------------------------------------

    def init_state(self) -> None:
        """Fresh start: initialize params/opt on-mesh and register the
        upper half."""
        rng = jax.random.PRNGKey(self.job.init_seed)
        init = jax.jit(lambda r: M.init_params(self.cfg, r),
                       out_shardings=self.pshard)
        params = init(rng)
        opt_state = jax.jit(
            lambda p: init_opt_state(p, self.opt_cfg),
            out_shardings=self.oshard)(params)
        logical = M.logical_specs(self.cfg)
        self.upper.register("params", "params", params, logical)
        self.upper.register("opt_state", "opt_state", opt_state,
                            opt_logical_specs(logical, self.opt_cfg))
        self.upper.register("step", "step", np.int64(0))
        self.upper.register("data_cursor", "data_cursor", np.int64(0))
        self.upper.register("rng_seed", "rng",
                            np.int64(self.job.init_seed))

    # --- stepping ---------------------------------------------------------

    def _device_batch(self, batch_np):
        return {k: jax.device_put(v, self._bshard[k])
                for k, v in batch_np.items()}

    def train_steps(self, n: int) -> Dict[str, float]:
        fn = self.lower.executable(self.vexec)
        params = self.upper.get("params")
        opt_state = self.upper.get("opt_state")
        step = int(self.upper.get("step"))
        cursor = int(self.upper.get("data_cursor"))
        lr_scale = jnp.float32(
            self.lower.schedule_overrides.get("lr_scale", 1.0))
        metrics = {}
        for _ in range(n):
            batch = self._device_batch(self.pipeline.batch_at(cursor))
            params, opt_state, metrics = fn(
                params, opt_state, batch, jnp.int32(step), lr_scale)
            step += 1
            cursor += 1
            self.lower.data_advance(1)
        self.upper.update("params", params)
        self.upper.update("opt_state", opt_state)
        self.upper.update("step", np.int64(step))
        self.upper.update("data_cursor", np.int64(cursor))
        return {k: float(np.asarray(jax.device_get(v)))
                for k, v in metrics.items()}

    # --- CheckpointableApp protocol (repro.api) -----------------------------

    def checkpoint_state(self) -> UpperHalf:
        return self.upper

    def checkpoint_step(self) -> int:
        return int(self.upper.get("step"))

    def runtime_log(self) -> OpLog:
        return self.lower.oplog

    def job_meta(self) -> Dict[str, Any]:
        return {"kind": "train",
                "arch": self.job.arch, "shape_key": self.job.shape_key,
                "plan_key": self.job.plan_key,
                "init_seed": self.job.init_seed,
                "data_seed": self.job.data_seed}

    def bind(self, restore: RestoreContext) -> None:
        """CheckpointableApp.bind: rematerialize the upper half onto
        this incarnation's (possibly different) mesh. Expects the
        context's lower half already replayed — the "train" binder
        orders the phases."""
        inc = restore.incarnation()
        ab_params = M.init_abstract(self.cfg)
        logical = M.logical_specs(self.cfg)
        params = inc.bind("params", ab_params, plan=self.plan,
                          logical=logical)
        ab_opt = abstract_opt_state(ab_params, self.opt_cfg)
        olog = opt_logical_specs(logical, self.opt_cfg)
        opt_state = inc.bind("opt_state", ab_opt, plan=self.plan,
                             logical=olog)
        self.upper.register("params", "params", params, logical)
        self.upper.register("opt_state", "opt_state", opt_state, olog)
        self.upper.register("step", "step", np.int64(inc.scalar("step")))
        self.upper.register("data_cursor", "data_cursor",
                            np.int64(inc.scalar("data_cursor")))
        self.upper.register("rng_seed", "rng",
                            np.int64(inc.scalar("rng_seed")))
        inc.release()   # host payload rebound on device; don't hold the
        self.incarnation = inc  # checkpoint's RAM for the life of the run

    # --- checkpoint / restore ------------------------------------------------

    def save(self, block: bool = True) -> None:
        assert self.manager is not None
        self.manager.save(self.checkpoint_step(), self.checkpoint_state(),
                          self.runtime_log(), block=block,
                          job_meta=self.job_meta())

    def snapshot(self):
        """Non-blocking checkpoint at the current step boundary: pays
        only the device→staging capture; delta encode + backend writes
        overlap the next train_steps() on the pipeline threads. Returns
        the SnapshotHandle (None if dropped under "skip" backpressure).

        Same payload a ``CheckpointSession`` wrapping this trainer would
        take — the protocol methods are the single source; the trainer
        deliberately does NOT hold a session of its own (one session
        owns an app's lifecycle, and that session is the caller's)."""
        assert self.manager is not None
        return self.manager.save(self.checkpoint_step(),
                                 self.checkpoint_state(),
                                 self.runtime_log(), block=False,
                                 job_meta=self.job_meta())

    def apply_reassignment(self, assignment) -> None:
        """Move data-shard ownership between hosts, as one *logged*
        operation: the DataReassign goes through the lower half (so a
        later restart replays it — the supervisor's hot-spare and
        straggler rebalances survive crashes) and the live pipeline
        adopts it immediately. Batch contents are unchanged — shard
        layout is a data constant, ownership is topology — so training
        stays token-identical across any reassignment."""
        self.lower.data_reassign(assignment)
        self.pipeline.reassign(list(map(tuple, assignment)))

    def train(self, n_steps: int, snapshot_every: Optional[int] = None,
              ) -> Dict[str, float]:
        """Step loop with overlapped checkpointing: snapshots are
        captured at step boundaries and drain in the background."""
        metrics: Dict[str, float] = {}
        for i in range(1, n_steps + 1):
            metrics = self.train_steps(1)
            if snapshot_every and self.manager is not None \
                    and i % snapshot_every == 0:
                self.snapshot()
        if self.manager is not None and snapshot_every:
            self.manager.wait()
        return metrics

    @classmethod
    def restore(cls, manager: CheckpointManager,
                mesh_factory: Optional[Callable] = None,
                step: Optional[int] = None,
                decode_workers: Optional[int] = None,
                rewrite_op: Optional[Callable] = None) -> "Trainer":
        """Legacy shim: delegates to the public session API
        (``repro.api.CheckpointSession.restore``), which resolves the
        "train" binder below through the app-kind registry. Phase
        timings land on ``trainer.incarnation.timings``; ``rewrite_op``
        transforms logged ops before replay (elastic re-shard)."""
        warnings.warn(
            "Trainer.restore is a legacy shim; use "
            "repro.api.CheckpointSession.restore", DeprecationWarning,
            stacklevel=2)
        return CheckpointSession.from_manager(manager).restore(
            step=step, expect_kind="train", mesh_factory=mesh_factory,
            rewrite_op=rewrite_op, decode_workers=decode_workers)

    # --- observability ---------------------------------------------------------

    def params_digest(self) -> str:
        import hashlib
        h = hashlib.blake2b(digest_size=16)
        for path, arr in sorted(
                (p, v) for p, v in
                _flatten(self.upper.get("params"))):
            h.update(path.encode())
            h.update(np.ascontiguousarray(
                np.asarray(jax.device_get(arr))).tobytes())
        return h.hexdigest()


def _flatten(tree):
    from repro.core.split_state import flatten_with_paths
    return flatten_with_paths(tree)


@register_app_kind("train")
def _restore_trainer(restore: RestoreContext) -> Trainer:
    """The "train" restore binder: the Incarnation lifecycle, trainer
    flavor — materialize the delta chain (parallel leaf decode), fresh
    lower half + op-log replay (recompile, reapply runtime ops), then
    ``Trainer.bind`` rematerializes the upper half on the (new) mesh."""
    inc = restore.incarnation()
    inc.materialize()
    jm = restore.job
    job = TrainJob(arch=jm["arch"], shape_key=jm["shape_key"],
                   init_seed=jm.get("init_seed", 0),
                   data_seed=jm.get("data_seed", 1234),
                   plan_overrides=json.loads(jm["plan_key"])
                   if jm.get("plan_key") else None)

    # 1-2: fresh lower half + replay (recompile, reapply runtime ops)
    lower = inc.build_lower()
    vexec = inc.last_compile("train_step")
    assert vexec is not None, "no train_step Compile in the log"

    t = Trainer(job, None, None, manager=restore.manager,
                _restored=(lower, vexec))
    t.bind(restore)   # 3: upper half onto the (new) mesh
    return t
