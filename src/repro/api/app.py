"""CheckpointableApp: the one protocol between applications and C/R.

The paper's application never knows which checkpoint package is
underneath (§V); here the application never knows which *mechanism* is
underneath. An app declares its semantic state (upper-half entries with
logical axes), names itself via ``job_meta()["kind"]``, and rebinds
after a restore through ``bind(RestoreContext)`` — snapshotting,
delta-chain policy, backend choice, incarnation replay and supervision
all come for free from ``CheckpointSession``. The trainer, the serving
engine and ``examples/checkpointable_pipeline.py`` all speak exactly
this protocol; nothing workload-specific leaks into the session.

Required surface::

    checkpoint_state() -> UpperHalf   # entries + logical axes, current
    checkpoint_step()  -> int         # the snapshot's step id
    job_meta()         -> dict        # must carry "kind" (the registry key)
    bind(restore)      -> None        # rebind state from a RestoreContext

Optional hooks, discovered by name::

    session_state() -> UpperHalf      # dynamic per-snapshot state; takes
                                      # precedence over checkpoint_state
    runtime_log()   -> OpLog          # logged lower-half history to ride
                                      # along (default: empty log)
    quiesce()       -> None           # flush/stop work before teardown —
                                      # the supervisor calls it before
                                      # replacing a runner
    apply_reassignment(assignment)    # adopt + log a data-shard move
                                      # (supervisor rebalances)
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Protocol, \
    runtime_checkable

from repro.api.errors import PolicyError

REQUIRED_METHODS = ("checkpoint_state", "checkpoint_step", "job_meta",
                    "bind")
OPTIONAL_HOOKS = ("session_state", "runtime_log", "quiesce",
                  "apply_reassignment")


@runtime_checkable
class CheckpointableApp(Protocol):
    """Structural protocol — apps implement it, they never inherit it."""

    def checkpoint_state(self) -> Any: ...          # -> UpperHalf

    def checkpoint_step(self) -> int: ...

    def job_meta(self) -> Dict[str, Any]: ...

    def bind(self, restore: "RestoreContext") -> None: ...


def validate_app(app: Any) -> None:
    """Protocol conformance with a nameable error, not an AttributeError
    three layers deep at the first snapshot."""
    missing = [n for n in REQUIRED_METHODS
               if not callable(getattr(app, n, None))]
    if missing:
        raise PolicyError(
            f"{type(app).__name__} is not a CheckpointableApp: missing "
            f"{missing}; the protocol requires {list(REQUIRED_METHODS)} "
            f"(optional hooks: {list(OPTIONAL_HOOKS)})")
    meta = app.job_meta()
    if not isinstance(meta, dict) or "kind" not in meta:
        raise PolicyError(
            f"{type(app).__name__}.job_meta() must be a dict with a "
            "'kind' key — restore resolves the app binder from it "
            "(register one with repro.api.register_app_kind)")


class RestoreContext:
    """One restore, as the application sees it.

    Wraps the core ``Incarnation`` lifecycle behind a surface an app can
    use without importing ``repro.core``: ``scalar``/``tree``/``paths``
    pull entries out of the materialized payload (driving materialize →
    replay lazily on first touch), ``lower``/``mesh`` expose the
    replayed runtime, ``release()`` drops the host payload when every
    entry is rebound. Binders that need the full phase control (elastic
    rewrites, skipped entries) call ``incarnation()`` once with their
    overrides before touching any helper.
    """

    def __init__(self, manager, step: int, job: Dict[str, Any], *,
                 mesh_factory: Optional[Callable] = None,
                 rewrite_op: Optional[Callable] = None,
                 decode_workers: Optional[int] = None,
                 streaming: bool = False,
                 lazy_kinds=None) -> None:
        self.manager = manager
        self.step = step
        self.job = dict(job)
        self.mesh_factory = mesh_factory
        self.rewrite_op = rewrite_op
        self.decode_workers = decode_workers
        self.streaming = streaming
        self.lazy_kinds = lazy_kinds
        self._inc = None

    # --- advanced surface (binders) ------------------------------------

    def incarnation(self, *, skip_entries: Optional[List[str]] = None,
                    rewrite_op: Optional[Callable] = None,
                    mesh_factory: Optional[Callable] = None):
        """The underlying ``Incarnation``, constructed once. Explicit
        arguments override the session-level options (a binder composing
        its own op rewrite passes the composed callable here)."""
        if self._inc is None:
            from repro.core.incarnation import Incarnation
            self._inc = Incarnation(
                self.manager, step=self.step,
                mesh_factory=mesh_factory or self.mesh_factory,
                rewrite_op=rewrite_op or self.rewrite_op,
                decode_workers=self.decode_workers,
                skip_entries=skip_entries,
                streaming=self.streaming,
                lazy_kinds=self.lazy_kinds)
        return self._inc

    def _ready(self):
        inc = self.incarnation()
        if inc.restored is None:
            inc.materialize()
        if inc.lower is None:
            inc.build_lower()
        return inc

    # --- simple surface (apps) -----------------------------------------

    def scalar(self, name: str):
        """A plain scalar entry (step counters, cursors)."""
        return self._ready().scalar(name)

    def paths(self, name: str) -> Dict[str, Any]:
        """Raw leaf-path -> host-array map for one entry."""
        return self._ready().entry_paths(name)

    def tree(self, name: str, template=None, plan=None, logical=None):
        """One entry as a pytree: with a ``template``, rebound onto this
        incarnation's mesh (sharded by the leaves' logical axes); without
        one, rebuilt structurally from the recorded paths — for state
        whose shape is data (queues, dynamic dicts)."""
        inc = self._ready()
        if template is None:
            from repro.core.split_state import tree_from_paths
            return tree_from_paths(inc.entry_paths(name))
        return inc.bind(name, template, plan=plan, logical=logical)

    def has(self, name: str) -> bool:
        return self._ready().has_entry(name)

    def release(self) -> None:
        """Drop the decoded host payload (call once every entry is
        rebound — keeps the checkpoint's RAM out of the resumed run)."""
        if self._inc is not None:
            self._inc.release()

    # --- replayed runtime ----------------------------------------------

    @property
    def lower(self):
        return self._ready().lower

    @property
    def mesh(self):
        return self._ready().mesh_or_none()

    @property
    def timings(self) -> Dict[str, float]:
        return self._inc.timings if self._inc is not None else {}
