"""Typed exception hierarchy for the public checkpoint API.

One base — ``CheckpointError`` — under which everything the checkpoint
machinery raises *on purpose* is classified, so a caller holding a
``CheckpointSession`` can write one ``except CheckpointError`` instead
of guessing which layer's ``ValueError``/``RuntimeError`` might
surface. Every subclass ALSO inherits the builtin type its raise sites
historically used (``ValueError`` / ``RuntimeError``), so existing
``except``/``pytest.raises`` call sites keep working unchanged — the
hierarchy adds ways to catch, it never removes one.

    CheckpointError
    ├── PolicyError          (ValueError)   bad Policy / store spec / app
    ├── BackendUnavailable   (RuntimeError) storage cannot serve a commit
    ├── SnapshotError        (RuntimeError) capture/encode pipeline failure
    ├── RestoreError         (ValueError)   checkpoint cannot be decoded
    ├── MigrationError       (RuntimeError) planned move cannot execute
    ├── LifecycleError       (RuntimeError) Incarnation phase out of order
    └── SupervisorError      (RuntimeError) failure loop cannot execute

``StaleHandleError`` predates the hierarchy and stays a ``KeyError``
subclass (callers index handle tables with it); it is re-exported here
so app code never imports ``repro.core`` for an exception type.
"""
from __future__ import annotations


class CheckpointError(Exception):
    """Base of every typed error the checkpoint API raises."""


class PolicyError(CheckpointError, ValueError):
    """Invalid configuration: a bad ``Policy`` field combination, a
    malformed backend store spec, an unknown registry key, or an object
    that does not satisfy the ``CheckpointableApp`` protocol."""


class BackendUnavailable(CheckpointError, RuntimeError):
    """A storage backend cannot serve what a commit or read requires
    (e.g. a manifest referencing blobs no live host can serve)."""


class SnapshotError(CheckpointError, RuntimeError):
    """The snapshot pipeline could not capture or encode a checkpoint."""


class RestoreError(CheckpointError, ValueError):
    """A committed checkpoint could not be decoded or rematerialized
    (unknown manifest format, broken delta chain, missing metadata)."""


class MigrationError(CheckpointError, RuntimeError):
    """A planned live move cannot execute: source/target is not a
    serving-style engine, an unknown engine name, or a routing state
    that would drop requests."""


# Re-exported members defined in their home modules (they are raised
# from layers that must not import upward). StaleHandleError is
# imported at the END of this module, AFTER every class above exists:
# repro.core modules import from here at their own module top, so the
# core -> api.errors -> core.virtual_ids cycle re-enters this module
# partially initialized — by then the classes it needs are defined.
from repro.core.virtual_ids import StaleHandleError  # noqa: E402,F401


def __getattr__(name: str):
    # LifecycleError / SupervisorError live in modules that themselves
    # import CheckpointError from here — resolve them lazily so this
    # module never imports them at load time.
    if name == "LifecycleError":
        from repro.core.incarnation import LifecycleError
        return LifecycleError
    if name == "SupervisorError":
        from repro.core.supervisor import SupervisorError
        return SupervisorError
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
