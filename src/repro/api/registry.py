"""URI-addressed registries: storage backends, codecs, and app kinds.

The paper's §V claim — the same split-process design serves multiple
checkpoint packages without the application caring — becomes literal
here: a backend is a *string* (``localfs:/path`` is the CRIU-analogue,
``sharded:/path?hosts=4&replicate=1`` the DMTCP-analogue), and swapping
packages is a one-string change at the call site. Third-party backends
register a factory under a new scheme without touching ``repro.core``:

    @register_backend("s3")
    def _s3(path, *, region="us-east-1"):
        return S3Backend(path, region=region)

App kinds close the same loop on the restore side: a checkpoint's
``job_meta()["kind"]`` names the binder that rebuilds the application
from a ``RestoreContext``, so ``CheckpointSession.restore`` works for
any workload that registered itself — the trainer, the serving engine,
and anything a user writes against the protocol alone.
"""
from __future__ import annotations

import importlib
import inspect
from typing import Any, Callable, Dict, Tuple

from repro.api.errors import PolicyError

# ---------------------------------------------------------------------------
# backends: scheme -> factory(path, **params)
# ---------------------------------------------------------------------------

BACKEND_SCHEMES: Dict[str, Callable[..., Any]] = {}


def _registrant(fn: Callable) -> str:
    mod = getattr(fn, "__module__", None) or "?"
    name = getattr(fn, "__qualname__", None) or repr(fn)
    return f"{mod}.{name}"


def register_backend(scheme: str, *, replace: bool = False) -> Callable:
    """Register ``factory(path, **params) -> CheckpointBackend`` under a
    URI scheme. Query parameters arrive as strings; the factory owns
    their conversion (raise ``PolicyError`` on a bad value).

    A scheme is a global name: registering a *different* factory under a
    taken scheme raises ``PolicyError`` instead of silently shadowing
    whoever got there first (re-registering the same callable — e.g. a
    module reimported under test — is a no-op). Pass ``replace=True`` to
    override deliberately."""
    def deco(factory: Callable) -> Callable:
        existing = BACKEND_SCHEMES.get(scheme)
        if existing is not None and existing is not factory and not replace:
            raise PolicyError(
                f"backend scheme {scheme!r} is already registered by "
                f"{_registrant(existing)}; pick a different scheme, or "
                f"pass register_backend({scheme!r}, replace=True) to "
                "override it deliberately")
        BACKEND_SCHEMES[scheme] = factory
        return factory
    return deco


def parse_store_spec(spec: str) -> Tuple[str, str, Dict[str, str]]:
    """``scheme:/path?k=v&...`` -> (scheme, path, params).

    One key is special: ``over=`` swallows the *rest of the query
    string* verbatim, so a whole nested store spec — query and all —
    can ride inside another one (``cached:/ssd?over=sharded:/remote?
    hosts=4&replicate=1``). That makes ``over`` necessarily the last
    parameter of its level; the outer split already stops at the first
    ``?``, so the nested spec's own ``?`` and ``&`` survive intact.

    Raises ``PolicyError`` with the expected shape spelled out — a store
    spec is user-facing configuration, so the error must be actionable.
    """
    shape = ("a store spec looks like 'scheme:/path[?key=value&...]', "
             f"e.g. 'localfs:/tmp/job' (known schemes: "
             f"{sorted(BACKEND_SCHEMES)})")
    if not isinstance(spec, str) or ":" not in spec:
        raise PolicyError(f"malformed backend spec {spec!r}: {shape}")
    scheme, rest = spec.split(":", 1)
    path, _, query = rest.partition("?")
    if not scheme or not path:
        raise PolicyError(f"malformed backend spec {spec!r}: {shape}")
    params: Dict[str, str] = {}
    if query:
        pieces = query.split("&")
        for i, piece in enumerate(pieces):
            key, eq, value = piece.partition("=")
            if not key or not eq:
                raise PolicyError(
                    f"malformed backend spec {spec!r}: query piece "
                    f"{piece!r} is not 'key=value'; {shape}")
            if key == "over":
                params[key] = "&".join([value] + pieces[i + 1:])
                break
            params[key] = value
    return scheme, path, params


def resolve_backend(spec: str, defaults: Dict[str, str] = None):
    """Build a backend from a store spec through the scheme registry."""
    scheme, path, params = parse_store_spec(spec)
    factory = BACKEND_SCHEMES.get(scheme)
    if factory is None:
        raise PolicyError(
            f"unknown backend scheme {scheme!r} in {spec!r} (known: "
            f"{sorted(BACKEND_SCHEMES)}); register a factory with "
            "repro.api.register_backend")
    merged = dict(defaults or {})
    merged.update(params)
    sig = inspect.signature(factory)
    accepts_kw = any(p.kind is inspect.Parameter.VAR_KEYWORD
                     for p in sig.parameters.values())
    allowed = {n for n, p in sig.parameters.items()
               if p.kind in (inspect.Parameter.KEYWORD_ONLY,
                             inspect.Parameter.POSITIONAL_OR_KEYWORD)}
    allowed.discard("path")
    unknown = sorted(set(params) - allowed) if not accepts_kw else []
    if unknown:
        raise PolicyError(
            f"backend spec {spec!r}: unknown parameter(s) {unknown}; "
            f"{scheme!r} accepts {sorted(allowed)}")
    if not accepts_kw:
        merged = {k: v for k, v in merged.items() if k in allowed}
    return factory(path, **merged)


def _as_int(spec_key: str, value) -> int:
    try:
        return int(value)
    except (TypeError, ValueError):
        raise PolicyError(
            f"store parameter {spec_key}={value!r} must be an integer")


def _as_bool(spec_key: str, value) -> bool:
    if isinstance(value, bool):
        return value
    v = str(value).lower()
    if v in ("1", "true", "yes", "on"):
        return True
    if v in ("0", "false", "no", "off"):
        return False
    raise PolicyError(
        f"store parameter {spec_key}={value!r} must be a boolean "
        "(1/0/true/false)")


@register_backend("localfs")
def _localfs_backend(path: str, *, fsync="1"):
    """CRIU-analogue: one image directory, atomic-rename commits."""
    from repro.core.backends.localfs import LocalFSBackend
    return LocalFSBackend(path, fsync=_as_bool("fsync", fsync))


@register_backend("sharded")
def _sharded_backend(path: str, *, hosts="4", replicate="0", writers="4",
                     fsync="1"):
    """DMTCP-analogue: blobs hashed to N virtual hosts, coordinator
    manifest, optional peer replication."""
    from repro.core.backends.sharded import ShardedBackend
    n_hosts = _as_int("hosts", hosts)
    n_writers = _as_int("writers", writers)
    # range checks here, not deep in the write pipeline: hosts=0 would
    # surface as a modulo-by-zero at the first blob hash, writers=0 as
    # a raw ThreadPoolExecutor ValueError
    if n_hosts < 1:
        raise PolicyError(f"store parameter hosts={n_hosts} must be >= 1")
    if n_writers < 1:
        raise PolicyError(
            f"store parameter writers={n_writers} must be >= 1")
    return ShardedBackend(path, n_hosts=n_hosts,
                          replicate=_as_bool("replicate", replicate),
                          writers=n_writers,
                          fsync=_as_bool("fsync", fsync))


@register_backend("cached")
def _cached_backend(path: str, *, over="", fsync="0"):
    """Local read-through blob cache over any other registered store:
    ``cached:/ssd-cache?over=sharded:/remote?hosts=4``. Reads hit the
    local tier first and warm it on a miss; streaming restore fetches
    from both tiers and primes the cache as it goes."""
    from repro.core.backends.cached import CachedBackend
    if not over:
        raise PolicyError(
            "store scheme 'cached:' needs the store it caches: "
            "'cached:/local-cache?over=<inner spec>', e.g. "
            "'cached:/ssd/cache?over=sharded:/remote?hosts=4' (over= "
            "swallows the rest of the spec, so it must come last)")
    return CachedBackend(path, resolve_backend(over),
                         fsync=_as_bool("fsync", fsync))


# ---------------------------------------------------------------------------
# codecs: per-entry-kind payload encodings (delta.CODECS is the store)
# ---------------------------------------------------------------------------

def register_codec(name: str, encode: Callable, decode: Callable) -> None:
    """Register a payload codec usable from ``Policy(codecs={kind: name})``.

    ``encode(array) -> {part_name: bytes-like}``; ``decode(parts, dtype,
    shape) -> np.ndarray`` — the same contract as the built-in ``int8``
    moment-quantization codec in ``core.delta``."""
    from repro.core import delta
    delta.CODECS[name] = (encode, decode)


def available_codecs():
    from repro.core import delta
    return sorted(delta.CODECS)


# ---------------------------------------------------------------------------
# app kinds: job_meta()["kind"] -> binder(RestoreContext, **kw) -> app
# ---------------------------------------------------------------------------

APP_KINDS: Dict[str, Callable[..., Any]] = {}

# Built-in kinds resolve lazily: repro.api must not import the app
# modules at load time (they import repro.api), so the module that owns
# each built-in binder is imported on first restore of that kind.
_LAZY_KINDS = {
    "train": "repro.train.loop",
    "serving": "repro.serving.engine",
}


def register_app_kind(kind: str, *, replace: bool = False) -> Callable:
    """Register the restore binder for a checkpoint kind. The binder
    receives a ``RestoreContext`` (plus any kwargs the caller passed to
    ``CheckpointSession.restore``) and returns the rebuilt app.

    A kind names a manifest format, so collisions are real bugs:
    registering a *different* binder under a taken kind — including the
    built-in lazy kinds, whether or not their module has loaded yet —
    raises ``PolicyError`` instead of silently shadowing the first
    registrant (re-registering the same callable is a no-op). Pass
    ``replace=True`` to override deliberately; a replaced built-in stays
    replaced even if its home module is imported later."""
    def deco(binder: Callable) -> Callable:
        home = _LAZY_KINDS.get(kind)
        if home is not None and getattr(binder, "__module__", None) == home:
            # the built-in module registering its own binder: first load
            # wins, but never clobber a deliberate replace=True override
            APP_KINDS.setdefault(kind, binder)
            return binder
        existing = APP_KINDS.get(kind)
        clash = (existing is not None and existing is not binder) \
            or (existing is None and home is not None)
        if clash and not replace:
            owner = (_registrant(existing) if existing is not None
                     else f"the built-in binder in {home}")
            raise PolicyError(
                f"app kind {kind!r} is already registered by {owner}; "
                f"pick a different kind, or pass register_app_kind("
                f"{kind!r}, replace=True) to override it deliberately")
        APP_KINDS[kind] = binder
        return binder
    return deco


def resolve_app_kind(kind: str) -> Callable:
    if kind not in APP_KINDS and kind in _LAZY_KINDS:
        importlib.import_module(_LAZY_KINDS[kind])
    try:
        return APP_KINDS[kind]
    except KeyError:
        raise PolicyError(
            f"no CheckpointableApp binder registered for checkpoint "
            f"kind {kind!r} (known: {sorted(set(APP_KINDS) | set(_LAZY_KINDS))}); "
            "import the module that defines the app or register one "
            "with repro.api.register_app_kind") from None
