"""CheckpointSession: the whole checkpoint lifecycle behind one object.

Before this facade every workload hand-rolled the same choreography:
construct a backend, wire a ``CheckpointManager``, thread step counters
and op-logs into ``save()``, drive an ``Incarnation`` phase by phase on
restore, and hook a ``ClusterSupervisor`` up by hand. The session owns
that sequence once, for every app that speaks ``CheckpointableApp``:

    sess = CheckpointSession("localfs:/tmp/job",
                             Policy(interval=5, chain=4, keep_last=3))
    sess.attach(app)                 # protocol-validated
    ...
    sess.maybe_snapshot()            # policy cadence; non-blocking
    ...
    app = sess.restore("latest")     # kind-registry binder + attach
    sup = sess.supervise([0, 1, 2])  # failure loop over the same session

Restore is checkpoint-*kind* driven: the manifest's ``job["kind"]``
names the registered binder that rebuilds the app through a
``RestoreContext`` — the session never contains workload code, which is
what keeps the paper's §V agnosticism claim honest at the API layer.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Union

from repro.api.app import RestoreContext, validate_app
from repro.api.errors import PolicyError
from repro.api.policy import Policy
from repro.api.registry import resolve_app_kind, resolve_backend


class CheckpointSession:
    """One app + one store + one policy, owned end to end.

    ``store`` is a URI-style spec (``localfs:/path``,
    ``sharded:/path?hosts=4``) or an already-built backend instance;
    ``policy`` defaults to ``Policy()``. ``from_manager`` adopts an
    existing ``CheckpointManager`` instead (the legacy-shim path).
    """

    def __init__(self, store: Union[str, Any], policy: Optional[Policy] = None,
                 *, app: Any = None, manager: Any = None) -> None:
        self.policy = policy or Policy()
        if manager is not None:
            if store is not None:
                raise PolicyError("give CheckpointSession a store OR a "
                                  "manager, not both")
            self.manager = manager
        elif store is None:
            raise PolicyError("CheckpointSession needs a store spec, a "
                              "backend instance, or manager=")
        else:
            if isinstance(store, str):
                defaults: Dict[str, str] = {}
                if self.policy.replicate is not None:
                    defaults["replicate"] = "1" if self.policy.replicate \
                        else "0"
                backend = resolve_backend(store, defaults=defaults)
            else:
                backend = store
            # Policy.replicate is a *default* (an explicit spec param
            # wins), but it must never be silently unservable: if the
            # user asked for replication and the resolved store can't
            # provide it, say so now — not at the first lost host.
            if self.policy.replicate \
                    and not getattr(backend, "replicate", False) \
                    and not (isinstance(store, str)
                             and "replicate=" in store):
                raise PolicyError(
                    f"Policy(replicate=True) but the "
                    f"{type(backend).__name__} store does not replicate; "
                    "use a replicating backend (e.g. "
                    "'sharded:/path?replicate=1') or construct it with "
                    "replication on")
            self.manager = self.policy.build_manager(backend)
        self._app: Any = None
        self.supervisor: Any = None
        if app is not None:
            self.attach(app)

    @classmethod
    def from_manager(cls, manager, policy: Optional[Policy] = None,
                     *, app: Any = None) -> "CheckpointSession":
        """Adopt an existing ``CheckpointManager`` (its pipeline settings
        win over ``policy``'s snapshot knobs; ``policy.interval`` still
        drives ``maybe_snapshot``)."""
        return cls(None, policy, app=app, manager=manager)

    # --- app attachment ------------------------------------------------

    @property
    def app(self) -> Any:
        return self._app

    @property
    def backend(self):
        return self.manager.backend

    def attach(self, app: Any) -> Any:
        """Validate the protocol and make ``app`` this session's app."""
        validate_app(app)
        self._app = app
        return app

    def _require_app(self) -> Any:
        if self._app is None:
            raise PolicyError("no app attached; call attach(app) or "
                              "restore() first")
        return self._app

    # --- snapshots -----------------------------------------------------

    def snapshot(self, block: bool = False):
        """One snapshot of the attached app at its current step: the
        optional ``session_state()`` hook wins over ``checkpoint_state()``
        (dynamic-state apps rebuild their entries per snapshot), the
        optional ``runtime_log()`` rides along for replay. Returns the
        in-flight ``SnapshotHandle`` (None when blocking or dropped under
        "skip" backpressure)."""
        app = self._require_app()
        state_fn = getattr(app, "session_state", None)
        state = state_fn() if callable(state_fn) else app.checkpoint_state()
        log_fn = getattr(app, "runtime_log", None)
        if callable(log_fn):
            log = log_fn()
        else:
            from repro.core.oplog import OpLog
            log = OpLog()
        return self.manager.save(int(app.checkpoint_step()), state, log,
                                 block=block, job_meta=dict(app.job_meta()))

    def maybe_snapshot(self, *, final: bool = False):
        """Policy-driven cadence: snapshot when the app's step lands on
        ``policy.interval`` (or unconditionally when ``final`` — the
        end-of-run boundary). Returns the handle, or None when the
        cadence says not yet."""
        if final:
            return self.snapshot()
        if not self.policy.interval:
            return None
        step = int(self._require_app().checkpoint_step())
        if step and step % self.policy.interval == 0:
            return self.snapshot()
        return None

    # --- restore -------------------------------------------------------

    def restorable_steps(self) -> List[int]:
        """Committed steps whose full delta chain is still intact."""
        from repro.core.restore import restorable_steps
        return restorable_steps(self.backend)

    def latest_step(self) -> Optional[int]:
        return self.backend.latest_step()

    def restore(self, step: Union[int, str, None] = None, *,
                expect_kind: Optional[str] = None,
                mesh_factory: Optional[Callable] = None,
                rewrite_op: Optional[Callable] = None,
                workers: Optional[int] = None,
                decode_workers: Optional[int] = None,
                streaming: Optional[bool] = None,
                **app_kwargs: Any) -> Any:
        """Rebuild and attach the checkpointed app.

        ``step`` is a step number, ``"latest"`` or None (latest). The
        manifest's ``job["kind"]`` resolves the registered binder, which
        drives the incarnation through a ``RestoreContext`` and returns
        the app; ``app_kwargs`` pass through to it (e.g. ``params=`` /
        ``n_slots=`` for the serving engine). ``expect_kind`` guards a
        caller that only handles one workload.

        ``workers`` sizes the restore's fetch/decode pools, threaded
        through the incarnation to ``CheckpointManager.restore``
        (``decode_workers`` is the older spelling of the same knob).
        ``streaming`` streams the payload — the app comes back once the
        hot tier is decoded and cold entries page in on first touch —
        with None deferring to ``policy.streaming_restore``. Streaming
        and eager restores are bit-identical."""
        if workers is not None and decode_workers is not None \
                and workers != decode_workers:
            raise PolicyError(
                f"workers={workers} and decode_workers={decode_workers} "
                "are the same knob spelled twice; pass one")
        workers = workers if workers is not None else decode_workers
        if streaming is None:
            streaming = self.policy.streaming_restore
        if step in (None, "latest"):
            resolved = self.manager.resolve_step(None)
        else:
            resolved = self.manager.resolve_step(int(step))
        job = self.backend.get_manifest(resolved).get("job", {})
        kind = job.get("kind", "train")
        if expect_kind is not None and kind != expect_kind:
            raise PolicyError(f"not a {expect_kind} checkpoint: {job!r}")
        binder = resolve_app_kind(kind)
        ctx = RestoreContext(self.manager, resolved, job,
                             mesh_factory=mesh_factory,
                             rewrite_op=rewrite_op,
                             decode_workers=workers,
                             streaming=bool(streaming),
                             lazy_kinds=self.policy.lazy_kinds)
        return self.attach(binder(ctx, **app_kwargs))

    # --- live migration ------------------------------------------------

    def migrate(self, to: Any, *, slots: Optional[List[int]] = None,
                include_queue: bool = False,
                via: Optional[str] = None,
                batch: Optional[int] = None,
                deadline_s: Optional[float] = None,
                streaming: bool = True):
        """Live-migrate this session's serving app's sessions onto
        another engine, through the C/R protocol: the chosen slots
        freeze, snapshot as a ``SessionBundle`` on a *move channel* (a
        dedicated store beside this session's chain — migration traffic
        never interleaves with the periodic snapshot chain), restore,
        and re-enter the target through admission replay — the re-slot
        machinery, so an N-slot engine's sessions land on an M-slot
        engine token-identically. The source keeps serving its
        unaffected slots throughout.

        ``to`` is the target engine (or a session holding one).
        ``via`` overrides the move-channel store spec (default: a
        ``_moves/`` directory under this session's store root).
        ``batch`` / ``deadline_s`` default to ``policy.migrate_batch`` /
        ``policy.drain_deadline_s``. Returns a ``MoveResult`` with
        per-batch blackout accounting."""
        from repro.api.errors import MigrationError
        from repro.core.migration import migrate_sessions

        source = self._require_app()
        target = to.app if isinstance(to, CheckpointSession) else to
        if target is None:
            raise MigrationError("target session has no app attached")
        if via is None:
            root = getattr(self.backend, "root", None)
            if root is None:
                raise MigrationError(
                    f"{type(self.backend).__name__} store has no root "
                    "path to derive a move channel from; pass via= (a "
                    "store spec for the migration transport)")
            via = f"localfs:{root}"
        return migrate_sessions(
            source, target, via=via, slots=slots,
            include_queue=include_queue,
            batch=batch if batch is not None else self.policy.migrate_batch,
            deadline_s=deadline_s if deadline_s is not None
            else self.policy.drain_deadline_s,
            streaming=streaming)

    # --- supervision ---------------------------------------------------

    def supervise(self, hosts: List[int], *,
                  spares: Optional[List[int]] = None,
                  heartbeat_timeout: float = 60.0,
                  clock: Callable[[], float] = time.monotonic,
                  allow_shrink: bool = True,
                  n_shards: Optional[int] = None,
                  restore_kwargs: Union[None, Dict[str, Any],
                                        Callable[[Any], Dict[str, Any]]] = None,
                  on_restored: Optional[Callable[[Any, Any], None]] = None,
                  teardown: Optional[Callable[[Any], None]] = None,
                  reassign: Optional[Callable[[Any, Any], None]] = None,
                  repair_storage: bool = True,
                  event_sink: Optional[
                      Callable[[float, str, Dict[str, Any]], None]] = None):
        """Close the failure loop over this session: a
        ``ClusterSupervisor`` whose restore hook goes back through
        ``CheckpointSession.restore`` — so a RESTART/SHRINK decision
        rebuilds whatever kind of app the checkpoint holds, through the
        protocol, with the decision's op rewrite applied.

        ``restore_kwargs`` supplies the binder kwargs a restore needs
        (dict, or ``callable(RestoreTarget) -> dict`` for kwargs that
        depend on the surviving topology — e.g. serving's proportional
        slot count); ``on_restored(app, target)`` observes each executed
        rebuild; ``event_sink(t, kind, detail)`` taps the supervisor's
        event stream live (``core.churn.IncidentLog`` writes it as
        JSONL). The supervisor also drives the app only through
        protocol hooks (``quiesce`` at teardown, ``apply_reassignment``
        for rebalances)."""
        from repro.core.supervisor import ClusterSupervisor

        def _restore(target):
            kw = restore_kwargs(target) if callable(restore_kwargs) \
                else dict(restore_kwargs or {})
            app = self.restore(step=target.step,
                               rewrite_op=target.rewrite_op(), **kw)
            if on_restored is not None:
                on_restored(app, target)
            return app

        sup = ClusterSupervisor(
            list(hosts), manager=self.manager, spares=list(spares or []),
            heartbeat_timeout=heartbeat_timeout, clock=clock,
            allow_shrink=allow_shrink, n_shards=n_shards,
            restore=_restore, teardown=teardown, reassign=reassign,
            repair_storage=repair_storage, runner=self._app,
            event_sink=event_sink)
        self.supervisor = sup
        return sup

    # --- lifecycle -----------------------------------------------------

    @property
    def stats(self) -> Dict[str, Any]:
        return self.manager.stats

    def wait(self) -> None:
        """Join the snapshot pipeline; re-raises the latest failure."""
        self.manager.wait()

    def close(self) -> None:
        self.manager.close()

    def __enter__(self) -> "CheckpointSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
