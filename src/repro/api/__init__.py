"""repro.api — the public, checkpoint-agnostic session surface.

Applications import ONLY from here: the ``CheckpointableApp`` protocol
to implement, the ``CheckpointSession`` facade that owns the snapshot /
restore / supervise lifecycle, the frozen ``Policy`` value, the
URI-spec registries (``register_backend`` / ``register_app_kind`` /
``register_codec``), the typed error hierarchy, and the state-declaration
types (``UpperHalf``, ``OpLog``) re-exported so app code never reaches
into ``repro.core``. See ARCHITECTURE.md "Public API".

Exports resolve lazily (PEP 562): ``repro.core`` modules import
``repro.api.errors`` at their own load time, so this package must stay
import-cycle-neutral — nothing heavy runs until an attribute is asked
for.
"""
from __future__ import annotations

__all__ = [
    # facade + protocol
    "CheckpointSession",
    "CheckpointableApp",
    "RestoreContext",
    "Policy",
    "validate_app",
    # registries
    "register_app_kind",
    "register_backend",
    "register_codec",
    "resolve_app_kind",
    "resolve_backend",
    "parse_store_spec",
    "available_codecs",
    # state declaration (re-exports: apps never import repro.core)
    "UpperHalf",
    "OpLog",
    # fleet migration (re-exports: the session's migrate() verb returns
    # a MoveResult; FleetRouter routes + moves over many engines)
    "FleetRouter",
    "MoveResult",
    # typed errors
    "CheckpointError",
    "PolicyError",
    "BackendUnavailable",
    "SnapshotError",
    "RestoreError",
    "MigrationError",
    "StaleHandleError",
    "LifecycleError",
    "SupervisorError",
    "errors",
]

_HOMES = {
    "CheckpointSession": "repro.api.session",
    "CheckpointableApp": "repro.api.app",
    "RestoreContext": "repro.api.app",
    "validate_app": "repro.api.app",
    "Policy": "repro.api.policy",
    "register_app_kind": "repro.api.registry",
    "register_backend": "repro.api.registry",
    "register_codec": "repro.api.registry",
    "resolve_app_kind": "repro.api.registry",
    "resolve_backend": "repro.api.registry",
    "parse_store_spec": "repro.api.registry",
    "available_codecs": "repro.api.registry",
    "UpperHalf": "repro.core.split_state",
    "OpLog": "repro.core.oplog",
    "FleetRouter": "repro.core.migration",
    "MoveResult": "repro.core.migration",
    "CheckpointError": "repro.api.errors",
    "PolicyError": "repro.api.errors",
    "BackendUnavailable": "repro.api.errors",
    "SnapshotError": "repro.api.errors",
    "RestoreError": "repro.api.errors",
    "MigrationError": "repro.api.errors",
    "StaleHandleError": "repro.api.errors",
    "LifecycleError": "repro.api.errors",
    "SupervisorError": "repro.api.errors",
}


def __getattr__(name: str):
    if name == "errors":
        import repro.api.errors as errors
        return errors
    home = _HOMES.get(name)
    if home is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(home), name)


def __dir__():
    return sorted(__all__)
