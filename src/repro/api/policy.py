"""Policy: the checkpoint lifecycle as one validated, frozen value.

``CheckpointManager`` accreted a dozen loose constructor kwargs (cadence
here, chain length there, backpressure somewhere else) that every
caller re-plumbed. ``Policy`` replaces that sprawl: one immutable
dataclass that validates at construction — a bad combination is a
``PolicyError`` at the line that wrote it, not a surprise deep inside
the first chained save — and builds a correctly-wired manager for any
backend. Being frozen, a policy is shareable by-value configuration:
launchers, tests and supervisors can pass it around without defensive
copies.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional

from repro.api.errors import PolicyError

_BACKPRESSURE = ("block", "skip")


@dataclass(frozen=True)
class Policy:
    """Snapshot-lifecycle configuration.

    ``interval``   auto-snapshot cadence in app steps for
                   ``CheckpointSession.maybe_snapshot`` (None = snapshots
                   are taken only when explicitly requested).
    ``chain``      delta-chain length: a full base snapshot every
                   ``chain`` checkpoints, XOR links between (1 = every
                   snapshot is a full base).
    ``keep_last``  retention GC: checkpoints to keep (None = keep all).
    ``sparse``     dirty-chunk capture on chain links (auto-disabled by
                   the pipeline when chaining is off or the accelerator
                   can't fingerprint cheaply).
    ``sparse_chunk_bytes`` / ``sparse_min_bytes``  dirty-chunk geometry
                   (None = pipeline defaults; only valid with chain>=2).
    ``backpressure`` "block" (wait for a staging slot) or "skip" (drop
                   the snapshot when the pipeline is busy).
    ``writers``    backend writer-pool width.
    ``compress``   zlib-probe blob compression.
    ``prune_oplog`` record-prune-replay the op-log into manifests.
    ``async_save`` capture-and-return snapshots (False = synchronous).
    ``replicate``  peer replication default for store specs that
                   support it (None = the spec decides).
    ``codecs``     entry kind -> codec name (e.g. {"opt_state": "int8"}).
    ``streaming_restore`` restore-side default: stream the payload
                   (return at hot-tier-decoded, cold entries page in on
                   first touch) instead of materializing it as one
                   barrier. Bit-identical either way; an explicit
                   ``streaming=`` at the restore call wins.
    ``lazy_kinds`` entry kinds the streaming restore defers to the cold
                   tier (None = the streaming default: optimizer
                   moments + KV cache).
    ``drain_deadline_s`` planned-move budget: the worst per-batch
                   blackout a ``CheckpointSession.migrate`` /
                   ``FleetRouter`` drain may cost before the move is
                   flagged ``within_deadline=False`` (None = no
                   deadline; moves are never aborted mid-flight — a
                   half-moved fleet is worse than a late one).
    ``migrate_batch`` sessions frozen per move batch: bounds any one
                   session's blackout — the rest keep decoding on the
                   source while a batch is in transit (None = move all
                   chosen sessions in one batch).
    """

    interval: Optional[int] = None
    chain: int = 1
    keep_last: Optional[int] = None
    sparse: bool = True
    sparse_chunk_bytes: Optional[int] = None
    sparse_min_bytes: Optional[int] = None
    backpressure: str = "block"
    writers: int = 4
    compress: bool = True
    prune_oplog: bool = True
    async_save: bool = True
    replicate: Optional[bool] = None
    codecs: Mapping[str, str] = field(default_factory=dict)
    streaming_restore: bool = False
    lazy_kinds: Optional[tuple] = None
    drain_deadline_s: Optional[float] = None
    migrate_batch: Optional[int] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "codecs", dict(self.codecs))
        if self.interval is not None and self.interval < 1:
            raise PolicyError(
                f"interval={self.interval}: the snapshot cadence must be "
                ">= 1 app step, or None for explicit snapshots only")
        if self.chain < 1:
            raise PolicyError(
                f"chain={self.chain}: the delta-chain length must be >= 1 "
                "(1 = every snapshot is a full base)")
        if self.keep_last is not None and self.keep_last < 1:
            raise PolicyError(
                f"keep_last={self.keep_last}: retention must keep at "
                "least one checkpoint, or None to keep all")
        if self.backpressure not in _BACKPRESSURE:
            raise PolicyError(
                f"backpressure={self.backpressure!r}: choose 'block' "
                "(wait for a staging slot) or 'skip' (drop the snapshot)")
        if self.writers < 1:
            raise PolicyError(f"writers={self.writers}: the writer pool "
                              "needs at least one thread")
        sparse_knobs = [k for k in ("sparse_chunk_bytes",
                                    "sparse_min_bytes")
                        if getattr(self, k) is not None]
        if sparse_knobs and self.chain < 2:
            raise PolicyError(
                f"{'/'.join(sparse_knobs)} set with chain={self.chain}: "
                "sparse dirty-chunk capture only applies to delta-chain "
                "links — set chain >= 2 or drop the sparse knobs")
        if sparse_knobs and not self.sparse:
            raise PolicyError(
                f"{'/'.join(sparse_knobs)} set with sparse=False: the "
                "dirty-chunk knobs have no effect — enable sparse or "
                "drop them")
        if self.lazy_kinds is not None:
            if isinstance(self.lazy_kinds, str) \
                    or not all(isinstance(k, str) for k in self.lazy_kinds):
                raise PolicyError(
                    f"lazy_kinds={self.lazy_kinds!r} must be a sequence "
                    "of entry-kind names (e.g. ('opt_state', 'cache')), "
                    "or None for the streaming default")
            object.__setattr__(self, "lazy_kinds", tuple(self.lazy_kinds))
            if not self.streaming_restore:
                raise PolicyError(
                    f"lazy_kinds={self.lazy_kinds!r} set with "
                    "streaming_restore=False: the cold tier only exists "
                    "under a streaming restore — enable it or drop the "
                    "knob (a per-call restore(streaming=True) uses the "
                    "streaming default tiers)")
        if self.drain_deadline_s is not None and self.drain_deadline_s <= 0:
            raise PolicyError(
                f"drain_deadline_s={self.drain_deadline_s}: the planned-"
                "move blackout budget must be > 0 seconds, or None for "
                "no deadline")
        if self.migrate_batch is not None and self.migrate_batch < 1:
            raise PolicyError(
                f"migrate_batch={self.migrate_batch}: a move batch "
                "freezes at least one session, or None to move all "
                "chosen sessions in one batch")
        if self.codecs:
            from repro.api.registry import available_codecs
            known = available_codecs()
            for kind, name in self.codecs.items():
                if name not in known:
                    raise PolicyError(
                        f"codecs[{kind!r}]={name!r}: unknown codec "
                        f"(available: {known}); register one with "
                        "repro.api.register_codec")

    def with_(self, **changes: Any) -> "Policy":
        """A modified copy, re-validated."""
        return dataclasses.replace(self, **changes)

    def build_manager(self, backend):
        """A ``CheckpointManager`` wired exactly as this policy says."""
        from repro.core.checkpoint import CheckpointManager
        extra: Dict[str, Any] = {}
        if self.sparse_chunk_bytes is not None:
            extra["sparse_chunk_bytes"] = self.sparse_chunk_bytes
        return CheckpointManager(
            backend,
            codec_by_kind=dict(self.codecs),
            async_save=self.async_save,
            keep_last=self.keep_last,
            prune_oplog=self.prune_oplog,
            delta_base_interval=self.chain,
            backpressure=self.backpressure,
            writers=self.writers,
            compress=self.compress,
            sparse_capture=self.sparse,
            sparse_min_bytes=self.sparse_min_bytes,
            **extra,
        )
