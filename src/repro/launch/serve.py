"""Serving launcher: continuous-batching engine over a registry arch
(smoke configs for CPU; full configs on real hardware), under the C/R
runtime when a checkpoint directory is given.

  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b-smoke \
      --requests 6 --max-new 8 [--ckpt-dir /tmp/svc --snapshot-every 4]

With ``--ckpt-dir`` the engine is built through the logged lower half
and snapshots its live sessions (queue, in-flight requests, KV cache)
every ``--snapshot-every`` steps. ``--resume [latest|STEP]`` restores a
killed server and finishes the interrupted requests; pass a different
``--slots`` to re-slot the sessions onto a larger or smaller engine
(elastic serving restore).

``--supervise`` (requires ``--ckpt-dir``) routes serving under a
``ClusterSupervisor`` over a simulated ``--hosts``-host world: a host
death (inject one with ``--kill-host H@STEP``) is detected after
``--heartbeat-timeout`` silent ticks and the decision executes for
real — hot-spare remaps the dead host to one of ``--spares``; shrink
restores the live sessions onto proportionally fewer slots through the
elastic re-slot path; restart resumes every session from the last
snapshot. In-flight generations continue token-identically.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import registry as cfg_registry
from repro.core import (CheckpointManager, ClusterSupervisor,
                        make_backend)
from repro.launch.supervise import (SimWorldDriver, add_supervise_args,
                                    parse_supervise_args)
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=5)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None,
                    help="enable live-session checkpointing to this dir")
    ap.add_argument("--backend", choices=("localfs", "sharded"),
                    default="localfs")
    ap.add_argument("--snapshot-every", type=int, default=4,
                    help="snapshot cadence in engine steps (with "
                         "--ckpt-dir)")
    ap.add_argument("--resume", nargs="?", const="latest", default=None,
                    metavar="STEP",
                    help="restore live sessions from --ckpt-dir: "
                         "'latest' (the bare flag) or a step number; "
                         "--slots may differ from the checkpoint "
                         "(elastic re-slotting)")
    add_supervise_args(ap, unit="engine step")
    args = ap.parse_args(argv)

    kill, err = parse_supervise_args(args, "serve")
    if err is not None:
        print(err, file=sys.stderr)
        return 2
    if args.supervise and not args.ckpt_dir:
        print("[serve] --supervise needs --ckpt-dir (restarts resume "
              "from snapshots)", file=sys.stderr)
        return 2

    # validate the cheap stuff before paying jax init + param build
    resume_step = None
    if args.resume is not None and args.resume != "latest":
        try:
            resume_step = int(args.resume)
        except ValueError:
            print(f"[serve] --resume: expected 'latest' or a step "
                  f"number, got {args.resume!r}", file=sys.stderr)
            return 2
    if args.resume is not None and not args.ckpt_dir:
        print("[serve] --resume needs --ckpt-dir", file=sys.stderr)
        return 2

    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(make_backend(args.backend, args.ckpt_dir),
                                async_save=True)
    step = resume_step
    if args.resume is not None:
        from repro.core.restore import restorable_steps
        ok = restorable_steps(mgr.backend)
        if not ok or (step is not None and step not in ok):
            print(f"[serve] --resume: step "
                  f"{'latest' if step is None else step} not restorable "
                  f"in {args.ckpt_dir} (have {ok})", file=sys.stderr)
            return 2
        if step is None:
            step = ok[-1]  # newest step with an intact chain
        ckpt_arch = mgr.backend.get_manifest(step).get("job", {}).get("arch")
        if ckpt_arch is not None and ckpt_arch != args.arch:
            print(f"[serve] --resume: checkpoint was taken with arch "
                  f"{ckpt_arch!r}, not {args.arch!r} — the params built "
                  f"from --arch would not match the restored engine",
                  file=sys.stderr)
            return 2

    # arguments are sound — now pay jax init + param construction
    if args.arch in cfg_registry.ARCH_IDS:
        cfg = cfg_registry.get_config(args.arch)
    else:
        cfg = cfg_registry.get_smoke_config(args.arch.removesuffix("-smoke"))
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    n_dev = len(jax.devices())

    if args.resume is not None:
        eng = ServingEngine.restore(mgr, params, n_slots=args.slots,
                                    step=step)
        reqs = eng.live_requests()
        inc = eng.incarnation
        print(f"[serve] RESUMED at engine step {eng.steps} with "
              f"{len(reqs)} live requests on {eng.n_slots} slots "
              f"(materialize {inc.timings['materialize_s']:.2f}s, "
              f"replay {inc.timings['replay_s']:.2f}s)")
    else:
        eng = ServingEngine.create(args.arch, params, (n_dev, 1),
                                   n_slots=args.slots,
                                   max_seq=args.max_seq, manager=mgr)
        rng = np.random.RandomState(args.seed)
        reqs = [Request(rid=i,
                        prompt=rng.randint(0, cfg.vocab_size,
                                           size=args.prompt_len),
                        max_new=args.max_new)
                for i in range(args.requests)]
        for r in reqs:
            eng.submit(r)

    # tokens already generated before a crash don't count toward this
    # process's throughput — only what the drain below produces does
    already = sum(len(r.out) for r in reqs)
    t0 = time.monotonic()
    if args.supervise:
        eng, reg = _run_supervised(args, mgr, eng, params, kill)
        reqs = sorted(reg.values(), key=lambda r: r.rid)
    else:
        eng.run_until_drained(
            snapshot_every=args.snapshot_every if mgr is not None else None)
    dt = time.monotonic() - t0
    toks = sum(len(r.out) for r in reqs) - already
    print(f"[serve] {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {eng.steps} engine steps, "
          f"{eng.n_slots} slots)")
    for r in reqs:
        print(f"  rid={r.rid} out={r.out}")
    return 0


def _run_supervised(args, mgr, eng, params, kill, max_steps: int = 10_000):
    """Drain the engine under the failure loop: one virtual-clock tick
    per engine step; a detected death swaps the engine under us (shrink
    restores the live sessions onto proportionally fewer slots through
    the elastic re-slot path). Returns the final engine and the latest
    Request object seen per rid — finished or restored, the newest
    object holds the request's authoritative output."""
    world = list(range(args.hosts))
    spares = list(range(args.hosts, args.hosts + args.spares))
    driver = SimWorldDriver(kill)

    def restore(target):
        # ceiling division: losing 1 of 4 hosts must not halve a
        # 2-slot engine — capacity shrinks proportionally, rounded up
        n_slots = max(1, -(-args.slots * len(target.hosts) // args.hosts))
        e = ServingEngine.restore(mgr, params, n_slots=n_slots,
                                  step=target.step)
        print(f"[supervisor] restored {len(e.live_requests())} live "
              f"sessions on {e.n_slots} slots at engine step {e.steps}")
        return e

    sup = ClusterSupervisor(
        world, manager=mgr, spares=spares,
        heartbeat_timeout=args.heartbeat_timeout,
        clock=driver.clock, allow_shrink=not args.no_shrink,
        restore=restore, runner=eng)
    driver.attach(sup)
    if mgr.backend.latest_step() is None:
        eng.snapshot(block=True)   # baseline: a death before the first
        # --snapshot-every commit still has a restore target (a resumed
        # engine already has one — don't overwrite its manifest)
    reg = {}
    while max_steps > 0:
        eng = sup.runner
        for r in eng.live_requests():
            reg[r.rid] = r
        if not (eng.queue or any(eng.slot_req)):
            break
        eng.step()
        max_steps -= 1
        if args.snapshot_every and eng.steps % args.snapshot_every == 0:
            eng.snapshot()
        driver.tick(eng.steps)
    driver.warn_if_kill_pending()
    mgr.wait()
    return sup.runner, reg


if __name__ == "__main__":
    sys.exit(main())
