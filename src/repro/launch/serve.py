"""Serving launcher: continuous-batching engine over a registry arch
(smoke configs for CPU; full configs on real hardware).

  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b-smoke \
      --requests 6 --max-new 8
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import registry as cfg_registry
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=5)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.arch in cfg_registry.ARCH_IDS:
        cfg = cfg_registry.get_config(args.arch)
    else:
        cfg = cfg_registry.get_smoke_config(args.arch.removesuffix("-smoke"))
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))

    eng = ServingEngine(cfg, params, mesh, n_slots=args.slots,
                        max_seq=args.max_seq)
    rng = np.random.RandomState(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       size=args.prompt_len),
                    max_new=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)

    t0 = time.monotonic()
    eng.run_until_drained()
    dt = time.monotonic() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {eng.steps} engine steps, "
          f"{args.slots} slots)")
    for r in reqs:
        print(f"  rid={r.rid} out={r.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
