"""Serving launcher: continuous-batching engine over a registry arch
(smoke configs for CPU; full configs on real hardware), under the C/R
runtime when a checkpoint directory is given.

  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b-smoke \
      --requests 6 --max-new 8 [--ckpt-dir /tmp/svc --snapshot-every 4]

With ``--ckpt-dir`` the engine is built through the logged lower half
and snapshots its live sessions (queue, in-flight requests, KV cache)
every ``--snapshot-every`` steps. ``--resume [latest|STEP]`` restores a
killed server and finishes the interrupted requests; pass a different
``--slots`` to re-slot the sessions onto a larger or smaller engine
(elastic serving restore).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import registry as cfg_registry
from repro.core import CheckpointManager, make_backend
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=5)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None,
                    help="enable live-session checkpointing to this dir")
    ap.add_argument("--backend", choices=("localfs", "sharded"),
                    default="localfs")
    ap.add_argument("--snapshot-every", type=int, default=4,
                    help="snapshot cadence in engine steps (with "
                         "--ckpt-dir)")
    ap.add_argument("--resume", nargs="?", const="latest", default=None,
                    metavar="STEP",
                    help="restore live sessions from --ckpt-dir: "
                         "'latest' (the bare flag) or a step number; "
                         "--slots may differ from the checkpoint "
                         "(elastic re-slotting)")
    args = ap.parse_args(argv)

    # validate the cheap stuff before paying jax init + param build
    resume_step = None
    if args.resume is not None and args.resume != "latest":
        try:
            resume_step = int(args.resume)
        except ValueError:
            print(f"[serve] --resume: expected 'latest' or a step "
                  f"number, got {args.resume!r}", file=sys.stderr)
            return 2
    if args.resume is not None and not args.ckpt_dir:
        print("[serve] --resume needs --ckpt-dir", file=sys.stderr)
        return 2

    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(make_backend(args.backend, args.ckpt_dir),
                                async_save=True)
    step = resume_step
    if args.resume is not None:
        from repro.core.restore import restorable_steps
        ok = restorable_steps(mgr.backend)
        if not ok or (step is not None and step not in ok):
            print(f"[serve] --resume: step "
                  f"{'latest' if step is None else step} not restorable "
                  f"in {args.ckpt_dir} (have {ok})", file=sys.stderr)
            return 2
        if step is None:
            step = ok[-1]  # newest step with an intact chain
        ckpt_arch = mgr.backend.get_manifest(step).get("job", {}).get("arch")
        if ckpt_arch is not None and ckpt_arch != args.arch:
            print(f"[serve] --resume: checkpoint was taken with arch "
                  f"{ckpt_arch!r}, not {args.arch!r} — the params built "
                  f"from --arch would not match the restored engine",
                  file=sys.stderr)
            return 2

    # arguments are sound — now pay jax init + param construction
    if args.arch in cfg_registry.ARCH_IDS:
        cfg = cfg_registry.get_config(args.arch)
    else:
        cfg = cfg_registry.get_smoke_config(args.arch.removesuffix("-smoke"))
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    n_dev = len(jax.devices())

    if args.resume is not None:
        eng = ServingEngine.restore(mgr, params, n_slots=args.slots,
                                    step=step)
        reqs = eng.live_requests()
        inc = eng.incarnation
        print(f"[serve] RESUMED at engine step {eng.steps} with "
              f"{len(reqs)} live requests on {eng.n_slots} slots "
              f"(materialize {inc.timings['materialize_s']:.2f}s, "
              f"replay {inc.timings['replay_s']:.2f}s)")
    else:
        eng = ServingEngine.create(args.arch, params, (n_dev, 1),
                                   n_slots=args.slots,
                                   max_seq=args.max_seq, manager=mgr)
        rng = np.random.RandomState(args.seed)
        reqs = [Request(rid=i,
                        prompt=rng.randint(0, cfg.vocab_size,
                                           size=args.prompt_len),
                        max_new=args.max_new)
                for i in range(args.requests)]
        for r in reqs:
            eng.submit(r)

    # tokens already generated before a crash don't count toward this
    # process's throughput — only what the drain below produces does
    already = sum(len(r.out) for r in reqs)
    t0 = time.monotonic()
    eng.run_until_drained(
        snapshot_every=args.snapshot_every if mgr is not None else None)
    dt = time.monotonic() - t0
    toks = sum(len(r.out) for r in reqs) - already
    print(f"[serve] {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {eng.steps} engine steps, "
          f"{eng.n_slots} slots)")
    for r in reqs:
        print(f"  rid={r.rid} out={r.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
