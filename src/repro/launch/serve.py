"""Serving launcher: continuous-batching engine over a registry arch
(smoke configs for CPU; full configs on real hardware), under the C/R
runtime when a checkpoint store is given.

  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b-smoke \
      --requests 6 --max-new 8 [--store localfs:/tmp/svc --snapshot-every 4]

With ``--store`` (or legacy ``--ckpt-dir``) the engine is built through
the logged lower half and snapshots its live sessions (queue, in-flight
requests, KV cache) every ``--snapshot-every`` steps; swapping the
checkpoint package is a one-string change (``--store
sharded:/tmp/svc?hosts=4``). ``--resume [latest|STEP]`` restores a
killed server and finishes the interrupted requests; pass a different
``--slots`` to re-slot the sessions onto a larger or smaller engine
(elastic serving restore).

``--supervise`` (requires a store) routes serving under a
``ClusterSupervisor`` over a simulated ``--hosts``-host world: a host
death (inject one with ``--kill-host H@STEP``) is detected after
``--heartbeat-timeout`` silent ticks and the decision executes for
real — hot-spare remaps the dead host to one of ``--spares``; shrink
restores the live sessions onto proportionally fewer slots through the
elastic re-slot path; restart resumes every session from the last
snapshot. In-flight generations continue token-identically.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Optional

import jax
import numpy as np

from repro.configs import registry as cfg_registry
from repro.core import IncidentLog
from repro.launch.common import (add_store_args, build_session,
                                 parse_resume_arg, resolve_store,
                                 restore_timings_line, validate_resume)
from repro.launch.supervise import (SimWorldDriver, add_supervise_args,
                                    parse_churn_args, parse_drain_arg,
                                    parse_supervise_args)
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=5)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--migrate-to", default=None, metavar="SLOTS@STEP",
                    help="live migration: at engine step STEP, move every "
                         "live session onto a fresh SLOTS-slot engine "
                         "through the C/R move channel and finish there "
                         "(needs --store; sessions continue "
                         "token-identically)")
    add_store_args(ap, interval_flag="--snapshot-every",
                   interval_default=4, interval_unit="engine steps")
    add_supervise_args(ap, unit="engine step")
    args = ap.parse_args(argv)

    kill, err = parse_supervise_args(args, "serve")
    if err is not None:
        print(err, file=sys.stderr)
        return 2
    drain, err = parse_drain_arg(args, "serve")
    if err is not None:
        print(err, file=sys.stderr)
        return 2
    # a serving drain has no fixed step count; the generated-trace
    # horizon is a bound on the engine-step clock, not a promise
    trace, err = parse_churn_args(args, "serve",
                                  horizon=args.requests * args.max_new)
    if err is not None:
        print(err, file=sys.stderr)
        return 2
    migrate_to, err = _parse_migrate_to(args, "serve")
    if err is not None:
        print(err, file=sys.stderr)
        return 2
    spec, err = resolve_store(args, "serve")
    if err is not None:
        print(err, file=sys.stderr)
        return 2
    if args.supervise and not spec:
        print("[serve] --supervise needs --store/--ckpt-dir (restarts "
              "resume from snapshots)", file=sys.stderr)
        return 2
    if migrate_to is not None and not spec:
        print("[serve] --migrate-to needs --store/--ckpt-dir (the move "
              "channel rides beside the store)", file=sys.stderr)
        return 2
    if migrate_to is not None and args.supervise:
        print("[serve] --migrate-to and --supervise would both own the "
              "engine swap; use --drain H@STEP for a supervised planned "
              "move", file=sys.stderr)
        return 2

    # validate the cheap stuff before paying jax init + param build
    resume, resume_step, err = parse_resume_arg(args, "serve")
    if err is not None:
        print(err, file=sys.stderr)
        return 2
    if resume and not spec:
        print("[serve] --resume needs --store/--ckpt-dir",
              file=sys.stderr)
        return 2

    sess = None
    if spec:
        sess, err = build_session(spec, "serve",
                                  interval=args.snapshot_every,
                                  keep_last=args.keep_last)
        if err is not None:
            print(err, file=sys.stderr)
            return 2
    step = resume_step
    if resume:
        step, err = validate_resume(sess, step, spec, "serve")
        if err is not None:
            print(err, file=sys.stderr)
            return 2
        ckpt_arch = sess.backend.get_manifest(step).get("job",
                                                        {}).get("arch")
        if ckpt_arch is not None and ckpt_arch != args.arch:
            print(f"[serve] --resume: checkpoint was taken with arch "
                  f"{ckpt_arch!r}, not {args.arch!r} — the params built "
                  f"from --arch would not match the restored engine",
                  file=sys.stderr)
            return 2

    # arguments are sound — now pay jax init + param construction
    cfg = cfg_registry.resolve_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    n_dev = len(jax.devices())

    if resume:
        eng = sess.restore(step=step, expect_kind="serving",
                           params=params, n_slots=args.slots,
                           streaming=args.streaming_restore or None)
        reqs = eng.live_requests()
        inc = eng.incarnation
        print(f"[serve] RESUMED at engine step {eng.steps} with "
              f"{len(reqs)} live requests on {eng.n_slots} slots "
              f"({restore_timings_line(inc)})")
    else:
        eng = ServingEngine.create(
            args.arch, params, (n_dev, 1), n_slots=args.slots,
            max_seq=args.max_seq,
            manager=sess.manager if sess is not None else None)
        if sess is not None:
            sess.attach(eng)
        rng = np.random.RandomState(args.seed)
        reqs = [Request(rid=i,
                        prompt=rng.randint(0, cfg.vocab_size,
                                           size=args.prompt_len),
                        max_new=args.max_new)
                for i in range(args.requests)]
        for r in reqs:
            eng.submit(r)

    # tokens already generated before a crash don't count toward this
    # process's throughput — only what the drain below produces does
    already = sum(len(r.out) for r in reqs)
    t0 = time.monotonic()
    if args.supervise:
        eng, reg = _run_supervised(args, sess, eng, params, kill, drain,
                                   trace)
        reqs = sorted(reg.values(), key=lambda r: r.rid)
    elif migrate_to is not None:
        eng, reg = _run_migrated(args, sess, eng, migrate_to)
        reqs = sorted(reg.values(), key=lambda r: r.rid)
    else:
        eng.run_until_drained(
            snapshot_every=sess.policy.interval if sess is not None
            else None)
    dt = time.monotonic() - t0
    toks = sum(len(r.out) for r in reqs) - already
    print(f"[serve] {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {eng.steps} engine steps, "
          f"{eng.n_slots} slots)")
    for r in reqs:
        print(f"  rid={r.rid} out={r.out}")
    return 0


def _parse_migrate_to(args, prog: str):
    if args.migrate_to is None:
        return None, None
    try:
        s, at = args.migrate_to.split("@")
        mt = (int(s), int(at))
    except ValueError:
        return None, (f"[{prog}] --migrate-to: expected SLOTS@STEP, got "
                      f"{args.migrate_to!r}")
    if mt[0] < 1:
        return None, (f"[{prog}] --migrate-to: SLOTS must be >= 1, got "
                      f"{mt[0]}")
    return mt, None


def _run_migrated(args, sess, eng, migrate_to, max_steps: int = 10_000):
    """Drain with one live move in the middle: at engine step STEP,
    every live session freezes, snapshots through the move channel and
    re-enters a fresh SLOTS-slot engine via admission replay — then the
    drain finishes there. Returns the final engine and the newest
    Request object per rid (the landed objects are the authoritative
    ones after a move)."""
    n_slots, at = migrate_to
    reg = {r.rid: r for r in eng.live_requests()}

    def drain(until: Optional[int]) -> int:
        nonlocal max_steps
        while (eng.queue or any(eng.slot_req)) and max_steps > 0 \
                and (until is None or eng.steps < until):
            eng.step()
            sess.maybe_snapshot()
            max_steps -= 1
        return max_steps

    drain(at)
    if eng.queue or any(eng.slot_req):
        target = ServingEngine.create(
            args.arch, eng.params, (len(jax.devices()), 1),
            n_slots=n_slots, max_seq=args.max_seq)
        res = sess.migrate(target, include_queue=True)
        print(f"[serve] migrated {len(res.moved)} sessions -> "
              f"{n_slots}-slot engine at step {eng.steps} (blackout "
              f"{res.blackout_s * 1e3:.0f}ms: capture "
              f"{res.capture_s * 1e3:.0f}ms + restore "
              f"{res.restore_s * 1e3:.0f}ms + first step)")
        eng = sess.attach(target)   # the session follows its sessions
        for r in eng.live_requests():
            reg[r.rid] = r
        drain(None)
    sess.wait()
    return eng, reg


def _run_supervised(args, sess, eng, params, kill, drain=None,
                    trace=None, max_steps: int = 10_000):
    """Drain the engine under the failure loop: one virtual-clock tick
    per engine step; a detected death swaps the engine under us through
    the session's app-kind registry (shrink restores the live sessions
    onto proportionally fewer slots through the elastic re-slot path;
    a churn-driven grow expands them back through the same path).
    Returns the final engine and the latest Request object seen per
    rid — finished or restored, the newest object holds the request's
    authoritative output."""
    world = list(range(args.hosts))
    spares = list(range(args.hosts, args.hosts + args.spares))
    driver = SimWorldDriver(kill, drain, trace=trace,
                            snapshot=lambda: sess.snapshot(block=True))

    def restore_kwargs(target):
        # ceiling division: losing 1 of 4 hosts must not halve a
        # 2-slot engine — capacity shrinks proportionally, rounded up
        # (and a grow back to the full world restores the full slots)
        n_slots = max(1, -(-args.slots * len(target.hosts) // args.hosts))
        return {"params": params, "n_slots": n_slots}

    def on_restored(e, target):
        print(f"[supervisor] restored {len(e.live_requests())} live "
              f"sessions on {e.n_slots} slots at engine step {e.steps}")

    sink = IncidentLog(args.incident_log) if args.incident_log else None
    sup = sess.supervise(
        world, spares=spares,
        heartbeat_timeout=args.heartbeat_timeout,
        clock=driver.clock, allow_shrink=not args.no_shrink,
        restore_kwargs=restore_kwargs, on_restored=on_restored,
        event_sink=sink)
    driver.attach(sup)
    if sess.latest_step() is None:
        sess.snapshot(block=True)   # baseline: a death before the first
        # --snapshot-every commit still has a restore target (a resumed
        # engine already has one — don't overwrite its manifest)
    reg = {}
    while max_steps > 0:
        eng = sup.runner
        for r in eng.live_requests():
            reg[r.rid] = r
        if not (eng.queue or any(eng.slot_req)):
            break
        eng.step()
        max_steps -= 1
        sess.maybe_snapshot()   # Policy.interval is the one cadence
        driver.tick(eng.steps)
    driver.warn_if_kill_pending()
    driver.print_goodput()
    if sink is not None:
        sink.close()
    sess.wait()
    return sup.runner, reg


if __name__ == "__main__":
    sys.exit(main())
