"""Fleet launcher: many live engines, one router, planned moves.

  PYTHONPATH=src python -m repro.launch.fleet --arch phi4-mini-3.8b-smoke \
      --engines 2 --slots 2 --rate 1.0 --requests 12 \
      --store localfs:/tmp/fleet --migrate e0:e1@6

Synthetic Poisson traffic (``serving.traffic``) arrives at a
``FleetRouter`` over ``--engines`` named engines; at the trigger step a
live move runs through the C/R move channel — ``--migrate SRC:DST@STEP``
moves SRC's live slots onto DST while SRC keeps serving what stays,
``--drain NAME@STEP`` moves *everything* (slots + queue) and retires
NAME from the rotation. Requests that arrive for a draining engine are
held and replayed on the target. The run exits non-zero if any request
was dropped or duplicated — the router's counters are the claim.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import registry as cfg_registry
from repro.core.migration import FleetRouter
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.serving.traffic import TrafficGenerator


def _parse_move(spec, prog: str, flag: str, names):
    """SRC:DST@STEP (--migrate) or NAME@STEP (--drain)."""
    if spec is None:
        return None, None
    try:
        head, at = spec.split("@")
        parts = head.split(":")
        if flag == "--migrate":
            src, dst = parts
        else:
            (src,), dst = parts, None
        move = (src, dst, int(at))
    except ValueError:
        shape = "SRC:DST@STEP" if flag == "--migrate" else "NAME@STEP"
        return None, (f"[{prog}] {flag}: expected {shape}, got {spec!r}")
    for name in filter(None, move[:2]):
        if name not in names:
            return None, (f"[{prog}] {flag}: unknown engine {name!r} "
                          f"(fleet has {sorted(names)})")
    if move[0] == move[1]:
        return None, f"[{prog}] {flag}: SRC and DST are both {move[0]!r}"
    return move, None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--engines", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--rate", type=float, default=1.0,
                    help="mean Poisson arrivals per fleet step")
    ap.add_argument("--requests", type=int, default=12,
                    help="total synthetic requests to emit")
    ap.add_argument("--steps", type=int, default=10_000,
                    help="fleet step budget")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store", required=True,
                    help="store spec the move channel rides under "
                         "(e.g. localfs:/tmp/fleet)")
    ap.add_argument("--migrate", default=None, metavar="SRC:DST@STEP",
                    help="at fleet step STEP, live-move SRC's slots "
                         "onto DST")
    ap.add_argument("--drain", default=None, metavar="NAME@STEP",
                    help="at fleet step STEP, move everything off NAME "
                         "and retire it from the rotation")
    ap.add_argument("--batch", type=int, default=None,
                    help="sessions frozen per move batch (bounds "
                         "per-session blackout)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="drain deadline in seconds (worst per-batch "
                         "blackout budget; missed = reported, not "
                         "aborted)")
    args = ap.parse_args(argv)

    if args.engines < 2 and (args.migrate or args.drain):
        print("[fleet] a move needs at least 2 engines", file=sys.stderr)
        return 2
    names = [f"e{i}" for i in range(args.engines)]
    migrate, err = _parse_move(args.migrate, "fleet", "--migrate", names)
    if err is not None:
        print(err, file=sys.stderr)
        return 2
    drain, err = _parse_move(args.drain, "fleet", "--drain", names)
    if err is not None:
        print(err, file=sys.stderr)
        return 2

    cfg = cfg_registry.resolve_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    engines = {n: ServingEngine(cfg, params, mesh, n_slots=args.slots,
                                max_seq=args.max_seq) for n in names}
    router = FleetRouter(engines, via=args.store,
                         migrate_batch=args.batch,
                         drain_deadline_s=args.deadline)
    traffic = TrafficGenerator(args.rate, seed=args.seed,
                               vocab=cfg.vocab_size,
                               limit=args.requests)

    t0 = time.monotonic()
    for step in range(1, args.steps + 1):
        traffic.tick(router)
        router.step()
        for mv, kind in ((migrate, "migrate"), (drain, "drain")):
            if mv is not None and step == mv[2]:
                src, dst, _ = mv
                if dst is None:
                    dst = min((n for n in names if n != src),
                              key=lambda n: len(
                                  engines[n].live_requests()))
                res = router.drain(src, dst) if kind == "drain" \
                    else router.migrate(src, dst)
                print(f"[fleet] {kind} {src} -> {dst}: "
                      f"{len(res.moved)} sessions moved, blackout "
                      f"{res.blackout_s * 1e3:.0f}ms "
                      f"({len(res.batches)} batches, {res.replayed} "
                      f"held requests replayed, deadline "
                      f"{'ok' if res.within_deadline else 'MISSED'})")
        if traffic.drained() and not router.inflight \
                and not router._held:
            break
    dt = time.monotonic() - t0

    s = router.stats()
    toks = sum(len(r.out) for r in router.completed.values())
    print(f"[fleet] {s['submitted']} requests, {toks} tokens in "
          f"{dt:.2f}s over {args.engines} engines "
          f"({s['completed']} completed, {s['dropped']} dropped, "
          f"{s['duplicates']} duplicated, {s['moves']} moves, worst "
          f"blackout {s['worst_blackout_s'] * 1e3:.0f}ms)")
    if s["dropped"] or s["duplicates"] or s["inflight"] or s["held"]:
        print(f"[fleet] FAILED: requests lost or duplicated: {s}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
