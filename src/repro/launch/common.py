"""Shared checkpoint-store surface for the launchers.

Both entry points (train, serve) speak the same store/resume flags and
build the same ``CheckpointSession``; this module is the single
definition of those flags, their validation, and the session
construction — so the surface can't drift between the two (the same
rule ``launch.supervise`` applies to the --supervise flags).

The store is a URI spec resolved through the ``repro.api`` backend
registry, which makes the paper's §V two-package claim a command-line
literal — swapping checkpoint packages is a one-string change:

    --store localfs:/tmp/job1                      # CRIU-analogue
    --store sharded:/tmp/job1?hosts=4&replicate=1  # DMTCP-analogue

``--ckpt-dir``/``--backend`` stay as legacy aliases that fold into a
store spec.
"""
from __future__ import annotations

import argparse
from typing import Optional, Tuple

from repro.api import CheckpointSession, Policy


def add_store_args(ap: argparse.ArgumentParser, *,
                   interval_flag: str = "--ckpt-every",
                   interval_default: int = 5,
                   interval_unit: str = "steps",
                   keep_last_default: Optional[int] = None) -> None:
    ap.add_argument("--store", default=None, metavar="URI",
                    help="checkpoint store spec 'scheme:/path[?k=v&...]' "
                         "(e.g. localfs:/tmp/job or "
                         "sharded:/tmp/job?hosts=4&replicate=1); "
                         "supersedes --ckpt-dir/--backend")
    ap.add_argument("--ckpt-dir", default=None,
                    help="legacy: checkpoint directory (folds into a "
                         "--store spec with --backend)")
    ap.add_argument("--backend", choices=("localfs", "sharded"),
                    default="localfs",
                    help="legacy: backend scheme for --ckpt-dir")
    ap.add_argument("--keep-last", type=int, default=keep_last_default,
                    help="retention: checkpoints to keep (default: "
                         f"{keep_last_default or 'all'})")
    ap.add_argument(interval_flag, type=int, default=interval_default,
                    help=f"snapshot cadence in {interval_unit}")
    ap.add_argument("--resume", nargs="?", const="latest", default=None,
                    metavar="STEP",
                    help="resume from a checkpoint: 'latest' (the bare "
                         "flag) or a step number; fails instead of "
                         "cold-starting when none is restorable")
    ap.add_argument("--streaming-restore", action="store_true",
                    help="stream the --resume: come back up as soon as "
                         "the hot tier (sessions, params) is decoded; "
                         "cold entries (optimizer moments, KV cache) "
                         "page in on first touch. Bit-identical to the "
                         "eager restore")


def resolve_store(args, prog: str) -> Tuple[Optional[str], Optional[str]]:
    """-> (store spec, error). Folds the legacy --ckpt-dir/--backend
    pair into a URI spec; a non-None error is the message the launcher
    prints before exiting 2."""
    if args.store and args.ckpt_dir:
        return None, (f"[{prog}] give --store or --ckpt-dir, not both "
                      "(--store already names the directory)")
    if args.store:
        return args.store, None
    if args.ckpt_dir:
        return f"{args.backend}:{args.ckpt_dir}", None
    return None, None


def build_session(spec: str, prog: str, *, interval: Optional[int] = None,
                  keep_last: Optional[int] = None,
                  ) -> Tuple[Optional[CheckpointSession], Optional[str]]:
    """-> (session, error): build the Policy AND resolve the store spec
    inside one error boundary, so any invalid flag value — bad scheme,
    bad parameter, bad cadence — becomes the launcher's one-line exit-2
    message, never a traceback. ``interval`` 0 means "no automatic
    cadence" on BOTH launchers (the store stays usable for explicit
    snapshots and resume)."""
    from repro.api.errors import PolicyError
    try:
        policy = Policy(interval=interval or None, keep_last=keep_last)
        return CheckpointSession(spec, policy), None
    except PolicyError as e:
        return None, f"[{prog}] {e}"


def restore_timings_line(inc) -> str:
    """The per-phase restore observability for a RESUMED banner: eager
    phase timings always; under a streaming restore, also the pipeline
    counters — fetch wall + per-source throughput, how much decode hid
    inside the fetch window, lazy faults served, hedges won."""
    t = inc.timings
    parts = [f"materialize {t.get('materialize_s', 0.0):.2f}s",
             f"replay {t.get('replay_s', 0.0):.2f}s"]
    if "rebind_s" in t:
        parts.append(f"rebind {t['rebind_s']:.2f}s")
    st = inc.stream_timings() if hasattr(inc, "stream_timings") else None
    if st is not None:
        rates = ", ".join(
            f"{k} {v:.1f}MB/s" for k, v in
            sorted(st.get("fetch_mb_s_per_source", {}).items()))
        stream = f"stream[fetch {st['fetch_s']:.2f}s"
        if rates:
            stream += f" ({rates})"
        stream += (f", decode overlap {st['decode_overlap_pct']:.0f}%, "
                   f"lazy faults {st['lazy_faults']}")
        if st.get("hedges"):
            stream += f", hedges won {st['hedge_wins']}/{st['hedges']}"
        parts.append(stream + "]")
    return ", ".join(parts)


def parse_resume_arg(args, prog: str
                     ) -> Tuple[bool, Optional[int], Optional[str]]:
    """-> (resume requested, explicit step or None, error)."""
    if args.resume is None:
        return False, None, None
    if args.resume == "latest":
        return True, None, None
    try:
        return True, int(args.resume), None
    except ValueError:
        return True, None, (f"[{prog}] --resume: expected 'latest' or a "
                            f"step number, got {args.resume!r}")


def validate_resume(sess: CheckpointSession, step: Optional[int],
                    where: str, prog: str
                    ) -> Tuple[Optional[int], Optional[str]]:
    """Resolve an explicit --resume against the committed steps whose
    delta chains are intact. -> (step, error)."""
    ok = sess.restorable_steps()
    if not ok or (step is not None and step not in ok):
        return None, (f"[{prog}] --resume: step "
                      f"{'latest' if step is None else step} not "
                      f"restorable in {where} (have {ok})")
    return step if step is not None else ok[-1], None
