"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, prove memory fit, and extract roofline
terms from the compiled artifact.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --skip-existing

Results land in benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json;
EXPERIMENTS.md tables are generated from those files.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing module (docstring above is not code):
# jax locks the device count on first backend init. The dry run — and
# ONLY the dry run — needs 512 placeholder host devices so
# jax.make_mesh can build the production mesh.

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as cfg_registry
from repro.configs.base import shapes_for
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import (analyze_hlo, roofline_terms,
                                       PEAK_FLOPS, HBM_BW, ICI_BW)
from repro.models import model as M
from repro.optim import abstract_opt_state
from repro.parallel.planner import make_plan, HBM_BYTES
from repro.train import step as step_lib
from repro.serving import engine as engine_lib

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def lower_cell(arch: str, shape_key: str, mesh, plan_overrides=None):
    """Returns (lowered, plan, aux) for one cell."""
    cfg = cfg_registry.get_config(arch)
    shape = cfg_registry.get_shape(shape_key)
    plan = make_plan(cfg, shape, mesh)
    if plan_overrides:
        plan = plan.with_(**plan_overrides)
    ab_params = M.init_abstract(cfg)

    if shape.kind == "train":
        fn, info = step_lib.jit_train_step(cfg, shape, mesh, plan=plan,
                                           donate=True)
        ab_opt = abstract_opt_state(ab_params, info["opt_cfg"])
        binputs = info["input_specs"]
        step = jax.ShapeDtypeStruct((), jnp.int32)
        lrs = jax.ShapeDtypeStruct((), jnp.float32)
        lowered = fn.lower(ab_params, ab_opt, binputs, step, lrs)
    elif shape.kind == "prefill":
        fn, info = engine_lib.jit_prefill(cfg, shape, mesh, plan=plan)
        specs = engine_lib.serve_input_specs(cfg, shape)
        args = [ab_params, specs["tokens"], specs["cache"]]
        if cfg.is_encoder_decoder:
            args.append(specs["frames"])
        lowered = fn.lower(*args)
    else:  # decode
        fn, info = engine_lib.jit_decode_step(cfg, shape, mesh, plan=plan)
        specs = engine_lib.serve_input_specs(cfg, shape)
        lowered = fn.lower(ab_params, specs["cache"], specs["tokens"],
                           specs["pos"])
    return lowered, plan


def run_cell(arch: str, shape_key: str, mesh_kind: str,
             plan_overrides=None, tag: str = "") -> dict:
    cfg = cfg_registry.get_config(arch)
    shape = cfg_registry.get_shape(shape_key)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))

    t0 = time.monotonic()
    lowered, plan = lower_cell(arch, shape_key, mesh, plan_overrides)
    t_lower = time.monotonic() - t0
    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0

    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
    # donated args alias outputs; live = args + temps
    live = mem["argument_bytes"] + mem["temp_bytes"]
    mem["live_bytes"] = live
    mem["fits_16g"] = bool(live < 0.98 * HBM_BYTES)
    print(compiled.memory_analysis())

    ca = compiled.cost_analysis() or {}
    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})

    hlo = compiled.as_text()
    hlo_dir = RESULTS_DIR.parent / "hlo" / mesh_kind
    hlo_dir.mkdir(parents=True, exist_ok=True)
    import gzip
    suffix = f"__{tag}" if tag else ""
    with gzip.open(hlo_dir / f"{arch}__{shape_key}{suffix}.txt.gz", "wt") as f:
        f.write(hlo)
    counts = analyze_hlo(hlo)
    terms = roofline_terms(counts)
    # kernel-adjusted: fusable streaming loops (flash attention / ssd
    # signature) charged at their streamed-block IO, as the validated
    # Pallas kernels execute them on TPU (see hlo_analysis.LoopProfile)
    terms_kernel = roofline_terms(counts, kernel_adjusted=True)
    fused_loops = [
        {"trips": lp.trips, "raw_gb": round(lp.raw_hbm / 2**30, 2),
         "stream_gb": round(lp.stream_hbm / 2**30, 2)}
        for lp in counts.loops if lp.fusable]

    n_active = cfg.n_active_params()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    if shape.kind == "train":
        model_flops = 6 * n_active * tokens
    else:
        model_flops = 2 * n_active * tokens
    model_flops_per_chip = model_flops / n_chips
    parsed = counts.flops
    useful = model_flops_per_chip / parsed if parsed else 0.0

    result = {
        "arch": arch, "shape": shape_key, "mesh": mesh_kind,
        "chips": n_chips, "tag": tag,
        "plan": plan.notes,
        "plan_overrides": plan_overrides or {},
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "xla_cost": {"flops": xla_flops, "bytes": xla_bytes,
                     "note": "loop bodies counted once by XLA"},
        "parsed": {
            "flops_per_chip": counts.flops,
            "hbm_bytes_per_chip": counts.hbm_bytes,
            "collective_bytes_per_chip": counts.collective_bytes,
            "collective_breakdown": counts.collective_breakdown,
            "n_collectives": counts.n_collectives,
            "while_trips": counts.while_trips[:16],
        },
        "roofline": terms,
        "roofline_kernel_adjusted": terms_kernel,
        "fused_loops": fused_loops,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flops_ratio": useful,
        "hw": {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "ici_bw": ICI_BW},
    }
    return result


def out_path(arch: str, shape_key: str, mesh_kind: str, tag: str = "") -> Path:
    d = RESULTS_DIR / mesh_kind
    d.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return d / f"{arch}__{shape_key}{suffix}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="", help="variant label (perf exps)")
    ap.add_argument("--plan-overrides", default="",
                    help='json, e.g. {"seq_shard": true}')
    args = ap.parse_args()

    overrides = json.loads(args.plan_overrides) if args.plan_overrides else None
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    cells = []
    if args.all:
        for arch in cfg_registry.ARCH_IDS:
            for s in shapes_for(cfg_registry.get_config(arch)):
                cells.append((arch, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for mesh_kind in meshes:
        for arch, shape_key in cells:
            p = out_path(arch, shape_key, mesh_kind, args.tag)
            if args.skip_existing and p.exists():
                print(f"skip {p.name} ({mesh_kind})")
                continue
            print(f"=== {arch} x {shape_key} on {mesh_kind} ===", flush=True)
            try:
                res = run_cell(arch, shape_key, mesh_kind, overrides,
                               args.tag)
                p.write_text(json.dumps(res, indent=1))
                r = res["roofline"]
                print(f"ok: dominant={r['dominant']} "
                      f"compute={r['compute_s']:.4f}s "
                      f"memory={r['memory_s']:.4f}s "
                      f"collective={r['collective_s']:.4f}s "
                      f"frac={r['roofline_fraction']:.2f} "
                      f"live={res['memory']['live_bytes']/2**30:.1f}GiB",
                      flush=True)
            except Exception as e:
                traceback.print_exc()
                failures.append((mesh_kind, arch, shape_key, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
