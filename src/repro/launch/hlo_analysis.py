"""HLO roofline analyzer.

Parses post-SPMD optimized HLO text (compiled.as_text()) and derives the
three roofline terms, correctly scaling ops inside while loops by their
trip counts (XLA's aggregate cost_analysis counts loop bodies ONCE, which
under-reports a scanned 80-layer transformer by ~80x — verified
empirically; see EXPERIMENTS.md §Dry-run).

Per-chip accounting (HLO shapes are already per-device after SPMD):
  flops            — dot/convolution ops: 2 * prod(result dims) *
                     prod(lhs contracting dims), x trip multiplier;
                     recursing into fusion bodies (dots can be fused).
  hbm bytes        — sum over surface ops (fusion/dot/collective/gather/
                     scatter/sort/custom-call) of operand+result bytes,
                     x trip multiplier. Fusion internals excluded: a
                     fusion reads inputs once and writes outputs once.
  collective bytes — per-chip wire bytes with ring factors:
                     all-gather/all-to-all: result x (n-1)/n
                     all-reduce:            result x 2(n-1)/n
                     reduce-scatter:        result x (n-1)
                     collective-permute:    result x 1
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(type_str: str) -> int:
    """Bytes of one (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return m.group(1), dims


@dataclass
class Op:
    name: str
    result_type: str
    kind: str
    operands: List[str]
    attrs: str
    line: str


@dataclass
class Computation:
    name: str
    params: Dict[str, str] = field(default_factory=dict)  # %pname -> type
    ops: Dict[str, Op] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)


_COMP_HDR = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->\s*(.+?)\s*\{")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|[\w\[\]\{\},\d]+)\s*"
    r"([\w\-]+)\((.*)$")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line.strip())
        if hdr and ("->" in line):
            cur = Computation(hdr.group(1))
            # parse parameter types from the signature
            for pm in re.finditer(r"%?([\w.\-]+):\s*((?:\([^)]*\))|[\w\[\]\{\},\d/]+)",
                                  hdr.group(2)):
                cur.params[pm.group(1)] = pm.group(2)
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, rtype, kind, rest = m.groups()
            # operands: %refs inside the first (...) group
            depth = 1
            args = []
            buf = ""
            for ch in rest:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        args.append(buf)
                        break
                if depth >= 1 and ch != ")":
                    buf += ch
            arg_str = args[0] if args else ""
            operands = re.findall(r"%([\w.\-]+)", arg_str)
            attrs = rest[len(arg_str):]
            op = Op(name, rtype, kind, operands, attrs, line)
            cur.ops[name] = op
            cur.order.append(name)
    return comps


def _operand_type(comp: Computation, comps: Dict[str, Computation],
                  name: str) -> str:
    if name in comp.ops:
        return comp.ops[name].result_type
    if name in comp.params:
        return comp.params[name]
    return ""


def _trip_count(cond_comp: Computation,
                comps: Dict[str, "Computation"]) -> int:
    """Extract the loop bound from a while condition computation.

    Handles both a bare `compare(%iv, %constant)` and XLA:CPU's
    `fusion(%iv, %constant), calls=%wrapped_compare_computation` form."""
    consts: Dict[str, int] = {}
    for op in cond_comp.ops.values():
        if op.kind == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.line)
            if m:
                consts[op.name] = int(m.group(1))

    def direction_of(op: Op) -> str:
        dm = re.search(r"direction=(\w+)", op.line)
        if dm:
            return dm.group(1)
        fm = re.search(r"calls=%?([\w.\-]+)", op.line)
        if fm and fm.group(1) in comps:
            for inner in comps[fm.group(1)].ops.values():
                if inner.kind == "compare":
                    dm = re.search(r"direction=(\w+)", inner.line)
                    if dm:
                        return dm.group(1)
        return "LT"

    for op in cond_comp.ops.values():
        if op.kind in ("compare", "fusion"):
            hit = [consts[o] for o in op.operands if o in consts]
            if hit:
                n = hit[0]
                return n + 1 if direction_of(op) == "LE" else max(n, 1)
    if consts:
        return max(max(consts.values()), 1)
    return 1


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# set via analyze_hlo(assume_bf16=...): count f32 collective payloads at
# bf16 width (the XLA:CPU bf16-dot upcast artifact; see inline comment)
_BF16_COLLECTIVE_FIX = False
# HBM-traffic surface: ops that read/write HBM on TPU. Standalone
# layout/element ops (transpose, reshape, concatenate, iota, slice,
# reduce) are excluded — XLA:TPU fuses them into neighbors, while the
# XLA:CPU HLO we parse leaves many standalone; counting them would
# overstate the TPU memory term.
_SURFACE = ("fusion", "dot", "convolution", "gather", "scatter", "sort",
            "custom-call") + _COLLECTIVES


def _is_convert_wrapper(comp: Computation) -> bool:
    """fusion body containing only converts/copies/bitcasts (dtype
    roundtrips inserted by XLA:CPU's bf16-dot upcast)."""
    kinds = {o.kind for o in comp.ops.values()}
    return bool(kinds) and kinds <= {"parameter", "convert", "copy",
                                     "bitcast", "transpose"}


def _group_size(op: Op) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", op.line)
    if m:
        return len(m.group(1).split(","))
    return 2


def _dot_flops(comp: Computation, comps, op: Op) -> int:
    _, rdims = shape_dims(op.result_type)
    lhs_type = _operand_type(comp, comps, op.operands[0]) if op.operands else ""
    _, ldims = shape_dims(lhs_type)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract = 1
    if cm and cm.group(1):
        for d in cm.group(1).split(","):
            if d and int(d) < len(ldims):
                contract *= ldims[int(d)]
    rsize = 1
    for d in rdims:
        rsize *= d
    return 2 * rsize * max(contract, 1)


@dataclass
class LoopProfile:
    """One while loop's contribution (already x trips x outer mult)."""
    trips: int = 1
    raw_hbm: float = 0.0       # surface-op traffic inside the body
    stream_hbm: float = 0.0    # per-trip xs reads + ys writes only
    n_dots: int = 0
    has_exp: bool = False
    has_inner: bool = False    # contains nested while loops

    @property
    def fusable(self) -> bool:
        """Streaming-softmax / streaming-recurrence signature: an
        *innermost* loop whose body re-materializes O(block^2) tiles
        that a Pallas kernel (see kernels/) keeps in VMEM, streaming
        only the per-trip input blocks. kernels/flash_attention and
        kernels/ssd_scan implement exactly this fusion and validate
        against the same math. Outer loops (the layer scan, microbatch
        accumulation) also contain exp+dots but are NOT kernels — the
        innermost restriction excludes them (and prevents
        double-subtracting nested loops)."""
        return (self.has_exp and self.n_dots >= 2 and self.trips > 1
                and not self.has_inner)


@dataclass
class RooflineCounts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: Dict[str, float] = field(default_factory=dict)
    n_collectives: int = 0
    while_trips: List[int] = field(default_factory=list)
    loops: List[LoopProfile] = field(default_factory=list)
    # internal accumulators for loop profiling
    n_dots: int = 0
    n_exp: int = 0
    stream_bytes: float = 0.0

    def merge(self, sub: "RooflineCounts") -> None:
        self.flops += sub.flops
        self.hbm_bytes += sub.hbm_bytes
        self.collective_bytes += sub.collective_bytes
        for k, v in sub.collective_breakdown.items():
            self.collective_breakdown[k] = \
                self.collective_breakdown.get(k, 0.0) + v
        self.n_collectives += sub.n_collectives
        self.while_trips.extend(sub.while_trips)
        self.loops.extend(sub.loops)
        self.n_dots += sub.n_dots
        self.n_exp += sub.n_exp
        self.stream_bytes += sub.stream_bytes

    def hbm_bytes_kernel_adjusted(self) -> float:
        """Memory traffic if fusable streaming loops ran as the Pallas
        kernels: subtract their measured body traffic, add back the
        streamed block IO (dynamic-slice reads / dynamic-update-slice
        writes per trip)."""
        adj = self.hbm_bytes
        for lp in self.loops:
            if lp.fusable:
                adj -= lp.raw_hbm
                adj += lp.stream_hbm
        return max(adj, 0.0)


def _walk(comp: Computation, comps: Dict[str, Computation], mult: float,
          out: RooflineCounts, surface: bool) -> None:
    for name in comp.order:
        op = comp.ops[name]
        kind = op.kind
        if kind == "while":
            body_m = re.search(r"body=%?([\w.\-]+)", op.line)
            cond_m = re.search(r"condition=%?([\w.\-]+)", op.line)
            trips = 1
            if cond_m and cond_m.group(1) in comps:
                trips = _trip_count(comps[cond_m.group(1)], comps)
            out.while_trips.append(trips)
            if body_m and body_m.group(1) in comps:
                sub = RooflineCounts()
                _walk(comps[body_m.group(1)], comps, mult * trips, sub, True)
                out.loops.append(LoopProfile(
                    trips=trips, raw_hbm=sub.hbm_bytes,
                    stream_hbm=sub.stream_bytes, n_dots=sub.n_dots,
                    has_exp=sub.n_exp > 0, has_inner=bool(sub.loops)))
                out.merge(sub)
            continue
        if kind in ("conditional", "call"):
            for cm in re.finditer(r"(?:branch_computations=\{|to_apply=)%?([\w.\-]+)",
                                  op.line):
                if cm.group(1) in comps:
                    _walk(comps[cm.group(1)], comps, mult, out, surface)
            continue
        if kind == "fusion":
            fm = re.search(r"calls=%?([\w.\-]+)", op.line)
            if fm and fm.group(1) in comps:
                called = comps[fm.group(1)]
                # dots inside fusions count as flops; traffic at boundary
                _walk(called, comps, mult, out, False)
                if _is_convert_wrapper(called):
                    # pure dtype-roundtrip fusion (convert/copy/bitcast
                    # only): the XLA:CPU bf16-upcast artifact — TPU never
                    # materializes these. Skip their traffic entirely.
                    continue
        if kind in ("dot", "convolution"):
            out.flops += mult * _dot_flops(comp, comps, op)
            out.n_dots += 1
        if kind == "exponential":
            out.n_exp += 1
        # streamed block IO (counted at any depth — slices may be fused):
        # what a Pallas kernel would actually move per grid step
        if kind == "dynamic-slice":
            out.stream_bytes += mult * shape_bytes(op.result_type)
        if kind == "dynamic-update-slice" and len(op.operands) >= 2:
            out.stream_bytes += mult * shape_bytes(
                _operand_type(comp, comps, op.operands[1]))
        if not surface:
            continue
        if kind in _COLLECTIVES:
            rbytes = shape_bytes(op.result_type)
            # XLA:CPU has no native bf16 dots: it upcasts operands to f32,
            # and the SPMD partitioner then moves those f32 tensors over
            # collectives. On TPU the same program moves bf16 (MXU-native).
            # Count f32 collective payloads at bf16 width when the model
            # computes in bf16 (set by the dry-run; verified against the
            # convert(bf16)->convert(f32) wrapper fusions in the HLO).
            if _BF16_COLLECTIVE_FIX and "f32[" in op.result_type:
                rbytes = rbytes / 2
            n = _group_size(op)
            if kind == "all-reduce":
                wire = rbytes * 2 * (n - 1) / n
            elif kind in ("all-gather", "all-to-all"):
                wire = rbytes * (n - 1) / n
            elif kind == "reduce-scatter":
                wire = rbytes * (n - 1)
            else:  # collective-permute
                wire = rbytes
            out.collective_bytes += mult * wire
            out.collective_breakdown[kind] = \
                out.collective_breakdown.get(kind, 0.0) + mult * wire
            out.n_collectives += 1
            out.hbm_bytes += mult * 2 * rbytes
            continue
        if kind in _SURFACE:
            b = shape_bytes(op.result_type)
            for o in op.operands:
                b += shape_bytes(_operand_type(comp, comps, o))
            out.hbm_bytes += mult * b


def analyze_hlo(text: str, assume_bf16: bool = True) -> RooflineCounts:
    global _BF16_COLLECTIVE_FIX
    _BF16_COLLECTIVE_FIX = assume_bf16
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: the computation with the most ops
        entry = max(comps, key=lambda c: len(comps[c].ops))
    out = RooflineCounts()
    _walk(comps[entry], comps, 1.0, out, True)
    return out


# hardware targets (TPU v5e per chip)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def roofline_terms(counts: RooflineCounts,
                   kernel_adjusted: bool = False) -> Dict[str, float]:
    t_c = counts.flops / PEAK_FLOPS
    hbm = counts.hbm_bytes_kernel_adjusted() if kernel_adjusted \
        else counts.hbm_bytes
    t_m = hbm / HBM_BW
    t_x = counts.collective_bytes / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    bound = max(t_c, t_m, t_x)
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": dom[1],
        "bound_s": bound,
        "roofline_fraction": (t_c / bound) if bound > 0 else 0.0,
    }
