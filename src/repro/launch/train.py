"""Training launcher: run any registry architecture under the C/R
runtime, with automatic restore-if-checkpoint-exists semantics (the
production crash-loop contract: the same command line either cold-starts
or transparently resumes).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b-smoke \
      --shape train_s32_b4 --steps 20 --ckpt-dir /tmp/job1 [--backend sharded]

Re-running the identical command after a kill continues bitwise from the
last committed checkpoint. ``--resume [latest|STEP]`` makes the intent
explicit: it *requires* a restorable checkpoint (and can pick a specific
step), where the default behavior silently falls back to a cold start.

``--supervise`` closes the failure loop in-process: the run is routed
under a ``ClusterSupervisor`` over a simulated ``--hosts``-host world
(deterministic virtual clock, one tick per step) with ``--spares`` idle
hosts and ``--heartbeat-timeout`` ticks of silence meaning death.
``--kill-host H@STEP`` injects a host death mid-run; the supervisor
detects it, decides (hot-spare > shrink > restart-last-ckpt), and
executes the decision end-to-end — storage repair, Incarnation restore,
logged shard rebalance — then training continues:

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b-smoke \
      --steps 20 --ckpt-dir /tmp/job1 --backend sharded \
      --supervise --hosts 4 --spares 1 --kill-host 2@8
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.core import (CheckpointManager, ClusterSupervisor,
                        FailureAction, make_backend)
from repro.launch.supervise import (SimWorldDriver, add_supervise_args,
                                    parse_supervise_args)
from repro.train.loop import Trainer, TrainJob


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="registry id or '<id>-smoke'")
    ap.add_argument("--shape", default="train_s32_b4",
                    help="shape cell or '<kind>_s<seq>_b<batch>'")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--backend", choices=("localfs", "sharded"),
                    default="localfs")
    ap.add_argument("--keep-last", type=int, default=3)
    ap.add_argument("--data-mesh", type=int, default=0,
                    help="data axis size (0 = all local devices)")
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--resume", nargs="?", const="latest", default=None,
                    metavar="STEP",
                    help="resume from a checkpoint: 'latest' (the bare "
                         "flag) or a step number; fails instead of "
                         "cold-starting when none is restorable")
    add_supervise_args(ap)
    args = ap.parse_args(argv)

    kill, err = parse_supervise_args(args, "launch")
    if err is not None:
        print(err, file=sys.stderr)
        return 2

    n_dev = len(jax.devices())
    d = args.data_mesh or (n_dev // args.model_mesh)
    mgr = CheckpointManager(make_backend(args.backend, args.ckpt_dir),
                            async_save=True, keep_last=args.keep_last)

    resume_step = None
    if args.resume is not None and args.resume != "latest":
        try:
            resume_step = int(args.resume)
        except ValueError:
            print(f"[launch] --resume: expected 'latest' or a step "
                  f"number, got {args.resume!r}", file=sys.stderr)
            return 2
    if args.resume is not None:
        from repro.core.restore import restorable_steps
        ok = restorable_steps(mgr.backend)
        if not ok:
            print(f"[launch] --resume: no restorable checkpoint in "
                  f"{args.ckpt_dir}", file=sys.stderr)
            return 2
        if resume_step is not None and resume_step not in ok:
            print(f"[launch] --resume: step {resume_step} not restorable "
                  f"(have {ok})", file=sys.stderr)
            return 2
        if resume_step is None:
            resume_step = ok[-1]  # newest step with an intact chain

    if mgr.backend.latest_step() is not None:
        tr = Trainer.restore(mgr, step=resume_step)
        inc = tr.incarnation
        print(f"[launch] RESUMED {args.arch} at step "
              f"{int(tr.upper.get('step'))} from {args.ckpt_dir} "
              f"(materialize {inc.timings['materialize_s']:.2f}s, "
              f"replay {inc.timings['replay_s']:.2f}s, "
              f"rebind {inc.timings.get('rebind_s', 0.0):.2f}s)")
    else:
        job = TrainJob(arch=args.arch, shape_key=args.shape)
        tr = Trainer(job, (d, args.model_mesh), ("data", "model"),
                     manager=mgr)
        tr.init_state()
        print(f"[launch] COLD START {args.arch} on mesh "
              f"({d},{args.model_mesh})")

    if args.supervise:
        tr = _run_supervised(args, mgr, tr, kill)
    else:
        start = int(tr.upper.get("step"))
        for step in range(start, args.steps):
            m = tr.train_steps(1)
            print(f"step {m['step']:5.0f} loss {m['loss']:.4f} "
                  f"lr {m['lr']:.2e}", flush=True)
            if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
                tr.save(block=False)
    mgr.wait()
    print(f"[launch] done at step {int(tr.upper.get('step'))}; "
          f"checkpoints: {mgr.backend.list_steps()}")
    return 0


def _run_supervised(args, mgr, tr, kill):
    """The failure loop around the step loop: every step is one tick of
    the simulated world's clock; live hosts heartbeat, the supervisor
    polls, and an executed decision swaps the runner under us (the
    restored trainer resumes from the last committed step — the
    crash-loop contract, but automated)."""
    world = list(range(args.hosts))
    spares = list(range(args.hosts, args.hosts + args.spares))
    driver = SimWorldDriver(kill)

    def restore(target):
        t = Trainer.restore(mgr, step=target.step,
                            rewrite_op=target.rewrite_op())
        print(f"[supervisor] restored at step "
              f"{int(t.upper.get('step'))} on hosts {target.hosts}")
        return t

    sup = ClusterSupervisor(
        world, manager=mgr, spares=spares,
        heartbeat_timeout=args.heartbeat_timeout,
        clock=driver.clock, n_shards=tr.shape.global_batch,
        allow_shrink=not args.no_shrink,
        restore=restore, runner=tr)
    driver.attach(sup)
    if mgr.backend.latest_step() is None:
        tr.save(block=True)   # baseline: a death before the first
        # --ckpt-every commit still has a restore target
    step = int(tr.upper.get("step"))
    while step < args.steps:
        tr = sup.runner
        m = tr.train_steps(1)
        step = int(tr.upper.get("step"))
        print(f"step {m['step']:5.0f} loss {m['loss']:.4f} "
              f"hosts {sup.world}", flush=True)
        if step % args.ckpt_every == 0 or step == args.steps:
            tr.save(block=False)
        target = driver.tick(step)
        if target is not None \
                and target.action is not FailureAction.HOT_SPARE:
            step = int(sup.runner.upper.get("step"))  # rolled back
    driver.warn_if_kill_pending()
    for inc in sup.incidents:
        print(f"[supervisor] incident {inc.action}: dead={inc.dead} "
              f"step={inc.step} mttr={inc.wall_s:.2f}s")
    return sup.runner


if __name__ == "__main__":
    sys.exit(main())
