"""Training launcher: run any registry architecture under the C/R
runtime, with automatic restore-if-checkpoint-exists semantics (the
production crash-loop contract: the same command line either cold-starts
or transparently resumes).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b-smoke \
      --shape train_s32_b4 --steps 20 --store localfs:/tmp/job1

Swapping checkpoint packages is a one-string change (the paper's §V
claim at the command line): ``--store sharded:/tmp/job1?hosts=4``.
``--ckpt-dir`` (+ ``--backend``) remain as legacy aliases.

Re-running the identical command after a kill continues bitwise from the
last committed checkpoint. ``--resume [latest|STEP]`` makes the intent
explicit: it *requires* a restorable checkpoint (and can pick a specific
step), where the default behavior silently falls back to a cold start.

``--supervise`` closes the failure loop in-process: the run is routed
under a ``ClusterSupervisor`` over a simulated ``--hosts``-host world
(deterministic virtual clock, one tick per step) with ``--spares`` idle
hosts and ``--heartbeat-timeout`` ticks of silence meaning death.
``--kill-host H@STEP`` injects a host death mid-run; the supervisor
detects it, decides (hot-spare > shrink > restart-last-ckpt), and
executes the decision end-to-end — storage repair, restore through the
session's app-kind registry, logged shard rebalance — then training
continues:

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b-smoke \
      --steps 20 --store sharded:/tmp/job1 \
      --supervise --hosts 4 --spares 1 --kill-host 2@8
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.core import FailureAction, IncidentLog
from repro.launch.common import (add_store_args, build_session,
                                 parse_resume_arg, resolve_store,
                                 restore_timings_line, validate_resume)
from repro.launch.supervise import (SimWorldDriver, add_supervise_args,
                                    parse_churn_args, parse_drain_arg,
                                    parse_supervise_args)
from repro.train.loop import Trainer, TrainJob


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="registry id or '<id>-smoke'")
    ap.add_argument("--shape", default="train_s32_b4",
                    help="shape cell or '<kind>_s<seq>_b<batch>'")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--data-mesh", type=int, default=0,
                    help="data axis size (0 = all local devices)")
    ap.add_argument("--model-mesh", type=int, default=1)
    add_store_args(ap, interval_flag="--ckpt-every", interval_default=5,
                   keep_last_default=3)
    add_supervise_args(ap)
    args = ap.parse_args(argv)

    kill, err = parse_supervise_args(args, "launch")
    if err is not None:
        print(err, file=sys.stderr)
        return 2
    drain, err = parse_drain_arg(args, "launch")
    if err is not None:
        print(err, file=sys.stderr)
        return 2
    trace, err = parse_churn_args(args, "launch", horizon=args.steps)
    if err is not None:
        print(err, file=sys.stderr)
        return 2
    spec, err = resolve_store(args, "launch")
    if err is not None:
        print(err, file=sys.stderr)
        return 2
    if spec is None:
        print("[launch] a checkpoint store is required: --store "
              "scheme:/path (or legacy --ckpt-dir DIR)", file=sys.stderr)
        return 2
    resume, resume_step, err = parse_resume_arg(args, "launch")
    if err is not None:
        print(err, file=sys.stderr)
        return 2

    n_dev = len(jax.devices())
    d = args.data_mesh or (n_dev // args.model_mesh)
    sess, err = build_session(spec, "launch", interval=args.ckpt_every,
                              keep_last=args.keep_last)
    if err is not None:
        print(err, file=sys.stderr)
        return 2

    if resume:
        resume_step, err = validate_resume(sess, resume_step, spec,
                                           "launch")
        if err is not None:
            print(err, file=sys.stderr)
            return 2

    if sess.latest_step() is not None:
        tr = sess.restore(step=resume_step, expect_kind="train",
                          streaming=args.streaming_restore or None)
        inc = tr.incarnation
        print(f"[launch] RESUMED {args.arch} at step "
              f"{tr.checkpoint_step()} from {spec} "
              f"({restore_timings_line(inc)})")
    else:
        job = TrainJob(arch=args.arch, shape_key=args.shape)
        tr = sess.attach(Trainer(job, (d, args.model_mesh),
                                 ("data", "model"), manager=sess.manager))
        tr.init_state()
        print(f"[launch] COLD START {args.arch} on mesh "
              f"({d},{args.model_mesh})")

    if args.supervise:
        tr = _run_supervised(args, sess, tr, kill, drain, trace)
    else:
        for step in range(tr.checkpoint_step(), args.steps):
            m = tr.train_steps(1)
            print(f"step {m['step']:5.0f} loss {m['loss']:.4f} "
                  f"lr {m['lr']:.2e}", flush=True)
            sess.maybe_snapshot(final=step + 1 == args.steps)
    sess.wait()
    print(f"[launch] done at step {tr.checkpoint_step()}; "
          f"checkpoints: {sess.backend.list_steps()}")
    return 0


def _run_supervised(args, sess, tr, kill, drain=None, trace=None):
    """The failure loop around the step loop: every step is one tick of
    the simulated world's clock; live hosts heartbeat, the supervisor
    polls, and an executed decision swaps the runner under us — the
    restore goes back through the session's app-kind registry, so the
    supervisor never touches trainer-specific code. Scripted --drain
    triggers and full --churn traces run through the same
    ``ChurnEngine``: preemption notices snapshot proactively and drain
    before the deadline, returned hosts re-enter the spare pool, and
    the engine grows the world back when capacity is idle."""
    world = list(range(args.hosts))
    spares = list(range(args.hosts, args.hosts + args.spares))
    driver = SimWorldDriver(kill, drain, trace=trace,
                            snapshot=lambda: sess.snapshot(block=True))

    def on_restored(t, target):
        print(f"[supervisor] restored at step "
              f"{t.checkpoint_step()} on hosts {target.hosts}")

    sink = IncidentLog(args.incident_log) if args.incident_log else None
    sup = sess.supervise(
        world, spares=spares,
        heartbeat_timeout=args.heartbeat_timeout,
        clock=driver.clock, n_shards=tr.shape.global_batch,
        allow_shrink=not args.no_shrink,
        on_restored=on_restored, event_sink=sink)
    driver.attach(sup)
    if sess.latest_step() is None:
        sess.snapshot(block=True)   # baseline: a death before the first
        # --ckpt-every commit still has a restore target
    step = tr.checkpoint_step()
    while step < args.steps:
        tr = sup.runner
        m = tr.train_steps(1)
        step = tr.checkpoint_step()
        print(f"step {m['step']:5.0f} loss {m['loss']:.4f} "
              f"hosts {sup.world}", flush=True)
        sess.maybe_snapshot(final=step == args.steps)
        targets = driver.tick(step)
        if any(t.action is not FailureAction.HOT_SPARE
               for t in targets):
            step = sup.runner.checkpoint_step()  # rolled back
    driver.warn_if_kill_pending()
    for inc in sup.incidents:
        print(f"[supervisor] incident {inc.action}: dead={inc.dead} "
              f"step={inc.step} mttr={inc.wall_s:.2f}s")
    driver.print_goodput()
    if sink is not None:
        sink.close()
    return sup.runner


if __name__ == "__main__":
    sys.exit(main())
