"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init; smoke
tests run with the single real CPU device).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests, elastic restore targets)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: Optional[int] = None, n_model: Optional[int] = None):
    """Best-effort mesh over whatever devices exist (tests/examples).
    Defaults to putting all devices on the data axis."""
    devs = jax.devices()
    n = len(devs)
    if n_data is None and n_model is None:
        n_data, n_model = n, 1
    elif n_data is None:
        n_data = n // n_model
    elif n_model is None:
        n_model = n // n_data
    assert n_data * n_model == n, (n_data, n_model, n)
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def mesh_axes(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def batch_axes_of(mesh) -> Tuple[str, ...]:
    """Data-parallel axes: pod (if present) folded into data."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mesh_devices(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
