"""Shared --supervise surface for the launchers.

Both entry points (train, serve) route their run under a
``ClusterSupervisor`` with the same knobs and the same simulated-world
mechanics; this module is the single definition of the flags, their
validation, and the world driver (virtual clock, heartbeat fan-out
with the injected kill excluded, one poll per tick) — so none of it
can drift between the two. Only the runner-specific step/restore logic
stays in each launcher.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Tuple


def add_supervise_args(ap: argparse.ArgumentParser,
                       unit: str = "step") -> None:
    """``unit`` names the simulated clock tick in help text ("step" for
    training, "engine step" for serving)."""
    ap.add_argument("--supervise", action="store_true",
                    help="run under a ClusterSupervisor (detect -> "
                         "decide -> restore) over a simulated world")
    # world-shape flags default to None so "explicitly set but
    # --supervise forgotten" is distinguishable from "left alone" —
    # parse_supervise_args rejects the former and fills the defaults in
    ap.add_argument("--hosts", type=int, default=None,
                    help="simulated world size under --supervise "
                         "(default 2)")
    ap.add_argument("--spares", type=int, default=0,
                    help="idle spare hosts the hot-spare policy may use")
    ap.add_argument("--heartbeat-timeout", type=float, default=None,
                    help="ticks of heartbeat silence before a host is "
                         f"declared dead (one tick per {unit}; "
                         "default 3)")
    ap.add_argument("--no-shrink", action="store_true",
                    help="forbid elastic shrink: a death with no spare "
                         "restarts from the last checkpoint")
    ap.add_argument("--kill-host", default=None, metavar="H@STEP",
                    help=f"fault injection: host H stops heartbeating "
                         f"at {unit} STEP (needs --supervise)")
    ap.add_argument("--drain", default=None, metavar="H@STEP",
                    help=f"planned move: at {unit} STEP, drain healthy "
                         "host H onto a spare (or shrink the world if "
                         "none) via supervisor.planned_move (needs "
                         "--supervise)")


def parse_supervise_args(args, prog: str
                         ) -> Tuple[Optional[Tuple[int, int]],
                                    Optional[str]]:
    """-> (kill, error). ``kill`` is the parsed (host, step) injection
    or None; a non-None ``error`` is the message the launcher should
    print before exiting 2. Also normalizes the None-sentinel defaults
    of --hosts/--heartbeat-timeout."""
    if not args.supervise and (args.kill_host is not None or args.spares
                               or args.no_shrink
                               or args.hosts is not None
                               or args.heartbeat_timeout is not None
                               or getattr(args, "drain", None) is not None):
        return None, (f"[{prog}] --hosts/--spares/--heartbeat-timeout/"
                      "--no-shrink/--kill-host/--drain only make sense "
                      "under --supervise (nothing would watch the "
                      "heartbeats)")
    if args.hosts is None:
        args.hosts = 2
    if args.heartbeat_timeout is None:
        args.heartbeat_timeout = 3.0
    if args.kill_host is None:
        return None, None
    try:
        h, s = args.kill_host.split("@")
        kill = (int(h), int(s))
    except ValueError:
        return None, (f"[{prog}] --kill-host: expected H@STEP, got "
                      f"{args.kill_host!r}")
    if not 0 <= kill[0] < args.hosts:
        # an out-of-world host would silently never die — the user
        # would believe the failure path was exercised when it wasn't
        return None, (f"[{prog}] --kill-host: host {kill[0]} is not in "
                      f"the simulated world 0..{args.hosts - 1}")
    return kill, None


def parse_drain_arg(args, prog: str
                    ) -> Tuple[Optional[Tuple[int, int]], Optional[str]]:
    """-> (drain, error): the parsed --drain (host, step) planned-move
    trigger, validated like --kill-host. Call AFTER
    ``parse_supervise_args`` (it fills the --hosts default)."""
    spec = getattr(args, "drain", None)
    if spec is None:
        return None, None
    try:
        h, s = spec.split("@")
        drain = (int(h), int(s))
    except ValueError:
        return None, (f"[{prog}] --drain: expected H@STEP, got {spec!r}")
    if not 0 <= drain[0] < args.hosts:
        return None, (f"[{prog}] --drain: host {drain[0]} is not in "
                      f"the simulated world 0..{args.hosts - 1}")
    if args.kill_host is not None and drain[0] == int(
            args.kill_host.split("@")[0]):
        return None, (f"[{prog}] --drain and --kill-host target the same "
                      f"host {drain[0]}; a drained host has already left "
                      "the world — pick different hosts")
    return drain, None


class SimWorldDriver:
    """The simulated world around a supervised run: one virtual-clock
    tick per step, every live host heartbeats (the injected kill stays
    silent from its step on), then one supervisor poll. Construct the
    driver first, hand ``driver.clock`` to the ClusterSupervisor, then
    ``attach`` it."""

    def __init__(self, kill: Optional[Tuple[int, int]],
                 drain: Optional[Tuple[int, int]] = None) -> None:
        self.kill = kill
        self.drain = drain
        self.sup = None
        self._t = 0.0

    def clock(self) -> float:
        return self._t

    def attach(self, sup) -> "SimWorldDriver":
        self.sup = sup
        return self

    def tick(self, step: int):
        """Advance the world one step; returns the executed decision's
        RestoreTarget (None when nothing died). An executed incident
        clears the kill — it is resolved, whichever policy ran."""
        self._t += 1.0
        for h in self.sup.world:
            if self.kill is not None and h == self.kill[0] \
                    and step >= self.kill[1]:
                continue
            self.sup.beat(h, step)
        target = self.sup.poll()
        if target is not None:
            print(f"[supervisor] {target.action.value}: dead="
                  f"{target.dead} -> hosts={target.hosts} "
                  f"(mttr {self.sup.incidents[-1].wall_s:.2f}s)")
            self.kill = None
        if self.drain is not None and step >= self.drain[1]:
            host, self.drain = self.drain[0], None
            moved = self.sup.planned_move(host)
            inc = self.sup.incidents[-1]
            print(f"[supervisor] {inc.action}: host {host} -> hosts="
                  f"{moved.hosts} (blackout {inc.wall_s:.2f}s)")
            return moved if target is None else target
        return target

    def warn_if_kill_pending(self) -> None:
        """Call after the run's loop: a --kill-host that never produced
        an incident (run ended before the silence crossed the timeout)
        must be said out loud, or the user believes the failure path
        was exercised when it wasn't."""
        if self.kill is not None:
            print(f"[supervisor] WARNING: --kill-host "
                  f"{self.kill[0]}@{self.kill[1]} never triggered an "
                  f"incident — the run ended before the death could be "
                  f"detected (raise --steps or lower "
                  f"--heartbeat-timeout)", file=sys.stderr)
        if self.drain is not None:
            print(f"[supervisor] WARNING: --drain "
                  f"{self.drain[0]}@{self.drain[1]} never ran — the run "
                  f"ended before the trigger step", file=sys.stderr)
