"""Shared --supervise surface for the launchers.

Both entry points (train, serve) route their run under a
``ClusterSupervisor`` with the same knobs and the same simulated-world
mechanics; this module is the single definition of the flags, their
validation, and the world driver — so none of it can drift between the
two. Only the runner-specific step/restore logic stays in each
launcher.

The driver is a thin shell over ``core.churn.ChurnEngine``: scripted
``--kill-host`` / ``--drain`` occurrences (repeatable) become a small
``ChurnTrace``, and the general form — a recorded JSONL trace
(``--churn-trace``) or a seeded generator (``--churn
poisson:rate=...,seed=...``) — drives deaths, grace-window preemptions,
returns and elastic grow through the same engine. ``--incident-log``
taps the supervisor's event stream as operator-readable JSONL.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional, Tuple, Union

from repro.core.churn import ChurnEngine, ChurnEvent, ChurnTrace

Spec = Union[None, Tuple[int, int], List[Tuple[int, int]]]


def add_supervise_args(ap: argparse.ArgumentParser,
                       unit: str = "step") -> None:
    """``unit`` names the simulated clock tick in help text ("step" for
    training, "engine step" for serving)."""
    ap.add_argument("--supervise", action="store_true",
                    help="run under a ClusterSupervisor (detect -> "
                         "decide -> restore) over a simulated world")
    # world-shape flags default to None so "explicitly set but
    # --supervise forgotten" is distinguishable from "left alone" —
    # parse_supervise_args rejects the former and fills the defaults in
    ap.add_argument("--hosts", type=int, default=None,
                    help="simulated world size under --supervise "
                         "(default 2)")
    ap.add_argument("--spares", type=int, default=0,
                    help="idle spare hosts the hot-spare policy may use")
    ap.add_argument("--heartbeat-timeout", type=float, default=None,
                    help="ticks of heartbeat silence before a host is "
                         f"declared dead (one tick per {unit}; "
                         "default 3)")
    ap.add_argument("--no-shrink", action="store_true",
                    help="forbid elastic shrink: a death with no spare "
                         "restarts from the last checkpoint")
    ap.add_argument("--kill-host", action="append", default=None,
                    metavar="H@STEP",
                    help=f"fault injection: host H stops heartbeating "
                         f"at {unit} STEP (needs --supervise; "
                         "repeatable)")
    ap.add_argument("--drain", action="append", default=None,
                    metavar="H@STEP",
                    help=f"planned move: at {unit} STEP, drain healthy "
                         "host H onto a spare (or shrink the world if "
                         "none) via supervisor.planned_move (needs "
                         "--supervise; repeatable)")
    ap.add_argument("--churn-trace", default=None, metavar="FILE",
                    help="replay a JSONL churn trace (die / "
                         "preempt+grace / return / drain events) "
                         "against the run (needs --supervise)")
    ap.add_argument("--churn", default=None, metavar="SPEC",
                    help="generated churn: 'poisson:rate=0.1,seed=1"
                         "[,preempt=0.5][,grace=3][,return=8]"
                         "[,events=50]' or 'racks:rate=0.05,size=2,"
                         "seed=1' (needs --supervise)")
    ap.add_argument("--incident-log", default=None, metavar="PATH",
                    help="append the supervisor's event stream to PATH "
                         "as JSONL, one line per event, as it happens")


def parse_supervise_args(args, prog: str
                         ) -> Tuple[List[Tuple[int, int]], Optional[str]]:
    """-> (kills, error). ``kills`` is the list of parsed (host, step)
    injections (possibly empty); a non-None ``error`` is the message
    the launcher should print before exiting 2. Also normalizes the
    None-sentinel defaults of --hosts/--heartbeat-timeout."""
    if not args.supervise and (args.kill_host is not None or args.spares
                               or args.no_shrink
                               or args.hosts is not None
                               or args.heartbeat_timeout is not None
                               or getattr(args, "drain", None) is not None
                               or getattr(args, "churn_trace", None)
                               is not None
                               or getattr(args, "churn", None) is not None
                               or getattr(args, "incident_log", None)
                               is not None):
        return [], (f"[{prog}] --hosts/--spares/--heartbeat-timeout/"
                    "--no-shrink/--kill-host/--drain/--churn[-trace]/"
                    "--incident-log only make sense under --supervise "
                    "(nothing would watch the heartbeats)")
    if args.hosts is None:
        args.hosts = 2
    if args.heartbeat_timeout is None:
        args.heartbeat_timeout = 3.0
    kills: List[Tuple[int, int]] = []
    for spec in args.kill_host or []:
        try:
            h, s = spec.split("@")
            kill = (int(h), int(s))
        except ValueError:
            return [], (f"[{prog}] --kill-host: expected H@STEP, got "
                        f"{spec!r}")
        if not 0 <= kill[0] < args.hosts:
            # an out-of-world host would silently never die — the user
            # would believe the failure path was exercised when it wasn't
            return [], (f"[{prog}] --kill-host: host {kill[0]} is not in "
                        f"the simulated world 0..{args.hosts - 1}")
        kills.append(kill)
    return kills, None


def parse_drain_arg(args, prog: str
                    ) -> Tuple[List[Tuple[int, int]], Optional[str]]:
    """-> (drains, error): the parsed --drain (host, step) planned-move
    triggers, validated like --kill-host. Call AFTER
    ``parse_supervise_args`` (it fills the --hosts default)."""
    killed = set()
    for spec in args.kill_host or []:
        try:
            killed.add(int(spec.split("@")[0]))
        except ValueError:
            pass   # parse_supervise_args already reported it
    drains: List[Tuple[int, int]] = []
    for spec in getattr(args, "drain", None) or []:
        try:
            h, s = spec.split("@")
            drain = (int(h), int(s))
        except ValueError:
            return [], (f"[{prog}] --drain: expected H@STEP, got "
                        f"{spec!r}")
        if not 0 <= drain[0] < args.hosts:
            return [], (f"[{prog}] --drain: host {drain[0]} is not in "
                        f"the simulated world 0..{args.hosts - 1}")
        if drain[0] in killed:
            return [], (f"[{prog}] --drain and --kill-host target the "
                        f"same host {drain[0]}; a drained host has "
                        "already left the world — pick different hosts")
        drains.append(drain)
    return drains, None


def parse_churn_args(args, prog: str, horizon: float
                     ) -> Tuple[Optional[ChurnTrace], Optional[str]]:
    """-> (trace, error): the replayed (--churn-trace FILE) or generated
    (--churn SPEC, over world hosts 0..hosts-1 up to ``horizon`` ticks
    unless the spec pins its own) churn trace, or None when neither
    flag was given. Call AFTER ``parse_supervise_args``."""
    file = getattr(args, "churn_trace", None)
    spec = getattr(args, "churn", None)
    if file is not None and spec is not None:
        return None, (f"[{prog}] --churn-trace and --churn are mutually "
                      "exclusive (one trace per run)")
    if file is not None:
        try:
            return ChurnTrace.load(file), None
        except (OSError, ValueError) as e:
            return None, f"[{prog}] --churn-trace {file}: {e}"
    if spec is not None:
        try:
            return ChurnTrace.from_spec(
                spec, list(range(args.hosts)), horizon=horizon), None
        except ValueError as e:
            return None, f"[{prog}] --churn: {e}"
    return None, None


def _as_events(spec: Spec, kind: str) -> List[ChurnEvent]:
    pairs = [spec] if isinstance(spec, tuple) else list(spec or [])
    return [ChurnEvent(t=float(s), kind=kind, host=int(h))
            for h, s in pairs]


class SimWorldDriver:
    """The simulated world around a supervised run: one virtual-clock
    tick per step, every live host heartbeats (hosts the trace killed
    stay silent), then one supervisor poll, then elastic grow toward
    the starting world size when idle capacity exists. Construct the
    driver first, hand ``driver.clock`` to the ClusterSupervisor, then
    ``attach`` it.

    Scripted ``kill``/``drain`` events (a single (host, step) pair or a
    list of them) and a full ``trace`` compose into one ``ChurnTrace``
    driven by ``core.churn.ChurnEngine``; ``snapshot`` is the blocking
    proactive-snapshot hook preemption notices and grows use.
    """

    def __init__(self, kill: Spec = None, drain: Spec = None, *,
                 trace: Optional[ChurnTrace] = None,
                 snapshot=None, grow: bool = True,
                 min_grace: float = 1.0) -> None:
        events = list(trace.events) if trace is not None else []
        events += _as_events(kill, "die")
        events += _as_events(drain, "drain")
        self.engine = ChurnEngine(ChurnTrace(events), snapshot=snapshot,
                                  grow=grow, min_grace=min_grace)
        self.sup = None

    def clock(self) -> float:
        return self.engine.clock()

    def attach(self, sup) -> "SimWorldDriver":
        self.sup = sup
        self.engine.attach(sup)
        return self

    def tick(self, step: int) -> list:
        """Advance the world one step; returns every executed decision's
        RestoreTarget (empty list on a quiet tick), printing one line
        per incident."""
        n0 = len(self.sup.incidents)
        executed = self.engine.tick(step)
        for inc in self.sup.incidents[n0:]:
            print(f"[supervisor] {inc.action}: dead={inc.dead} -> "
                  f"hosts={self.sup.world} (mttr {inc.wall_s:.2f}s)")
        return executed

    def goodput(self):
        return self.engine.report()

    def print_goodput(self, label: str = "churn") -> None:
        rep = self.engine.report()
        if not rep.incidents and not self.engine.trace.events:
            return
        print(f"[{label}] goodput {rep.goodput:.2f} "
              f"({rep.useful_steps} useful / {rep.attempted_steps} "
              f"attempted steps, {rep.lost_steps} lost, "
              f"{len(rep.incidents)} incidents, "
              f"{rep.proactive_preempts} proactive preempts, "
              f"{rep.grows} grows)")

    def warn_if_kill_pending(self) -> None:
        """Call after the run's loop: trace events that never fired, or
        a death whose silence never crossed the timeout, must be said
        out loud — or the user believes the failure path was exercised
        when it wasn't."""
        for ev in self.engine.unfired_events():
            print(f"[supervisor] WARNING: churn event {ev.kind} host "
                  f"{ev.host}@{ev.t:g} never fired — the run ended "
                  f"before its step (raise --steps)", file=sys.stderr)
        for host in self.engine.unresolved_hosts():
            print(f"[supervisor] WARNING: host {host} went silent but "
                  f"never produced an incident — the run ended before "
                  f"the death could be detected (raise --steps or "
                  f"lower --heartbeat-timeout)", file=sys.stderr)
