"""jit'd public wrapper for the flash-attention kernel.

On TPU this is the compiled Pallas kernel; elsewhere it runs in interpret
mode (correctness path used by tests). Model code calls this through
models.layers when CallOptions.use_flash_kernel is set.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_q",
                                    "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = None):
    if interpret is None:
        interpret = not _on_tpu()
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


# --- differentiable variant (custom VJP over the Pallas fwd/bwd kernels) ---

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_diff(q, k, v, causal=True, window=0, block_q=128,
                         block_k=128, interpret=False):
    out, _ = flash_attention_fwd(q, k, v, causal=causal, window=window,
                                 block_q=block_q, block_k=block_k,
                                 interpret=interpret, return_lse=True)
    return out


def _fa_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    out, lse = flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k,
                                   interpret=interpret, return_lse=True)
    return out, (q, k, v, out, lse)


def _fa_bwd(causal, window, block_q, block_k, interpret, res, do):
    from repro.kernels.flash_attention.backward import flash_attention_bwd
    q, k, v, out, lse = res
    dq, dk, dv = flash_attention_bwd(
        q, k, v, out, lse, do, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return dq, dk, dv


flash_attention_diff.defvjp(_fa_fwd, _fa_bwd)
