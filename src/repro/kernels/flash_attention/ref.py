"""Pure-jnp oracle for flash attention: naive full-softmax GQA attention
with identical masking semantics."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [B,H,Sq,hd]; k/v: [B,Hkv,Skv,hd] -> [B,H,Sq,hd] (f32 math)."""
    B, H, Sq, hd = q.shape
    _, Hkv, Skv, _ = k.shape
    g = H // Hkv
    scale = 1.0 / np.sqrt(hd)
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, Sq, hd) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bkcd->bkgqc", qf, kf)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bkcd->bkgqd", p, vf)
    return o.reshape(B, H, Sq, hd).astype(q.dtype)
