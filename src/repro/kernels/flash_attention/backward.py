"""Pallas TPU kernels: flash attention backward (dq, dk, dv).

Standard two-kernel split (no atomics on TPU — each kernel owns the
accumulator that matches its grid order):
  * dq kernel:   grid (B, H, i, j) — kv sequential, dq accumulates in
                 VMEM scratch across j (same layout as the forward).
  * dk/dv kernel: grid (B, Hkv, j, i*G) — q-block x group sequential,
                 dk/dv accumulate across (i, g); GQA groups fold into
                 the sequential axis so a kv head sees all its q heads.

Both recompute p from (q, k, softmax stats) per tile — the flash trade:
O(S^2) recompute to keep HBM traffic linear. The forward kernel is
extended to emit the logsumexp row stats (saved residual).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _masks(i, j, bq, bk, sq, skv, causal, window):
    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = (k_pos < skv) & (q_pos < sq)
    if causal:
        valid = valid & (k_pos <= q_pos)
    if window > 0:
        valid = valid & (q_pos - k_pos < window)
    return valid


# --- dq ---------------------------------------------------------------------

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_sc, *, scale, causal, window, bq, bk, n_kv, sq, skv):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _():
        acc_sc[...] = jnp.zeros_like(acc_sc)

    live = jnp.bool_(True)
    if causal:
        live = live & ((j * bk) <= (i * bq + bq - 1))
    if window > 0:
        live = live & ((i * bq) - (j * bk + bk - 1) < window)

    @pl.when(live)
    def _():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]                       # [bq]
        delta = delta_ref[0, 0]                   # [bq]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        valid = _masks(i, j, bq, bk, sq, skv, causal, window)
        s = jnp.where(valid, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        acc_sc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == n_kv - 1)
    def _():
        dq_ref[0, 0] = acc_sc[...].astype(dq_ref.dtype)


# --- dk / dv -----------------------------------------------------------------

def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_sc, dv_sc, *,
                scale, causal, window, bq, bk, n_qg, sq, skv, group):
    j, ig = pl.program_id(2), pl.program_id(3)
    i = ig // group   # q block

    @pl.when(ig == 0)
    def _():
        dk_sc[...] = jnp.zeros_like(dk_sc)
        dv_sc[...] = jnp.zeros_like(dv_sc)

    live = jnp.bool_(True)
    if causal:
        live = live & ((j * bk) <= (i * bq + bq - 1))
    if window > 0:
        live = live & ((i * bq) - (j * bk + bk - 1) < window)

    @pl.when(live)
    def _():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        valid = _masks(i, j, bq, bk, sq, skv, causal, window)
        s = jnp.where(valid, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])             # [bq, bk]
        dv_sc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_sc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) / scale

    @pl.when(ig == n_qg - 1)
    def _():
        # ds was computed against the pre-scaled q, so the /scale in the
        # accumulation already restored raw-q units — write through.
        dk_ref[0, 0] = dk_sc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_sc[...].astype(dv_ref.dtype)


def flash_attention_bwd(
    q, k, v, out, lse, do, *,
    causal=True, window=0, block_q=128, block_k=128, interpret=False,
):
    """q:[B,H,Sq,hd] k/v:[B,Hkv,Skv,hd] out/do:[B,H,Sq,hd] lse:[B,H,Sq].
    Returns (dq, dk, dv) with dk/dv summed over each kv head's group."""
    B, H, Sq, hd = q.shape
    _, Hkv, Skv, _ = k.shape
    group = H // Hkv
    scale = 1.0 / np.sqrt(hd)
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)

    from repro.kernels.flash_attention.kernel import _pad_to
    qp, dop, outp = (_pad_to(x, 2, bq) for x in (q, do, out))
    kp, vp = (_pad_to(x, 2, bk) for x in (k, v))
    lsep = _pad_to(lse, 2, bq)
    n_q = qp.shape[2] // bq
    n_kv = kp.shape[2] // bk

    # delta = rowsum(do * out)  [B,H,Sq]
    delta = jnp.sum(dop.astype(jnp.float32) * outp.astype(jnp.float32),
                    axis=-1)

    def cp(sem):
        if interpret:
            return {}
        c = getattr(pltpu, "CompilerParams", None) or \
            getattr(pltpu, "TPUCompilerParams")
        return {"compiler_params": c(dimension_semantics=sem)}

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, n_kv=n_kv,
                          sq=Sq, skv=Skv),
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
        **cp(("parallel", "parallel", "parallel", "arbitrary")),
    )(qp, kp, vp, dop, lsep, delta)

    n_qg = n_q * group
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, n_qg=n_qg,
                          sq=Sq, skv=Skv, group=group),
        grid=(B, Hkv, n_kv, n_qg),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd),
                         lambda b, hk, j, ig, g=group:
                         (b, hk * g + ig % g, ig // g, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, hk, j, ig: (b, hk, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, hk, j, ig: (b, hk, j, 0)),
            pl.BlockSpec((1, 1, bq, hd),
                         lambda b, hk, j, ig, g=group:
                         (b, hk * g + ig % g, ig // g, 0)),
            pl.BlockSpec((1, 1, bq),
                         lambda b, hk, j, ig, g=group:
                         (b, hk * g + ig % g, ig // g)),
            pl.BlockSpec((1, 1, bq),
                         lambda b, hk, j, ig, g=group:
                         (b, hk * g + ig % g, ig // g)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, hd), lambda b, hk, j, ig: (b, hk, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, hk, j, ig: (b, hk, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(kp.shape, k.dtype),
            jax.ShapeDtypeStruct(vp.shape, v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bk, hd), jnp.float32),
                        pltpu.VMEM((bk, hd), jnp.float32)],
        interpret=interpret,
        **cp(("parallel", "parallel", "parallel", "arbitrary")),
    )(qp, kp, vp, dop, lsep, delta)

    return dq[:, :, :Sq], dk[:, :, :Skv], dv[:, :, :Skv]
