"""Pallas TPU kernel: blocked causal GQA flash attention (forward).

TPU-native adaptation (DESIGN.md): rather than porting the CUDA warp
layout, blocks are sized for the MXU (128-aligned bq x bk score tiles)
and VMEM residency. Grid = (batch, q_heads, q_blocks, kv_blocks); the kv
axis is the innermost (sequential) dimension, carrying the streaming
softmax state (m, l, acc) in VMEM scratch across kv steps — the same
recurrence models/layers.chunked_attention uses, so that pure-jnp path is
the oracle.

GQA is expressed in the BlockSpec index maps: the kv block index maps
h -> h // group, so no head replication is materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *rest,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int, n_kv: int, sq: int, skv: int,
                  with_lse: bool = False):
    if with_lse:
        lse_ref, m_sc, l_sc, acc_sc = rest
    else:
        (m_sc, l_sc, acc_sc), lse_ref = rest, None
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = k_pos < skv
    if causal:
        valid = valid & (k_pos <= q_pos)
    if window > 0:
        valid = valid & (q_pos - k_pos < window)

    # whole-block skip (causal upper triangle / outside window): the
    # scratch state is untouched, so skipped blocks cost ~nothing.
    block_live = jnp.bool_(True)
    if causal:
        block_live = block_live & ((j * bk) <= (i * bq + bq - 1))
    if window > 0:
        block_live = block_live & ((i * bq) - (j * bk + bk - 1) < window)

    @pl.when(block_live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)              # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)              # [bk, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)
        if with_lse:
            lse_ref[0, 0] = m_sc[...] + jnp.log(l)


def flash_attention_fwd(
    q: jax.Array,              # [B, H, Sq, hd]
    k: jax.Array,              # [B, Hkv, Skv, hd]
    v: jax.Array,              # [B, Hkv, Skv, hd]
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    return_lse: bool = False,
):
    B, H, Sq, hd = q.shape
    _, Hkv, Skv, _ = k.shape
    assert H % Hkv == 0
    group = H // Hkv
    scale = 1.0 / np.sqrt(hd)

    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    # pad seq dims to block multiples (masked via skv/sq bounds)
    qp = _pad_to(q, 2, bq)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    n_q = qp.shape[2] // bq
    n_kv = kp.shape[2] // bk

    grid = (B, H, n_q, n_kv)
    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv=n_kv, sq=Sq, skv=Skv, with_lse=return_lse)

    kwargs = {}
    if not interpret:
        cp = getattr(pltpu, "CompilerParams", None) or \
            getattr(pltpu, "TPUCompilerParams")
        kwargs["compiler_params"] = cp(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    out_specs = pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0))
    out_shape = jax.ShapeDtypeStruct(qp.shape, q.dtype)
    if return_lse:
        out_specs = [out_specs,
                     pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i))]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct(qp.shape[:3], jnp.float32)]
    res = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(qp, kp, vp)
    if return_lse:
        out, lse = res
        return out[:, :, :Sq], lse[:, :, :Sq]
    return res[:, :, :Sq]


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)
