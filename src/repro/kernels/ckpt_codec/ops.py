"""jit'd wrappers for the checkpoint codec kernel (padding, device
dispatch, interpret fallback on CPU)."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ckpt_codec import kernel as K
from repro.kernels.ckpt_codec.ref import BLOCK, FP_CHUNK_BYTES

# The backend never changes within a process, but jax.default_backend()
# re-resolves the platform stack on every call — and every new input
# shape retraces these jit wrappers, re-probing it. Resolve once.
_INTERPRET_DEFAULT: Optional[bool] = None


def _default_interpret() -> bool:
    global _INTERPRET_DEFAULT
    if _INTERPRET_DEFAULT is None:
        _INTERPRET_DEFAULT = jax.default_backend() != "tpu"
    return _INTERPRET_DEFAULT


def _on_tpu() -> bool:
    return not _default_interpret()


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize(x: jax.Array, *, interpret: bool = None):
    """x: f32 any shape -> (q [nb, BLOCK] int8, scale [nb] f32)."""
    if interpret is None:
        interpret = _default_interpret()
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    xb = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    nb = xb.shape[0]
    # pad rows so the tile divides evenly
    rows = min(K.ROWS_PER_TILE, nb)
    rpad = (-nb) % rows
    if rpad:
        xb = jnp.pad(xb, ((0, rpad), (0, 0)))
    q, s = K.quantize_blocks(xb, interpret=interpret)
    return q[:nb], s[:nb]


@functools.partial(jax.jit, static_argnames=("interpret",))
def _xor_i32(a: jax.Array, b: jax.Array, *, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    nb = a.shape[0]
    rows = min(K.ROWS_PER_TILE, nb)
    rpad = (-nb) % rows
    if rpad:
        a = jnp.pad(a, ((0, rpad), (0, 0)))
        b = jnp.pad(b, ((0, rpad), (0, 0)))
    return K.xor_blocks(a, b, interpret=interpret)[:nb]


def delta_encode(x: np.ndarray, prev: np.ndarray, *,
                 interpret: bool = None) -> np.ndarray:
    """Byte XOR of two equal-length byte buffers through the Pallas
    kernel (TPU path of the chained snapshot encoder; the host path in
    core.delta uses numpy directly). Returns uint8[len]."""
    a = np.frombuffer(np.ascontiguousarray(x), np.uint8)
    b = np.frombuffer(np.ascontiguousarray(prev), np.uint8)
    assert a.size == b.size, (a.size, b.size)
    n = a.size
    lane_bytes = 4 * BLOCK
    pad = (-n) % lane_bytes
    if pad:
        a = np.concatenate([a, np.zeros(pad, np.uint8)])
        b = np.concatenate([b, np.zeros(pad, np.uint8)])
    ai = jnp.asarray(a.view(np.int32).reshape(-1, BLOCK))
    bi = jnp.asarray(b.view(np.int32).reshape(-1, BLOCK))
    out = np.asarray(jax.device_get(_xor_i32(ai, bi, interpret=interpret)))
    return out.view(np.uint8).reshape(-1)[:n]


def delta_decode(delta: np.ndarray, prev: np.ndarray, dtype,
                 shape, *, interpret: bool = None) -> np.ndarray:
    """XOR is its own inverse; reinterpret the result. ``interpret``
    is forwarded to the encode kernel (a CPU caller forcing
    ``interpret=True`` must not silently get the probed default)."""
    raw = delta_encode(delta, prev, interpret=interpret)
    return np.frombuffer(raw.tobytes(), dtype=dtype).reshape(shape)


# ---------------------------------------------------------------------------
# dirty-chunk fingerprints + device-side gather compaction
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk_bytes", "interpret"))
def _fingerprint_impl(x: jax.Array, *, chunk_bytes: int,
                      interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    xi = _device_i32_chunks(x, chunk_bytes)
    rows = chunk_bytes // (4 * BLOCK)
    return K.fingerprint_blocks(xi.reshape(-1, BLOCK), rows,
                                interpret=interpret)


def _device_i32_chunks(x: jax.Array, chunk_bytes: int) -> jax.Array:
    """Reinterpret a device array as i32 [n_chunks, chunk_elems] without
    leaving the device (zero-padded to a chunk multiple)."""
    flat = x.reshape(-1)
    if flat.dtype.itemsize != 4:
        b = jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(-1)
        pad = (-b.size) % chunk_bytes
        if pad:
            b = jnp.pad(b, (0, pad))
        xi = jax.lax.bitcast_convert_type(b.reshape(-1, 4), jnp.int32)
    else:
        xi = jax.lax.bitcast_convert_type(flat, jnp.int32)
        pad = (-xi.size) % (chunk_bytes // 4)
        if pad:
            xi = jnp.pad(xi, (0, pad))
    return xi.reshape(-1, chunk_bytes // 4)


def chunk_fingerprints(x, chunk_bytes: int = FP_CHUNK_BYTES, *,
                       interpret: bool = None) -> jax.Array:
    """Per-chunk fingerprints of a (device or host) array through the
    Pallas kernel: i32 [n_chunks, 2]. The leaf is read once on device;
    only the fingerprints are small enough to compare/keep resident.
    chunk_bytes must be a multiple of 4*BLOCK (one i32 lane row)."""
    assert chunk_bytes % (4 * BLOCK) == 0, chunk_bytes
    return _fingerprint_impl(jnp.asarray(x), chunk_bytes=chunk_bytes,
                             interpret=interpret)


@jax.jit
def _dirty_mask(fp: jax.Array, prev_fp: jax.Array) -> jax.Array:
    return jnp.any(fp != prev_fp, axis=1)


@functools.partial(jax.jit, static_argnames=("chunk_bytes",))
def _gather_chunks(x: jax.Array, idx: jax.Array, *, chunk_bytes: int):
    xi = _device_i32_chunks(x, chunk_bytes)
    return jnp.take(xi, idx, axis=0)


def dirty_chunk_capture(x, prev_fp, chunk_bytes: int = FP_CHUNK_BYTES, *,
                        interpret: bool = None
                        ) -> Tuple[jax.Array, np.ndarray, Optional[np.ndarray]]:
    """Device-side incremental capture of one leaf — the two-launch
    path (fingerprint launch, mask sync, gather launch, payload sync).
    Kept as the explicit fallback for :func:`fused_dirty_chunk_capture`
    (compaction-buffer overflow) and for callers that cannot bound the
    dirty count up front; the pipeline's default is the fused kernel.

    Fingerprints ``x`` on device, compares against the previous
    snapshot's device-resident fingerprints, gather-compacts the dirty
    chunks on device, and returns
    ``(new_fp [device], dirty_idx [host i64], dirty_bytes [host u8
    [k, chunk_bytes] or None])`` — the data makes exactly one
    device->host hop, sized by what changed rather than by the leaf.

    The gather index vector is padded to the next power of two so jit
    retraces O(log n_chunks) variants, not one per dirty count.
    """
    fp = chunk_fingerprints(x, chunk_bytes, interpret=interpret)
    mask = np.asarray(jax.device_get(_dirty_mask(fp, prev_fp)))
    idx = np.nonzero(mask)[0]
    if idx.size == 0:
        return fp, idx, None
    padded = 1 << (idx.size - 1).bit_length()
    idxp = np.full(padded, idx[-1], np.int32)
    idxp[:idx.size] = idx
    compact = _gather_chunks(jnp.asarray(x), jnp.asarray(idxp),
                             chunk_bytes=chunk_bytes)
    host = np.asarray(jax.device_get(compact))[:idx.size]
    return fp, idx, host.view(np.uint8).reshape(idx.size, chunk_bytes)


# ---------------------------------------------------------------------------
# fused single-pass capture (fingerprint + compare + compact, one launch)
# ---------------------------------------------------------------------------

# the compaction buffer stays VMEM-resident for the whole grid (constant
# index map), so its size is bounded; 8 MB leaves room for the input
# chunk tile + fingerprints inside a 16 MB VMEM
_FUSED_VMEM_BUDGET = 8 << 20
# capacity floor: below this the pow-of-two bucketing would retrace the
# jit wrapper for every tiny dirty-count fluctuation
_FUSED_MIN_CAPACITY = 8


def fused_capacity(n_chunks: int, chunk_bytes: int,
                   hint: Optional[int] = None) -> int:
    """Compaction-buffer capacity (in chunks) for one fused launch.

    2x the caller's hint (the leaf's dirty count last snapshot — change
    rates are stable step to step), clamped to the leaf and to the VMEM
    budget, then rounded up to a power of two so jit retraces O(log)
    capacity variants instead of one per dirty count."""
    cap = max(_FUSED_MIN_CAPACITY,
              2 * (hint if hint is not None else _FUSED_MIN_CAPACITY))
    cap = min(cap, n_chunks, max(1, _FUSED_VMEM_BUDGET // chunk_bytes))
    return 1 << (cap - 1).bit_length()


@functools.partial(jax.jit,
                   static_argnames=("chunk_bytes", "capacity", "interpret"))
def _fused_capture_impl(x: jax.Array, prev_fp: jax.Array, *,
                        chunk_bytes: int, capacity: int,
                        interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    xi = _device_i32_chunks(x, chunk_bytes)
    rows = chunk_bytes // (4 * BLOCK)
    return K.fused_capture_blocks(xi.reshape(-1, BLOCK), prev_fp, rows,
                                  capacity, interpret=interpret)


def fused_dirty_chunk_capture(
        x, prev_fp, chunk_bytes: int = FP_CHUNK_BYTES, *,
        capacity_hint: Optional[int] = None, interpret: bool = None
        ) -> Tuple[jax.Array, np.ndarray, Optional[np.ndarray]]:
    """Single-pass incremental capture of one leaf: exactly ONE kernel
    launch and ONE blocking device->host transfer.

    The fused kernel reads the leaf once, computes the 2-lane chunk
    fingerprints, compares them in-kernel against the device-resident
    previous fingerprints, and prefix-sum-compacts the dirty chunks into
    a bounded buffer; ``(count, idx, compact)`` come back in one
    ``device_get``. Returns the same ``(new_fp [device], dirty_idx
    [host i64], dirty_bytes [host u8 [k, chunk_bytes] or None])``
    contract as :func:`dirty_chunk_capture`, which remains the explicit
    fallback: when more than ``capacity`` chunks are dirty (the kernel
    keeps counting past the buffer so the host can tell), the gather
    path finishes the job, reusing the fingerprints already computed.

    ``capacity_hint`` sizes the compaction buffer (chunks dirty last
    snapshot); see :func:`fused_capacity` for the clamping policy.
    """
    assert chunk_bytes % (4 * BLOCK) == 0, chunk_bytes
    xd = jnp.asarray(x)
    if isinstance(prev_fp, np.ndarray):  # ref-twin callers hold u32
        prev_fp = prev_fp.view(np.int32)
    n_chunks = -(-xd.nbytes // chunk_bytes)
    capacity = fused_capacity(n_chunks, chunk_bytes, capacity_hint)
    fp, cnt, idx, compact = _fused_capture_impl(
        xd, prev_fp, chunk_bytes=chunk_bytes, capacity=capacity,
        interpret=interpret)
    # the one blocking hop: count + indices + compacted payload together
    cnt_h, idx_h, compact_h = jax.device_get((cnt, idx, compact))
    k = int(cnt_h[0, 0])
    if k == 0:
        return fp, np.empty(0, np.int64), None
    if k > capacity:
        # overflow: the change rate outran the buffer. Finish via the
        # two-launch gather fallback, reusing the fingerprints (costs
        # the old path's extra sync — but only on the rare step whose
        # dirty count more than doubled; the caller's next hint is k)
        mask = np.asarray(jax.device_get(_dirty_mask(fp, prev_fp)))
        full_idx = np.nonzero(mask)[0]
        padded = 1 << (full_idx.size - 1).bit_length()
        idxp = np.full(padded, full_idx[-1], np.int32)
        idxp[:full_idx.size] = full_idx
        gathered = _gather_chunks(xd, jnp.asarray(idxp),
                                  chunk_bytes=chunk_bytes)
        host = np.asarray(jax.device_get(gathered))[:full_idx.size]
        return (fp, full_idx.astype(np.int64),
                host.view(np.uint8).reshape(full_idx.size, chunk_bytes))
    rows = chunk_bytes // (4 * BLOCK)
    dirty_idx = idx_h[:k, 0].astype(np.int64)
    dirty_bytes = np.ascontiguousarray(compact_h[:k * rows]) \
        .view(np.uint8).reshape(k, chunk_bytes)
    return fp, dirty_idx, dirty_bytes


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize(q: jax.Array, scale: jax.Array, *, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    nb = q.shape[0]
    rows = min(K.ROWS_PER_TILE, nb)
    rpad = (-nb) % rows
    if rpad:
        q = jnp.pad(q, ((0, rpad), (0, 0)))
        scale = jnp.pad(scale, (0, rpad))
    x = K.dequantize_blocks(q, scale, interpret=interpret)
    return x[:nb].reshape(-1)
