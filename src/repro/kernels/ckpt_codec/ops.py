"""jit'd wrappers for the checkpoint codec kernel (padding, device
dispatch, interpret fallback on CPU)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ckpt_codec import kernel as K
from repro.kernels.ckpt_codec.ref import BLOCK


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize(x: jax.Array, *, interpret: bool = None):
    """x: f32 any shape -> (q [nb, BLOCK] int8, scale [nb] f32)."""
    if interpret is None:
        interpret = not _on_tpu()
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    xb = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    nb = xb.shape[0]
    # pad rows so the tile divides evenly
    rows = min(K.ROWS_PER_TILE, nb)
    rpad = (-nb) % rows
    if rpad:
        xb = jnp.pad(xb, ((0, rpad), (0, 0)))
    q, s = K.quantize_blocks(xb, interpret=interpret)
    return q[:nb], s[:nb]


@functools.partial(jax.jit, static_argnames=("interpret",))
def _xor_i32(a: jax.Array, b: jax.Array, *, interpret: bool = None):
    if interpret is None:
        interpret = not _on_tpu()
    nb = a.shape[0]
    rows = min(K.ROWS_PER_TILE, nb)
    rpad = (-nb) % rows
    if rpad:
        a = jnp.pad(a, ((0, rpad), (0, 0)))
        b = jnp.pad(b, ((0, rpad), (0, 0)))
    return K.xor_blocks(a, b, interpret=interpret)[:nb]


def delta_encode(x: np.ndarray, prev: np.ndarray, *,
                 interpret: bool = None) -> np.ndarray:
    """Byte XOR of two equal-length byte buffers through the Pallas
    kernel (TPU path of the chained snapshot encoder; the host path in
    core.delta uses numpy directly). Returns uint8[len]."""
    a = np.frombuffer(np.ascontiguousarray(x), np.uint8)
    b = np.frombuffer(np.ascontiguousarray(prev), np.uint8)
    assert a.size == b.size, (a.size, b.size)
    n = a.size
    lane_bytes = 4 * BLOCK
    pad = (-n) % lane_bytes
    if pad:
        a = np.concatenate([a, np.zeros(pad, np.uint8)])
        b = np.concatenate([b, np.zeros(pad, np.uint8)])
    ai = jnp.asarray(a.view(np.int32).reshape(-1, BLOCK))
    bi = jnp.asarray(b.view(np.int32).reshape(-1, BLOCK))
    out = np.asarray(jax.device_get(_xor_i32(ai, bi, interpret=interpret)))
    return out.view(np.uint8).reshape(-1)[:n]


def delta_decode(delta: np.ndarray, prev: np.ndarray, dtype,
                 shape) -> np.ndarray:
    """XOR is its own inverse; reinterpret the result."""
    raw = delta_encode(delta, prev)
    return np.frombuffer(raw.tobytes(), dtype=dtype).reshape(shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize(q: jax.Array, scale: jax.Array, *, interpret: bool = None):
    if interpret is None:
        interpret = not _on_tpu()
    nb = q.shape[0]
    rows = min(K.ROWS_PER_TILE, nb)
    rpad = (-nb) % rows
    if rpad:
        q = jnp.pad(q, ((0, rpad), (0, 0)))
        scale = jnp.pad(scale, (0, rpad))
    x = K.dequantize_blocks(q, scale, interpret=interpret)
    return x[:nb].reshape(-1)
