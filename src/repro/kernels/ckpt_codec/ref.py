"""Pure reference for the checkpoint codec: int8 block quantization
(256-lane blocks, symmetric, per-block scale) + delta encoding.

numpy implementations (host checkpoint path) are the oracle the Pallas
kernel is validated against.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

BLOCK = 256


def quantize_ref(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """x: f32 any-shape -> (q int8 [nb, BLOCK], scale f32 [nb]).
    Padded with zeros to a BLOCK multiple."""
    flat = np.asarray(x, np.float32).reshape(-1)
    n = flat.size
    pad = (-n) % BLOCK
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.float32)])
    xb = flat.reshape(-1, BLOCK)
    scale = np.maximum(np.abs(xb).max(axis=1), 1e-12) / 127.0
    q = np.clip(np.rint(xb / scale[:, None]), -127, 127).astype(np.int8)
    return q, scale.astype(np.float32)


def dequantize_ref(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    """-> f32 flat [nb * BLOCK] (caller slices to logical size)."""
    return (q.astype(np.float32) * scale[:, None].astype(np.float32)).reshape(-1)


def delta_encode_ref(x: np.ndarray, prev: np.ndarray) -> np.ndarray:
    """Byte-level XOR delta (runs of zeros compress well downstream)."""
    a = np.frombuffer(np.ascontiguousarray(x).tobytes(), np.uint8)
    b = np.frombuffer(np.ascontiguousarray(prev).tobytes(), np.uint8)
    assert a.size == b.size
    return np.bitwise_xor(a, b)


def delta_decode_ref(delta: np.ndarray, prev: np.ndarray, dtype, shape):
    b = np.frombuffer(np.ascontiguousarray(prev).tobytes(), np.uint8)
    raw = np.bitwise_xor(delta, b).tobytes()
    return np.frombuffer(raw, dtype=dtype).reshape(shape)


# jnp twin (device-side oracle for the Pallas kernel tests)
def quantize_jnp(x):
    import jax.numpy as jnp
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.size
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    xb = flat.reshape(-1, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_jnp(q, scale):
    import jax.numpy as jnp
    return (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
