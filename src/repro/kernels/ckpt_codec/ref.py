"""Pure reference for the checkpoint codec: int8 block quantization
(256-lane blocks, symmetric, per-block scale) + delta encoding +
per-chunk fingerprints for dirty-chunk detection.

numpy implementations (host checkpoint path) are the oracle the Pallas
kernel is validated against.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

BLOCK = 256

# --- dirty-chunk fingerprint geometry --------------------------------------
# A leaf is fingerprinted in fixed-size chunks; capture transfers only the
# chunks whose fingerprint changed since the previous snapshot. 256 KiB
# balances detection granularity against per-chunk metadata (16 B of
# fingerprint per chunk on device -> 1/16384 overhead).
FP_CHUNK_BYTES = 256 * 1024
# host fingerprint: one u64 lane per segment; 8 KiB segments keep the
# reduction SIMD-friendly while bounding the blind span (see below)
FP_SEG_BYTES = 8 * 1024

# kernel fingerprint mixing constants (odd multipliers: a single changed
# int32 lane always flips the hash — (x'-x)*odd is nonzero mod 2^32)
_FP_XOR_C = 0x5BD1E995
_FP_MUL_C = 0x9E3779B1


def quantize_ref(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """x: f32 any-shape -> (q int8 [nb, BLOCK], scale f32 [nb]).
    Padded with zeros to a BLOCK multiple."""
    flat = np.asarray(x, np.float32).reshape(-1)
    n = flat.size
    pad = (-n) % BLOCK
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.float32)])
    xb = flat.reshape(-1, BLOCK)
    scale = np.maximum(np.abs(xb).max(axis=1), 1e-12) / 127.0
    q = np.clip(np.rint(xb / scale[:, None]), -127, 127).astype(np.int8)
    return q, scale.astype(np.float32)


def dequantize_ref(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    """-> f32 flat [nb * BLOCK] (caller slices to logical size)."""
    return (q.astype(np.float32) * scale[:, None].astype(np.float32)).reshape(-1)


def delta_encode_ref(x: np.ndarray, prev: np.ndarray) -> np.ndarray:
    """Byte-level XOR delta (runs of zeros compress well downstream)."""
    a = np.frombuffer(np.ascontiguousarray(x).tobytes(), np.uint8)
    b = np.frombuffer(np.ascontiguousarray(prev).tobytes(), np.uint8)
    assert a.size == b.size
    return np.bitwise_xor(a, b)


def delta_decode_ref(delta: np.ndarray, prev: np.ndarray, dtype, shape):
    b = np.frombuffer(np.ascontiguousarray(prev).tobytes(), np.uint8)
    raw = np.bitwise_xor(delta, b).tobytes()
    return np.frombuffer(raw, dtype=dtype).reshape(shape)


# ---------------------------------------------------------------------------
# chunk fingerprints (dirty detection for sparse capture)
# ---------------------------------------------------------------------------

def _as_bytes(buf) -> np.ndarray:
    a = np.ascontiguousarray(buf)
    return a.reshape(-1).view(np.uint8)


def fingerprint_ref(buf, chunk_bytes: int = FP_CHUNK_BYTES) -> np.ndarray:
    """Oracle for the Pallas fingerprint kernel: two positional
    multiply-mix hashes per chunk over the int32 lanes, int32-wraparound
    arithmetic. Returns uint32 [n_chunks, 2].

    Computed in uint64 and truncated: 2^32 divides 2^64, so uint64
    wraparound then ``& 0xFFFFFFFF`` equals the kernel's int32
    wraparound exactly.
    """
    assert chunk_bytes % 4 == 0
    b = _as_bytes(buf)
    n = b.size
    pad = (-n) % chunk_bytes
    if pad:
        b = np.concatenate([b, np.zeros(pad, np.uint8)])
    ce = chunk_bytes // 4
    x = b.view(np.uint32).reshape(-1, ce).astype(np.uint64)
    pos = np.arange(ce, dtype=np.uint64)
    m1 = 2 * pos + 1
    m2 = 2 * pos + np.uint64(_FP_MUL_C)
    h1 = (x * m1).sum(axis=1) & 0xFFFFFFFF
    h2 = ((x ^ np.uint64(_FP_XOR_C)) * m2).sum(axis=1) & 0xFFFFFFFF
    return np.stack([h1, h2], axis=1).astype(np.uint32)


def fused_capture_ref(buf, prev_fp, chunk_bytes: int = FP_CHUNK_BYTES,
                      capacity: Optional[int] = None
                      ) -> Tuple[np.ndarray, int, np.ndarray, np.ndarray]:
    """Bit-identical host twin of the fused single-pass capture kernel
    (``kernel.fused_capture_blocks`` / ``ops.fused_dirty_chunk_capture``).

    Returns ``(fp u32 [n_chunks, 2], count, dirty_idx i64 [k],
    compact u8 [k, chunk_bytes])`` where ``count`` is the TOTAL dirty
    count (it may exceed ``capacity``, mirroring the kernel's overflow
    signal) and ``dirty_idx``/``compact`` hold the first
    ``min(count, capacity)`` dirty chunks in chunk order — exactly the
    rows the kernel's running-count compaction emits. The tail chunk is
    zero-padded to ``chunk_bytes``, matching the kernel's padded read.
    """
    fp = fingerprint_ref(buf, chunk_bytes)
    pf = np.ascontiguousarray(prev_fp).view(np.uint32).reshape(fp.shape)
    idx = np.nonzero(np.any(fp != pf, axis=1))[0]
    count = int(idx.size)
    kept = idx if capacity is None else idx[:capacity]
    b = _as_bytes(buf)
    pad = (-b.size) % chunk_bytes
    if pad:
        b = np.concatenate([b, np.zeros(pad, np.uint8)])
    compact = b.reshape(-1, chunk_bytes)[kept]
    return fp, count, kept.astype(np.int64), compact


def fingerprint_host(buf, chunk_bytes: int = FP_CHUNK_BYTES,
                     seg_bytes: int = FP_SEG_BYTES) -> np.ndarray:
    """Fast host fingerprint: per-segment uint64 wraparound sums,
    grouped per chunk. Returns uint64 [n_chunks, segs_per_chunk].

    ~1 SIMD read pass (vs ~3 memory ops for the multiply-mix oracle),
    which is what lets sparse capture beat a plain copy on the caller
    thread when no accelerator is attached. Detection model: any change
    to a segment's u64 word-sum is caught; blind to byte permutations
    *within* one 8 KiB segment and to exactly-compensating multi-word
    edits — neither occurs for real float/optimizer updates, and the
    device kernel path uses the positional hash instead.
    """
    seg_bytes = min(seg_bytes, chunk_bytes)
    assert chunk_bytes % seg_bytes == 0 and seg_bytes % 8 == 0
    b = _as_bytes(buf)
    n = b.size
    se = seg_bytes // 8
    n_full = (n // seg_bytes) * seg_bytes
    sums = b[:n_full].view(np.uint64).reshape(-1, se).sum(
        axis=1, dtype=np.uint64)
    if n_full < n:  # partial tail segment, zero-padded
        tail = np.zeros(seg_bytes, np.uint8)
        tail[:n - n_full] = b[n_full:]
        sums = np.concatenate(
            [sums, tail.view(np.uint64).sum(dtype=np.uint64)[None]])
    spc = chunk_bytes // seg_bytes
    pad = (-sums.size) % spc
    if pad:
        sums = np.concatenate([sums, np.zeros(pad, np.uint64)])
    return sums.reshape(-1, spc)


# jnp twin (device-side oracle for the Pallas kernel tests)
def quantize_jnp(x):
    import jax.numpy as jnp
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.size
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    xb = flat.reshape(-1, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_jnp(q, scale):
    import jax.numpy as jnp
    return (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
