"""Pallas TPU kernel: int8 block quantization for checkpoint compression.

Why a kernel: snapshotting a 2 TB model's optimizer moments through the
codec is HBM-bandwidth-bound; fusing abs-max + scale + round into one VMEM
pass reads each element once (vs 3 passes for the naive composition),
tripling effective snapshot codec throughput on TPU.

Tiling: rows of 256-lane blocks; each grid step processes a
(ROWS_PER_TILE, 256) tile resident in VMEM — 256 lanes matches the VPU
lane width, ROWS_PER_TILE=512 keeps the tile at 512KB f32 in + 128KB int8
out, well inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256
ROWS_PER_TILE = 512

# fingerprint mixing constants — shared with ref.fingerprint_ref
_FP_XOR_C = 0x5BD1E995
_FP_MUL_C = 0x9E3779B1 - (1 << 32)  # as signed int32


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...]                                  # [R, BLOCK] f32
    amax = jnp.max(jnp.abs(x), axis=1)              # [R]
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def quantize_blocks(xb: jax.Array, *, interpret: bool = False):
    """xb: f32 [nb, BLOCK] (padded by ops.py) -> (q int8 [nb, BLOCK],
    scale f32 [nb])."""
    nb = xb.shape[0]
    rows = min(ROWS_PER_TILE, nb)
    assert nb % rows == 0, (nb, rows)
    grid = (nb // rows,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, BLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(xb)


def _xor_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jax.lax.bitwise_xor(a_ref[...], b_ref[...])


def xor_blocks(a: jax.Array, b: jax.Array, *, interpret: bool = False):
    """Byte-level XOR delta for chained snapshots, vectorized as int32
    lanes: a, b are [nb, BLOCK] int32 views of the raw payload (ops.py
    does the byte reinterpretation + padding). One VMEM pass, pure
    VPU work — HBM-bandwidth-bound like the quantizer."""
    nb = a.shape[0]
    rows = min(ROWS_PER_TILE, nb)
    assert nb % rows == 0, (nb, rows)
    grid = (nb // rows,)
    return pl.pallas_call(
        _xor_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, BLOCK), jnp.int32),
        interpret=interpret,
    )(a, b)


def _fingerprint_kernel(x_ref, o_ref):
    # two positional multiply-mix hashes over one chunk's int32 lanes;
    # int32 arithmetic wraps, matching ref.fingerprint_ref exactly
    x = x_ref[...]                                   # [R, BLOCK] i32
    r, c = x.shape
    row = jax.lax.broadcasted_iota(jnp.int32, (r, c), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (r, c), 1)
    pos = row * c + col                              # index within chunk
    h1 = jnp.sum(x * (2 * pos + 1))
    h2 = jnp.sum((x ^ jnp.int32(_FP_XOR_C)) * (2 * pos + jnp.int32(_FP_MUL_C)))
    o_ref[0, 0] = h1
    o_ref[0, 1] = h2


def fingerprint_blocks(xb: jax.Array, rows_per_chunk: int, *,
                       interpret: bool = False):
    """xb: i32 [n_chunks * rows_per_chunk, BLOCK] (one chunk =
    ``rows_per_chunk`` rows, padded by ops.py) -> i32 [n_chunks, 2].

    One VMEM pass per chunk: the whole leaf is read once at HBM
    bandwidth and only 8 B of fingerprint per chunk ever leaves the
    device — dirty detection without a device->host copy of the data."""
    nb = xb.shape[0]
    assert nb % rows_per_chunk == 0, (nb, rows_per_chunk)
    grid = (nb // rows_per_chunk,)
    return pl.pallas_call(
        _fingerprint_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows_per_chunk, BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb // rows_per_chunk, 2), jnp.int32),
        interpret=interpret,
    )(xb)


def _fused_capture_kernel(x_ref, pfp_ref, fp_ref, cnt_ref, idx_ref, out_ref):
    """One chunk per grid step: hash, compare against the previous
    snapshot's fingerprint, and — when dirty — append the chunk to the
    compaction buffer at the running dirty count. TPU grids execute
    sequentially, so ``cnt_ref`` (a 1x1 accumulator revisited by every
    step) is a prefix sum over the dirty mask: each chunk lands at its
    final compacted position in the same pass that detected it."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        cnt_ref[0, 0] = 0

    x = x_ref[...]                                   # [R, BLOCK] i32
    r, c = x.shape
    row = jax.lax.broadcasted_iota(jnp.int32, (r, c), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (r, c), 1)
    pos = row * c + col
    h1 = jnp.sum(x * (2 * pos + 1))
    h2 = jnp.sum((x ^ jnp.int32(_FP_XOR_C)) * (2 * pos + jnp.int32(_FP_MUL_C)))
    fp_ref[0, 0] = h1
    fp_ref[0, 1] = h2
    dirty = jnp.logical_or(h1 != pfp_ref[0, 0], h2 != pfp_ref[0, 1])
    k = cnt_ref[0, 0]
    capacity = idx_ref.shape[0]

    @pl.when(jnp.logical_and(dirty, k < capacity))
    def _():
        idx_ref[k, 0] = i
        out_ref[pl.ds(k * r, r), :] = x

    @pl.when(dirty)
    def _():
        # counted past capacity on purpose: the host reads the final
        # count to detect overflow (fall back to the two-launch path)
        cnt_ref[0, 0] = k + 1


def fused_capture_blocks(xb: jax.Array, prev_fp: jax.Array,
                         rows_per_chunk: int, capacity: int, *,
                         interpret: bool = False):
    """Single-pass capture: xb i32 [n_chunks * rows_per_chunk, BLOCK],
    prev_fp i32 [n_chunks, 2] (device-resident) ->

      (fp i32 [n_chunks, 2],          this snapshot's fingerprints
       count i32 [1, 1],              total dirty chunks (may exceed
                                      ``capacity`` — overflow signal)
       idx i32 [capacity, 1],         chunk index per compacted slot
       compact i32 [capacity * rows_per_chunk, BLOCK])

    The leaf is read from HBM exactly once; fingerprint compare and
    dirty compaction happen in the same VMEM pass (vs the two-launch
    path: one fingerprint read + a host round-trip + a gather re-read).
    ``capacity * chunk_bytes`` stays VMEM-resident for the whole grid,
    so ops.py bounds it (~8 MB); only ``count`` rows are meaningful.
    """
    nb = xb.shape[0]
    assert nb % rows_per_chunk == 0, (nb, rows_per_chunk)
    n_chunks = nb // rows_per_chunk
    assert prev_fp.shape == (n_chunks, 2), (prev_fp.shape, n_chunks)
    assert capacity >= 1
    grid = (n_chunks,)
    return pl.pallas_call(
        _fused_capture_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_chunk, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((capacity, 1), lambda i: (0, 0)),
            pl.BlockSpec((capacity * rows_per_chunk, BLOCK),
                         lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_chunks, 2), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((capacity, 1), jnp.int32),
            jax.ShapeDtypeStruct((capacity * rows_per_chunk, BLOCK),
                                 jnp.int32),
        ],
        interpret=interpret,
    )(xb, prev_fp)


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = q * s_ref[...][:, None]


def dequantize_blocks(q: jax.Array, scale: jax.Array, *,
                      interpret: bool = False):
    nb = q.shape[0]
    rows = min(ROWS_PER_TILE, nb)
    assert nb % rows == 0
    grid = (nb // rows,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, BLOCK), jnp.float32),
        interpret=interpret,
    )(q, scale)
