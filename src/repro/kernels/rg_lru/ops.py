"""jit'd wrapper for the RG-LRU kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rg_lru.kernel import rg_lru_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block_w", "interpret"))
def rg_lru_scan(x, r, i, lam, *, chunk: int = 256, block_w: int = 512,
                interpret: bool = None):
    if interpret is None:
        interpret = not _on_tpu()
    B, S, W = x.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0)))
        i = jnp.pad(i, ((0, 0), (0, pad), (0, 0)))
    bw = min(block_w, W)
    wpad = (-W) % bw
    if wpad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, wpad)))
        r = jnp.pad(r, ((0, 0), (0, 0), (0, wpad)))
        i = jnp.pad(i, ((0, 0), (0, 0), (0, wpad)))
        lam = jnp.pad(lam, (0, wpad))
    y = rg_lru_fwd(x, r, i, lam, chunk=c, block_w=bw, interpret=interpret)
    return y[:, :S, :W]
