"""Pallas TPU kernel: RG-LRU gated linear recurrence (forward).

The recurrence is elementwise over channels (embarrassingly parallel on
the VPU lanes) and sequential over time. Grid = (batch, channel_blocks,
time_chunks); time is the sequential axis carrying the hidden state
[block_w] in VMEM scratch; within a chunk a fori_loop steps the
recurrence on [block_w]-wide vectors. Channel blocks of 512 lanes keep
x/r/i chunk tiles (3 x Q x 512 x 4B = 1.5 MB at Q=256) VMEM-resident.

This layout means a width-sharded RG-LRU layer (width over the `model`
axis) runs the kernel per shard with zero cross-chip traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_C = 8.0


def _rglru_kernel(x_ref, r_ref, i_ref, lam_ref, y_ref, h_sc, *, q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_sc[...] = jnp.zeros_like(h_sc)

    x = x_ref[0].astype(jnp.float32)     # [Q, W]
    r = r_ref[0].astype(jnp.float32)
    gi = i_ref[0].astype(jnp.float32)
    lam = lam_ref[...].astype(jnp.float32)  # [W]

    log_a = -_C * jax.nn.softplus(lam)[None, :] * r      # [Q, W]
    a = jnp.exp(log_a)
    gate = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    b = gate * (gi * x)

    def step(t, carry):
        h, ybuf = carry
        h = a[t] * h + b[t]
        ybuf = jax.lax.dynamic_update_index_in_dim(ybuf, h, t, 0)
        return h, ybuf

    h0 = h_sc[...]
    y0 = jnp.zeros((q, x.shape[1]), jnp.float32)
    h_last, y = jax.lax.fori_loop(0, q, step, (h0, y0))
    y_ref[0] = y.astype(y_ref.dtype)
    h_sc[...] = h_last


def rg_lru_fwd(x, r, i, lam, *, chunk: int = 256, block_w: int = 512,
               interpret: bool = False):
    """x, r, i: [B, S, W]; lam: [W] -> h sequence [B, S, W]."""
    B, S, W = x.shape
    assert S % chunk == 0
    bw = min(block_w, W)
    assert W % bw == 0
    nc = S // chunk
    nw = W // bw

    grid = (B, nw, nc)
    kern = functools.partial(_rglru_kernel, q=chunk)
    kwargs = {}
    if not interpret:
        cp = getattr(pltpu, "CompilerParams", None) or \
            getattr(pltpu, "TPUCompilerParams")
        kwargs["compiler_params"] = cp(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bw), lambda b, w, c: (b, c, w)),
            pl.BlockSpec((1, chunk, bw), lambda b, w, c: (b, c, w)),
            pl.BlockSpec((1, chunk, bw), lambda b, w, c: (b, c, w)),
            pl.BlockSpec((bw,), lambda b, w, c: (w,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, bw), lambda b, w, c: (b, c, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), x.dtype),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(x, r, i, lam)
