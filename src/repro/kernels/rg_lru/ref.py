"""Oracle for the RG-LRU kernel: the model's associative-scan version."""
from __future__ import annotations

from repro.models.hybrid import rg_lru


def rg_lru_ref(x, r, i, lam):
    h, _ = rg_lru(x, r, i, lam)
    return h
