"""Pallas TPU kernel: Mamba-2 chunked SSD scan (forward).

Grid = (batch, heads, chunks); the chunk axis is sequential ("arbitrary")
and carries the running inter-chunk state [head_dim, d_state] in VMEM
scratch — the HBM traffic is exactly one read of (x, dt, B, C) and one
write of y per token, with the O(Q^2) intra-chunk work done on the MXU
from VMEM. Chunk length 128-256 balances the quadratic intra term
against state-passing overhead (same blocking as models/ssm.ssd_chunked,
which is the oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_sc, *,
                q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_sc[...] = jnp.zeros_like(state_sc)

    x = x_ref[0, 0].astype(jnp.float32)          # [Q, hd]
    dt = dt_ref[0, 0].astype(jnp.float32)        # [Q]
    A = a_ref[0].astype(jnp.float32)             # scalar for this head
    Bm = b_ref[0].astype(jnp.float32)            # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)            # [Q, N]

    dA = dt * A                                   # [Q], negative
    cum = jnp.cumsum(dA)                          # [Q]
    xdt = x * dt[:, None]

    # intra-chunk: scores (C_i . B_j) * exp(cum_i - cum_j), causal
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    seg = cum[:, None] - cum[None, :]
    L = jnp.where(iota_i >= iota_j, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_intra = jax.lax.dot_general(scores * L, xdt,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: y_i += exp(cum_i) * C_i . state^T   (state: [hd, N])
    state = state_sc[...]
    y_inter = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y = y_intra + y_inter * jnp.exp(cum)[:, None]
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: state' = state * exp(cum[-1]) + sum_t e^{cum[-1]-cum_t}
    #                         xdt_t (x) B_t
    decay_end = jnp.exp(cum[q - 1] - cum)         # [Q]
    upd = jax.lax.dot_general(xdt * decay_end[:, None], Bm,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    state_sc[...] = state * jnp.exp(cum[q - 1]) + upd


def ssd_scan_fwd(x, dt, A, Bm, Cm, *, chunk: int = 128,
                 interpret: bool = False):
    """x: [B,S,H,hd]; dt: [B,S,H]; A: [H]; Bm/Cm: [B,S,N] -> y [B,S,H,hd].

    S must be a multiple of `chunk` (ops.py pads)."""
    Bsz, S, H, hd = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    nc = S // chunk

    # layout: [B, H, nc, Q, ...] blocks
    xt = x.transpose(0, 2, 1, 3)                  # [B,H,S,hd]
    dtt = dt.transpose(0, 2, 1)                   # [B,H,S]

    grid = (Bsz, H, nc)
    kern = functools.partial(_ssd_kernel, q=chunk)
    kwargs = {}
    if not interpret:
        cp = getattr(pltpu, "CompilerParams", None) or \
            getattr(pltpu, "TPUCompilerParams")
        kwargs["compiler_params"] = cp(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    y = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, hd),
                               lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, H, S, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(xt, dtt, A, Bm, Cm)
    return y.transpose(0, 2, 1, 3)
