"""jit'd wrapper for the SSD scan kernel (padding + device dispatch)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = None):
    if interpret is None:
        interpret = not _on_tpu()
    S = x.shape[1]
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y = ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=c, interpret=interpret)
    return y[:, :S]
