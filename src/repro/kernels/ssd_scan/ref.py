"""Oracle for the SSD kernel: the pure-jnp chunked implementation used by
the model itself (single source of truth), plus a brute-force sequential
recurrence for cross-validation."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked


def ssd_ref(x, dt, A, Bm, Cm, chunk: int = 128):
    y, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    return y


def ssd_sequential(x, dt, A, Bm, Cm):
    """O(S) literal recurrence: h_t = h_{t-1} e^{dt A} + dt x_t B_t^T;
    y_t = h_t C_t."""
    Bsz, S, H, hd = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp               # [B,H,hd],[B,H],[B,N],[B,N]
        dec = jnp.exp(dtt * A)              # [B,H]
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt)
        h = h * dec[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bsz, H, hd, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3)
