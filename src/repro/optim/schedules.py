"""LR schedules as pure functions of the step counter.

Schedule *state* is just (name, hyperparams, step) — upper-half data.
Runtime overrides (ScheduleSet ops) multiply on top and replay with the
op-log, so a mid-run LR touch-up survives restart.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ScheduleConfig:
    kind: str = "warmup_cosine"     # warmup_cosine | warmup_linear | constant
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_ratio: float = 0.1


def schedule_lr(cfg: ScheduleConfig, step, overrides: Dict[str, float] = None):
    s = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.kind == "constant":
        base = jnp.float32(1.0)
    elif cfg.kind == "warmup_linear":
        frac = jnp.clip((s - cfg.warmup_steps) /
                        max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        base = 1.0 - (1.0 - cfg.min_ratio) * frac
    else:  # warmup_cosine
        frac = jnp.clip((s - cfg.warmup_steps) /
                        max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(np.pi * frac))
        base = cfg.min_ratio + (1.0 - cfg.min_ratio) * cos
    lr = cfg.peak_lr * warm * base
    if overrides and "lr_scale" in overrides:
        lr = lr * overrides["lr_scale"]
    return lr
