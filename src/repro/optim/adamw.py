"""AdamW with optional int8 block-quantized moments.

Quantized moments (the 8-bit-Adam trick) are a distributed-optimization
lever twice over: they shrink per-chip optimizer HBM ~4x (what lets the
1T-param MoE fit on 256-512 chips) and shrink checkpoint payloads by the
same factor (state is stored quantized, so snapshots move less data — the
same goal as the paper's log pruning). Dequant-update-requant happens per
step in f32; per-block scales (256 lanes along the last axis) bound the
quantization error.

Quantized moments keep the *parameter's shape* (int8 array + a scale
array whose last dim is the block count), so they shard with exactly the
parameter's logical axes — no special-case resharding on elastic restore.

Pure-functional; no optax dependency.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_moments: bool = False


class QMoment(NamedTuple):
    q: jax.Array       # int8, same shape as the param
    scale: jax.Array   # f32, param.shape[:-1] + (ceil(last/BLOCK),)


def _nblocks(last: int) -> int:
    return (last + BLOCK - 1) // BLOCK


def _q_encode(x: jax.Array) -> QMoment:
    """x: f32 param-shaped."""
    shape = x.shape
    last = shape[-1] if shape else 1
    nb = _nblocks(last)
    pad = nb * BLOCK - last
    xp = jnp.pad(x.reshape(shape[:-1] + (last,)),
                 [(0, 0)] * (len(shape) - 1) + [(0, pad)])
    xb = xp.reshape(shape[:-1] + (nb, BLOCK))
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    q = q.reshape(shape[:-1] + (nb * BLOCK,))[..., :last]
    return QMoment(q, scale)


def _q_decode(m: QMoment) -> jax.Array:
    q, scale = m
    shape = q.shape
    last = shape[-1]
    nb = scale.shape[-1]
    pad = nb * BLOCK - last
    qp = jnp.pad(q, [(0, 0)] * (len(shape) - 1) + [(0, pad)])
    xb = qp.reshape(shape[:-1] + (nb, BLOCK)).astype(jnp.float32)
    return (xb * scale[..., None]).reshape(
        shape[:-1] + (nb * BLOCK,))[..., :last]


def _zeros_moment(p, quantize: bool):
    if not quantize or p.ndim == 0:
        return jnp.zeros(p.shape, jnp.float32)
    nb = _nblocks(p.shape[-1])
    return QMoment(jnp.zeros(p.shape, jnp.int8),
                   jnp.zeros(p.shape[:-1] + (nb,), jnp.float32))


def _read_moment(m) -> jax.Array:
    return _q_decode(m) if isinstance(m, QMoment) else m


def _write_moment(val: jax.Array, like) :
    return _q_encode(val) if isinstance(like, QMoment) else val


# --- public API ---------------------------------------------------------------

def init_opt_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    return {
        "mu": jax.tree.map(lambda p: _zeros_moment(p, cfg.quantize_moments), params),
        "nu": jax.tree.map(lambda p: _zeros_moment(p, cfg.quantize_moments), params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params, cfg: AdamWConfig):
    def mom(p):
        if not cfg.quantize_moments or len(p.shape) == 0:
            return jax.ShapeDtypeStruct(p.shape, jnp.float32)
        nb = _nblocks(p.shape[-1])
        return QMoment(jax.ShapeDtypeStruct(p.shape, jnp.int8),
                       jax.ShapeDtypeStruct(p.shape[:-1] + (nb,), jnp.float32))
    return {
        "mu": jax.tree.map(mom, abstract_params),
        "nu": jax.tree.map(mom, abstract_params),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_logical_specs(param_logical, cfg: AdamWConfig):
    """Moments inherit the param's logical axes (quantized: q = same
    axes; scale = same axes with the last replaced by None — block
    counts rarely divide the mesh, and scales are tiny)."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)

    def mom_axes(axes):
        if not cfg.quantize_moments or len(axes) == 0:
            return axes
        return QMoment(tuple(axes), tuple(axes[:-1]) + (None,))

    return {
        "mu": jax.tree.map(mom_axes, param_logical, is_leaf=is_axes),
        "nu": jax.tree.map(mom_axes, param_logical, is_leaf=is_axes),
        "count": (),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, opt_state, cfg: AdamWConfig, lr: jax.Array):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    cf = count.astype(jnp.float32)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)

    bc1 = 1.0 - cfg.b1 ** cf
    bc2 = 1.0 - cfg.b2 ** cf

    def upd(p, g, mu, nu):
        gf = g.astype(jnp.float32) * clip
        m = _read_moment(mu)
        v = _read_moment(nu)
        m = cfg.b1 * m + (1.0 - cfg.b1) * gf
        v = cfg.b2 * v + (1.0 - cfg.b2) * gf * gf
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step + decay * p.astype(jnp.float32))
        return (new_p.astype(p.dtype), _write_moment(m, mu),
                _write_moment(v, nu))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
