from repro.optim.adamw import (
    AdamWConfig, QMoment, init_opt_state, abstract_opt_state,
    opt_logical_specs, apply_updates, global_norm,
)
from repro.optim.schedules import ScheduleConfig, schedule_lr
