"""Gradient compression for the data-parallel all-reduce, with error
feedback (EF-SGD style).

With pjit, the gradient reduction is implicit; this module provides the
explicit shard_map variant: per-DP-shard gradients are int8-quantized
(per-block scales), psum'd in int8-widened form, dequantized, and the
quantization residual is carried in the optimizer state and added back
next step — preserving convergence while cutting DP all-reduce bytes 2x
(bf16->int8). Enable via TrainConfig.grad_compression = "int8_ef".
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _blockwise_quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: f32 flat [N] -> (int8 [nb, BLOCK], scales [nb])."""
    n = x.size
    pad = (-n) % BLOCK
    xb = jnp.pad(x, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def _blockwise_dequant(q: jax.Array, scale: jax.Array, n: int) -> jax.Array:
    return (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, ef_state, axis_name: str):
    """Inside shard_map: quantize (grad + carried error), all-reduce the
    int8 payload (widened to int32 for the sum — on the wire this is the
    int8 tensor), dequantize the mean, and compute the new error carry.

    Returns (reduced_grads, new_ef_state)."""
    n_shards = jax.lax.psum(1, axis_name)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _blockwise_quant(gf.reshape(-1))
        sent = _blockwise_dequant(q, scale, gf.size).reshape(gf.shape)
        new_e = gf - sent                      # local quantization residual
        total = jax.lax.psum(sent, axis_name)  # wire bytes ~ int8 + scales
        return total / n_shards, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))
