"""Whisper-base — encoder-decoder audio model; conv frontend stubbed.

[audio] 6L d_model=512 8H (GQA kv=8) d_ff=2048 vocab=51865
[arXiv:2212.04356; unverified]

Backbone only per the assignment: the log-mel + conv frontend is a stub;
``input_specs()`` supplies precomputed frame embeddings (1500 frames x
d_model) to the encoder. 6 encoder + 6 decoder layers, MHA (kv=8 == 8H),
LayerNorm + GeLU, learned positions approximated with RoPE-free absolute
embeddings folded into the stub.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "whisper-base"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="audio",
    n_layers=6,               # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    is_encoder_decoder=True,
    n_encoder_layers=6,
    encoder_seq=1500,
    frontend_dim=512,
    act="gelu",
    norm="layernorm",
    tie_embeddings=True,
    source="arXiv:2212.04356",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        n_encoder_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        encoder_seq=24,
        frontend_dim=64,
    )


def matrix_config() -> ModelConfig:
    """Conformance-matrix tiny: one encoder + one decoder layer keeps
    the cross-attention cache (the enc-dec-specific C/R payload) in
    every matrix cell."""
    return CONFIG.replace(
        name=ARCH_ID + "-matrix",
        n_layers=1,
        n_encoder_layers=1,
        d_model=32,
        n_heads=2,
        n_kv_heads=2,
        head_dim=16,
        d_ff=64,
        vocab_size=64,
        encoder_seq=8,
        frontend_dim=32,
    )
