"""Kimi K2 — trillion-parameter MoE, 32B active.

[moe] 61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840,
MoE 384e top-8  [arXiv:2501.kimi2; unverified]

Per the assignment table the attention is GQA (kv=8). d_ff=2048 is the
per-expert hidden dim; one DeepSeek-V3-style shared expert per layer.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "kimi-k2-1t-a32b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,           # per-expert hidden (moe_d_ff defaults to d_ff)
    vocab_size=163840,
    head_dim=112,        # 7168 / 64
    n_experts=384,
    experts_per_token=8,
    n_shared_experts=1,
    act="silu",
    norm="rmsnorm",
    rope_theta=50_000.0,
    source="arXiv:2501.kimi2",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=32,
        vocab_size=256,
        n_experts=8,
        experts_per_token=2,
        n_shared_experts=1,
    )


def matrix_config() -> ModelConfig:
    """Conformance-matrix tiny: keeps top-k>1 routing (the second MoE
    row of the matrix — llama4 covers top-1), floor everything else."""
    return CONFIG.replace(
        name=ARCH_ID + "-matrix",
        n_layers=1,
        d_model=32,
        n_heads=2,
        n_kv_heads=1,
        head_dim=16,
        d_ff=16,
        vocab_size=64,
        n_experts=4,
        experts_per_token=2,
        n_shared_experts=1,
    )
