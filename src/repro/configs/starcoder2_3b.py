"""StarCoder2-3B — dense code LM, GQA + RoPE.

[dense] 30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152
[arXiv:2402.19173; hf]

StarCoder2 uses LayerNorm (with bias) and a plain GeLU MLP (d_ff = 4*d),
plus QKV bias — faithful to the HF config.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "starcoder2-3b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    head_dim=128,
    qkv_bias=True,
    act="gelu",
    norm="layernorm",
    rope_theta=999_999.44,
    tie_embeddings=True,
    source="arXiv:2402.19173",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=256,
    )


def matrix_config() -> ModelConfig:
    """Conformance-matrix tiny: the smallest same-family config that
    still exercises every C/R-relevant code path (GQA + biases here),
    sized so a full torture cell compiles and runs in seconds on CPU."""
    return CONFIG.replace(
        name=ARCH_ID + "-matrix",
        n_layers=1,
        d_model=32,
        n_heads=2,
        n_kv_heads=1,
        head_dim=16,
        d_ff=64,
        vocab_size=64,
    )
