"""RecurrentGemma-9B (Griffin) — RG-LRU + local attention, 2:1 pattern.

[hybrid] 38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000
[arXiv:2402.19427; unverified]

Block pattern (recurrent, recurrent, local-attention) repeated; 38 layers
= 12 full groups + 2 trailing recurrent blocks. Local attention window
2048, MQA (kv=1). GeGLU MLP per the Griffin paper.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "recurrentgemma-9b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    attn_window=2048,
    block_pattern=("rglru", "rglru", "attn"),
    rglru_width=4096,
    act="gelu_glu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    attn_logit_softcap=0.0,
    tie_embeddings=True,
    source="arXiv:2402.19427",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name=ARCH_ID + "-smoke",
        n_layers=5,  # exercises remainder handling (5 = 1 group + 2)
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_window=32,
        rglru_width=64,
    )


def matrix_config() -> ModelConfig:
    """Conformance-matrix tiny: one full (rglru, rglru, attn) group so
    both block kinds (RG-LRU recurrence + windowed attention) sit in
    every checkpoint cell."""
    return CONFIG.replace(
        name=ARCH_ID + "-matrix",
        n_layers=3,
        d_model=32,
        n_heads=2,
        n_kv_heads=1,
        head_dim=16,
        d_ff=64,
        vocab_size=64,
        attn_window=8,
        rglru_width=32,
    )
