"""Chameleon-34B — early-fusion VLM decoder, VQ image tokens in the vocab.

[vlm] 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536
[arXiv:2405.09818; unverified]

Early fusion means image patches are VQ-quantized into discrete codes that
live in the same 65536-entry vocabulary as text tokens, so the backbone is
an ordinary dense decoder; the VQ tokenizer frontend is a stub per the
assignment (``input_specs()`` supplies token ids). Chameleon uses qk-norm
for training stability at scale.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "chameleon-34b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    head_dim=128,
    qk_norm=True,
    act="silu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    source="arXiv:2405.09818",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
