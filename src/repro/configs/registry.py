"""Architecture registry: ``--arch <id>`` resolution for all launchers."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig, shapes_for, SHAPES_BY_NAME

# arch-id -> module path (one module per assigned architecture)
_MODULES = {
    "chameleon-34b": "repro.configs.chameleon_34b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "qwen1.5-110b": "repro.configs.qwen1_5_110b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "whisper-base": "repro.configs.whisper_base",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).smoke_config()


def get_matrix_config(arch_id: str) -> ModelConfig:
    """Conformance-matrix tiny variant: smaller than smoke, sized so a
    full C/R torture cell (train + restore, or serve + re-slot) runs in
    seconds on CPU. Falls back to the smoke config for arch modules
    that haven't defined one."""
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch_id])
    fn = getattr(mod, "matrix_config", None)
    return fn() if fn is not None else mod.smoke_config()


def resolve_config(arch: str) -> ModelConfig:
    """One resolver for every ``arch`` string a job can carry: a bare
    registry id gives the published config; an id with a ``-smoke`` or
    ``-matrix`` suffix gives that reduced variant. Checkpoint metadata
    stores these strings, so both the trainer and the serving engine
    must resolve them identically — this is the single place."""
    if arch in _MODULES:
        return get_config(arch)
    if arch.endswith("-smoke"):
        return get_smoke_config(arch.removesuffix("-smoke"))
    if arch.endswith("-matrix"):
        return get_matrix_config(arch.removesuffix("-matrix"))
    raise KeyError(
        f"unknown arch {arch!r}; known: {sorted(_MODULES)} "
        "(optionally with a -smoke or -matrix suffix)")


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def get_shape(shape_name: str) -> ShapeConfig:
    if shape_name in SHAPES_BY_NAME:
        return SHAPES_BY_NAME[shape_name]
    # dynamic keys for tests / custom runs: "<kind>_s<seq>_b<batch>"
    parts = shape_name.split("_")
    if len(parts) == 3 and parts[1].startswith("s") and parts[2].startswith("b"):
        return ShapeConfig(shape_name, int(parts[1][1:]), int(parts[2][1:]),
                           parts[0])
    raise KeyError(f"unknown shape {shape_name!r}")


def all_cells():
    """Every applicable (arch, shape) dry-run cell."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            yield arch, shape.name


def skipped_cells():
    """Cells excluded per DESIGN.md §7 (long_500k on full-attention archs)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        if not cfg.sub_quadratic:
            yield arch, "long_500k"
