"""Phi-4-mini 3.8B — dense LM, RoPE + SwiGLU + GQA.

[dense] 32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064
[arXiv:2412.08905; hf]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "phi4-mini-3.8b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    head_dim=128,
    act="silu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="arXiv:2412.08905",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
