"""Configuration dataclasses for the model zoo and input shapes.

Every assigned architecture gets one module in this package defining a
``CONFIG: ModelConfig`` at the exact published dimensions plus a
``smoke_config()`` returning a reduced same-family config for CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters.

    A single config class covers all five families (dense / moe / ssm /
    hybrid / enc-dec); family-specific fields default to "off".
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- attention flavor ---
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_window: int = 0          # 0 = full attention; >0 = local window
    attn_logit_softcap: float = 0.0

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0             # per-expert hidden dim (0 -> d_ff)
    router_aux_loss: float = 0.01
    capacity_factor: float = 1.25

    # --- SSM (mamba2) ---
    ssm_state: int = 0            # d_state; >0 enables SSD blocks
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4

    # --- hybrid (recurrentgemma) ---
    # period pattern of block kinds, e.g. ("rglru", "rglru", "attn")
    block_pattern: tuple = ()
    rglru_width: int = 0          # recurrent width (0 -> d_model)

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0          # fixed encoder frames (stub frontend)
    frontend_dim: int = 0         # dim of precomputed frame/patch embeddings

    # --- norm / activation / embedding ---
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    norm_eps: float = 1e-6
    act: str = "silu"             # silu (SwiGLU) | gelu (plain MLP)
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # --- provenance ---
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can serve 500k-token contexts without a full
        O(seq) dense KV cache per layer (SSM state / windowed attention)."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid" and self.attn_window > 0:
            return True
        return False

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def n_params(self) -> int:
        """Analytic total parameter count (embeddings included)."""
        h = self.resolved_head_dim
        d = self.d_model
        attn = d * (self.n_heads * h) * 2 + d * (self.n_kv_heads * h) * 2 \
            if self.n_heads else 0
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * h
        gated = self.act == "silu"
        per_ff = lambda dff: d * dff * (3 if gated else 2)
        total = 0
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            # in_proj -> (z,x,B,C,dt), conv(x,B,C), out_proj
            conv_dim = d_in + 2 * self.ssm_state
            per_layer = (
                d * (2 * d_in + 2 * self.ssm_state + nh)
                + conv_dim * self.ssm_conv_width
                + d_in * d
                + 2 * nh  # A_log, D
                + 2 * d   # norms
            )
            total += per_layer * self.n_layers
        elif self.family == "hybrid":
            per = len(self.block_pattern)
            w = self.rglru_width or d
            # in-proj x2 + out-proj + conv/gates/lambda (per-channel)
            rglru_layer = 3 * d * w + 9 * w + per_ff(self.d_ff)
            attn_layer = attn + per_ff(self.d_ff)
            n_r = sum(1 for b in self.block_pattern if b == "rglru")
            groups, rem = divmod(self.n_layers, per)
            n_rg = groups * n_r + sum(
                1 for b in self.block_pattern[:rem] if b == "rglru")
            n_at = self.n_layers - n_rg
            total += n_rg * rglru_layer + n_at * attn_layer
        else:
            per_layer = attn
            if self.n_experts:
                per_layer += self.n_experts * per_ff(self.resolved_moe_d_ff)
                per_layer += self.n_shared_experts * per_ff(self.resolved_moe_d_ff)
                per_layer += d * self.n_experts  # router
            else:
                per_layer += per_ff(self.d_ff)
            per_layer += 2 * d  # norms
            total += per_layer * self.n_layers
            if self.is_encoder_decoder:
                # encoder self-attn + ff, decoder adds cross-attn
                enc_layer = attn + per_ff(self.d_ff) + 2 * d
                total += enc_layer * self.n_encoder_layers
                total += attn * self.n_layers  # cross-attention
        emb = self.vocab_size * d
        total += emb if self.tie_embeddings else 2 * emb
        total += d  # final norm
        return int(total)

    def n_active_params(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if not self.n_experts:
            return self.n_params()
        gated = self.act == "silu"
        per_ff = self.d_model * self.resolved_moe_d_ff * (3 if gated else 2)
        dead = (self.n_experts - self.experts_per_token) * per_ff * self.n_layers
        return int(self.n_params() - dead)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: what gets lowered for the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


# The assigned LM-family shape set (identical across archs).
TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shapes_for(config: ModelConfig) -> tuple:
    """Applicable shape cells for an arch (long_500k needs sub-quadratic
    sequence handling; skip documented in DESIGN.md §7)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if config.sub_quadratic:
        out.append(LONG_500K)
    return tuple(out)
