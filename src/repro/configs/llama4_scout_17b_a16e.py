"""Llama-4 Scout — 17B-active, 16-expert MoE with early fusion.

[moe] 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048,
MoE 16e top-1  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Top-1 routed expert + one always-on shared expert per Llama-4's design.
The vision frontend is a stub per the assignment (early-fusion patch
embeddings are precomputed in ``input_specs``).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "llama4-scout-17b-a16e"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    n_experts=16,
    experts_per_token=1,
    n_shared_experts=1,
    act="silu",
    norm="rmsnorm",
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=64,
        vocab_size=256,
        n_experts=4,
        experts_per_token=1,
        n_shared_experts=1,
    )


def matrix_config() -> ModelConfig:
    """Conformance-matrix tiny: top-1 routing + shared expert kept (the
    MoE C/R surface), everything else at the floor."""
    return CONFIG.replace(
        name=ARCH_ID + "-matrix",
        n_layers=1,
        d_model=32,
        n_heads=2,
        n_kv_heads=1,
        head_dim=16,
        d_ff=32,
        vocab_size=64,
        n_experts=2,
        experts_per_token=1,
        n_shared_experts=1,
    )
