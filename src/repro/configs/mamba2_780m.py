"""Mamba-2 780M — attention-free SSM with SSD (state-space duality).

[ssm] 48L d_model=1536 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified]

Each block: in_proj -> (z, x, B, C, dt); short causal conv on (x, B, C);
chunked SSD scan with scalar-per-head decay; gated RMSNorm; out_proj.
d_inner = 2 * d_model, head_dim = 64 -> 48 heads.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "mamba2-780m"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    norm="rmsnorm",
    tie_embeddings=True,
    source="arXiv:2405.21060",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        ssm_state=16,
        ssm_head_dim=16,
        vocab_size=256,
    )


def matrix_config() -> ModelConfig:
    """Conformance-matrix tiny: the SSD scan + conv state path at the
    floor (d_inner=64, 8 heads of 8)."""
    return CONFIG.replace(
        name=ARCH_ID + "-matrix",
        n_layers=1,
        d_model=32,
        ssm_state=8,
        ssm_head_dim=8,
        vocab_size=64,
    )
