from repro.configs.base import (
    ModelConfig,
    ShapeConfig,
    ALL_SHAPES,
    SHAPES_BY_NAME,
    shapes_for,
)
from repro.configs.registry import (
    ARCH_IDS,
    all_cells,
    all_configs,
    get_config,
    get_matrix_config,
    get_shape,
    get_smoke_config,
    resolve_config,
    skipped_cells,
)
