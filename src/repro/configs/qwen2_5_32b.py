"""Qwen2.5-32B — dense LM, GQA with QKV bias.

[dense] 64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064
[hf:Qwen/Qwen2.5-0.5B; hf]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen2.5-32b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    act="silu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-32B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
