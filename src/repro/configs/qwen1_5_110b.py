"""Qwen1.5-110B — dense LM, GQA with QKV bias.

[dense] 80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064
[hf:Qwen/Qwen1.5-0.5B; hf]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen1.5-110b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    act="silu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-110B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=256,
    )
