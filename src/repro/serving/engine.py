"""Serving: sharded prefill/decode steps + a continuous-batching engine.

The step builders are registered in the C/R function registry, so a
serving process restores exactly like a trainer — through one
``core.incarnation.Incarnation``: fresh lower half, replay recompiles
the decode executable and re-creates the (zeroed) cache, then the
*complete* session state rebinds: cache contents, request queue,
per-slot in-flight requests (prompt, generated tokens, budget), slot
positions and pending tokens. This is the paper's §IV demo — the artist
reopens Maya and the scene is still there — for inference sessions.

Restore is *elastic* in the serving dimension: a checkpoint taken on an
N-slot engine lands on an M-slot engine (re-slotting). Each live
session's KV slice is rebuilt by replaying its full token history
(prompt + tokens generated so far) through the prefill path into its
new slot — the serving analogue of restoring a trainer onto a different
mesh.
"""
from __future__ import annotations

import dataclasses
import json
import re
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.api import register_app_kind
from repro.api.app import RestoreContext
from repro.api.errors import RestoreError
from repro.api.session import CheckpointSession
from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs import registry as cfg_registry
from repro.models import model as M
from repro.parallel.sharding import ParallelPlan, tree_specs
from repro.parallel.planner import make_plan
from repro.parallel import context as pctx
from repro.serving.kv_cache import cache_shardings, abstract_cache
from repro.core.oplog import CacheAlloc, Compile
from repro.core.split_state import (LowerHalf, UpperHalf, fill_like,
                                    register_step_fn, tree_from_paths)
from repro.train.step import make_call_options, ContextualJit


def serve_param_shardings(cfg: ModelConfig, plan: ParallelPlan, mesh):
    ab = M.init_abstract(cfg)
    logical = M.logical_specs(cfg)
    specs = tree_specs(plan, logical, ab, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def jit_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh,
                plan: Optional[ParallelPlan] = None,
                cache_len: Optional[int] = None):
    """``cache_len``: the actual cache sequence capacity when it differs
    from the prompt window (the engine prefills a ``shape.seq_len``-wide
    token bucket into a ``max_seq``-long cache) — sharding divisibility
    must be judged on the real cache geometry, not the bucket's."""
    plan = plan or make_plan(cfg, shape, mesh)
    opts = make_call_options(plan, mesh)

    def prefill_fn(params, tokens, cache, frames=None):
        return M.prefill(cfg, params, tokens, cache, opts, frames=frames)

    pshard = serve_param_shardings(cfg, plan, mesh)
    cshard = cache_shardings(cfg, plan, mesh,
                             abstract_cache(cfg, shape.global_batch,
                                            cache_len or shape.seq_len))
    b = plan.batch_axes[0] if len(plan.batch_axes) == 1 \
        else tuple(plan.batch_axes)
    tshard = NamedSharding(mesh, PartitionSpec(b, None))
    in_sh = [pshard, tshard, cshard]
    fshard = None
    if cfg.is_encoder_decoder:
        fshard = NamedSharding(mesh, PartitionSpec(b, None, None))
        in_sh.append(fshard)
    jitted = jax.jit(prefill_fn, in_shardings=tuple(in_sh),
                     out_shardings=(None, cshard),
                     donate_argnums=(2,))
    return ContextualJit(jitted, mesh, plan), dict(
        plan=plan, cache_shardings=cshard, param_shardings=pshard)


def jit_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                    plan: Optional[ParallelPlan] = None):
    plan = plan or make_plan(cfg, shape, mesh)
    opts = make_call_options(plan, mesh)

    def decode_fn(params, cache, tokens, pos):
        return M.decode_step(cfg, params, cache, tokens, pos, opts)

    pshard = serve_param_shardings(cfg, plan, mesh)
    cshard = cache_shardings(cfg, plan, mesh,
                             abstract_cache(cfg, shape.global_batch,
                                            shape.seq_len))
    b = plan.batch_axes[0] if len(plan.batch_axes) == 1 \
        else tuple(plan.batch_axes)
    bdiv = int(np.prod([mesh.shape[a] for a in plan.batch_axes]))
    b_ok = b if shape.global_batch % bdiv == 0 else None
    tshard = NamedSharding(mesh, PartitionSpec(b_ok, None))
    qshard = NamedSharding(mesh, PartitionSpec(b_ok))
    jitted = jax.jit(decode_fn,
                     in_shardings=(pshard, cshard, tshard, qshard),
                     out_shardings=(None, cshard),
                     donate_argnums=(1,))
    return ContextualJit(jitted, mesh, plan), dict(
        plan=plan, cache_shardings=cshard, param_shardings=pshard)


def serve_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for serving steps (dry-run)."""
    b = shape.global_batch
    if shape.kind == "prefill":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
            "cache": abstract_cache(cfg, b, shape.seq_len),
        }
        if cfg.is_encoder_decoder:
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.frontend_dim), jnp.float32)
        return specs
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
        "cache": abstract_cache(cfg, b, shape.seq_len),
    }


# ---------------------------------------------------------------------------
# C/R registry builders
# ---------------------------------------------------------------------------

def _resolve_cfg(arch: str) -> ModelConfig:
    return cfg_registry.resolve_config(arch)


@register_step_fn("prefill_step")
def _build_prefill(arch, shape_key, plan_key, lower):
    cfg = _resolve_cfg(arch)
    shape = cfg_registry.get_shape(shape_key)
    plan = make_plan(cfg, shape, lower.mesh)
    if plan_key:
        plan = plan.with_(**json.loads(plan_key))
    fn, _ = jit_prefill(cfg, shape, lower.mesh, plan)
    return fn


@register_step_fn("decode_step")
def _build_decode(arch, shape_key, plan_key, lower):
    cfg = _resolve_cfg(arch)
    shape = cfg_registry.get_shape(shape_key)
    plan = make_plan(cfg, shape, lower.mesh)
    if plan_key:
        plan = plan.with_(**json.loads(plan_key))
    fn, _ = jit_decode_step(cfg, shape, lower.mesh, plan)
    return fn


# ---------------------------------------------------------------------------
# continuous batching engine (host-side scheduler)
# ---------------------------------------------------------------------------

@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [P] int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


def _request_tree(r: Request) -> Dict[str, np.ndarray]:
    """A Request as a checkpointable pytree of arrays."""
    return {"rid": np.int64(r.rid), "max_new": np.int64(r.max_new),
            "prompt": np.asarray(r.prompt, np.int32),
            "out": np.asarray(r.out, np.int32)}


def _request_from_tree(t: Dict[str, Any]) -> Request:
    return Request(rid=int(t["rid"]), max_new=int(t["max_new"]),
                   prompt=np.asarray(t["prompt"], np.int32),
                   out=[int(x) for x in np.asarray(t["out"]).ravel()])


def _reslot_rewriter(n_old: int, n_new: int) -> Callable:
    """Op-log rewrite for elastic re-slotting: the logged CacheAlloc and
    decode Compile carry the old slot count; replay them at the new one
    (same virtual ids — the vid/handle indirection is what makes the
    rewrite invisible to everything above the table)."""
    def rewrite(op):
        if isinstance(op, CacheAlloc) and op.batch == n_old:
            return dataclasses.replace(op, batch=n_new)
        if isinstance(op, Compile) and op.fn_name == "decode_step":
            return dataclasses.replace(op, shape_key=re.sub(
                rf"_b{n_old}$", f"_b{n_new}", op.shape_key))
        return op
    return rewrite


class ServingEngine:
    """Slot-based continuous batching over fixed-shape decode steps.

    Decode always runs the full slot batch (fixed shapes = no recompiles);
    finished slots are refilled from the queue between steps. Admission
    rebuilds the slot's decode state from the request's full token
    history — prompt plus any tokens already generated, so a request
    resumed from a checkpoint re-enters mid-generation — through the
    batched prefill path (size-bucketed, right-padded; attention-family
    models) or a single-slot decode replay (recurrent families, where
    padding would pollute the state).
    """

    def __init__(self, cfg: ModelConfig, params, mesh, n_slots: int,
                 max_seq: int, plan: Optional[ParallelPlan] = None,
                 manager=None, lower=None, arch: Optional[str] = None,
                 _adopt: Optional[Dict[str, Any]] = None):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.arch = arch
        if _adopt is not None:
            # runtime resources already exist (built or replayed through
            # the logged lower half) — adopt instead of re-creating
            self.decode = _adopt["decode"]
            self.plan = getattr(self.decode, "plan", plan)
            self.cache = _adopt["cache"]
            self.vexec = _adopt.get("vexec")
            self.vcache = _adopt.get("vcache")
        else:
            shape = ShapeConfig("engine", max_seq, n_slots, "decode")
            self.decode, dinfo = jit_decode_step(cfg, shape, mesh, plan)
            self.plan = dinfo["plan"]
            self.cache = M.init_cache(cfg, n_slots, max_seq)
            self.vexec = self.vcache = None
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.slot_tok = np.zeros((n_slots, 1), np.int32)
        self.queue: List[Request] = []
        self.steps = 0
        # admission executables, built lazily: prefill jits per size
        # bucket, and a batch-1 decode for recurrent-state replay
        self._admit_prefill: Dict[int, Any] = {}
        self._slot_decode = None
        # streaming restore: the checkpointed KV cache still decoding in
        # the background (LazyLeaves), and the slots admission filled
        # while it was in flight — see _ensure_cache()
        self._pending_cache = None
        self._touched_slots: set = set()
        self._prefill_admission = (cfg.family not in ("ssm", "hybrid")
                                   and not cfg.is_encoder_decoder)
        # optional live-session checkpointing (core.async_snapshot):
        # manager drains snapshots in the background, lower's op-log (if
        # the engine was built through the logged runtime) rides along so
        # a restore can replay CacheAlloc/Compile. The engine does NOT
        # hold a CheckpointSession of its own — one session owns an
        # app's lifecycle, and that session is the caller's.
        self.manager = manager
        self.lower = lower

    @classmethod
    def create(cls, arch: str, params, mesh_shape,
               mesh_axes=("data", "model"), *, n_slots: int, max_seq: int,
               manager=None) -> "ServingEngine":
        """Build an engine through the logged C/R runtime: MeshCreate +
        decode Compile + CacheAlloc all flow through a LowerHalf, so a
        snapshot of this engine carries the op-log a restore replays."""
        lower = LowerHalf()
        lower.mesh_create(mesh_shape, mesh_axes)
        vexec = lower.compile_step("decode_step", arch,
                                   f"decode_s{max_seq}_b{n_slots}")
        vcache = lower.cache_alloc(arch, n_slots, max_seq)
        cfg = _resolve_cfg(arch)
        return cls(cfg, params, lower.mesh, n_slots=n_slots,
                   max_seq=max_seq, manager=manager, lower=lower, arch=arch,
                   _adopt={"decode": lower.executable(vexec),
                           "cache": lower.cache(vcache),
                           "vexec": vexec, "vcache": vcache})

    # --- live-session checkpointing ------------------------------------

    def session_state(self) -> UpperHalf:
        """The engine's *complete* semantic (upper-half) state: cache
        contents, slot bookkeeping (positions + pending tokens), every
        in-flight request (prompt, generated tokens, budget, identity)
        and the waiting queue. Params are the trainer's job, not ours."""
        self._ensure_cache()   # never snapshot a half-paged-in cache
        up = UpperHalf()
        up.register("kv_cache", "cache", self.cache)
        up.register("sessions", "sessions", {
            "slot_pos": np.array(self.slot_pos),
            "slot_tok": np.array(self.slot_tok),
        })
        sched: Dict[str, Dict[str, Any]] = {"queue": {}, "slots": {}}
        for i, r in enumerate(self.queue):
            sched["queue"][f"{i:06d}"] = _request_tree(r)
        for s, r in enumerate(self.slot_req):
            if r is not None:
                sched["slots"][f"{s:06d}"] = _request_tree(r)
        up.register("sched", "sched", sched)
        up.register("steps", "step", np.int64(self.steps))
        return up

    def job_meta(self) -> Dict[str, Any]:
        return {"kind": "serving", "arch": self.arch,
                "n_slots": self.n_slots, "max_seq": self.max_seq}

    # --- CheckpointableApp protocol (repro.api) ------------------------

    def checkpoint_state(self) -> UpperHalf:
        # session_state() is the dynamic hook the session prefers; this
        # satisfies the protocol's required method with the same answer
        return self.session_state()

    def checkpoint_step(self) -> int:
        return self.steps

    def runtime_log(self):
        from repro.core.oplog import OpLog
        return self.lower.oplog if self.lower is not None else OpLog()

    def snapshot(self, block: bool = False):
        """Snapshot of live sessions at an engine-step boundary;
        non-blocking by default — decode keeps running while the
        pipeline encodes and writes. Returns the SnapshotHandle (None
        when blocking, or if dropped under "skip" backpressure). Same
        payload a ``CheckpointSession`` wrapping this engine would
        take — the protocol methods are the single source."""
        assert self.manager is not None, \
            "construct with manager= to snapshot"
        return self.manager.save(self.checkpoint_step(),
                                 self.session_state(), self.runtime_log(),
                                 block=block, job_meta=self.job_meta())

    # --- restore (the Incarnation lifecycle, serving flavor) -----------

    @classmethod
    def restore(cls, manager, params, *, n_slots: Optional[int] = None,
                step: Optional[int] = None, mesh=None, mesh_factory=None,
                decode_workers: Optional[int] = None) -> "ServingEngine":
        """Legacy shim: delegates to the public session API
        (``repro.api.CheckpointSession.restore``), which resolves the
        "serving" binder below through the app-kind registry.

        Same-geometry restore (``n_slots`` matches the checkpoint)
        rebinds cache contents and slot state directly. A different
        ``n_slots`` triggers **re-slotting**: the op-log replays with
        CacheAlloc/Compile rewritten to the new slot count, and every
        live session re-enters through admission — the serving analogue
        of elastic multi-device restore. ``mesh``/``mesh_factory``
        override the logged topology."""
        warnings.warn(
            "ServingEngine.restore is a legacy shim; use "
            "repro.api.CheckpointSession.restore", DeprecationWarning,
            stacklevel=2)
        return CheckpointSession.from_manager(manager).restore(
            step=step, expect_kind="serving", mesh_factory=mesh_factory,
            decode_workers=decode_workers, params=params,
            n_slots=n_slots, mesh=mesh)

    def bind(self, restore: RestoreContext) -> None:
        """CheckpointableApp.bind: rebind the *complete* session state —
        cache contents, slot bookkeeping, in-flight requests, waiting
        queue — from a materialized restore context. On a re-slot
        restore (this engine's slot count differs from the checkpoint's)
        the skipped cache/slot entries are rebuilt instead: every former
        in-flight session re-enters through admission, which replays its
        prompt + generated tokens into its new slot."""
        inc = restore.incarnation()
        reslot = self.n_slots != int(restore.job["n_slots"])
        self.steps = int(inc.scalar("steps")) if inc.has_entry("steps") \
            else 0

        sched = (tree_from_paths(inc.entry_paths("sched"))
                 if inc.has_entry("sched") else {})
        slot_reqs = [(int(k), _request_from_tree(v))
                     for k, v in sorted(sched.get("slots", {}).items())]
        queue_reqs = [_request_from_tree(v)
                      for _, v in sorted(sched.get("queue", {}).items())]

        if not reslot:
            kv = inc.entry_paths("kv_cache")
            if callable(getattr(kv, "wait", None)):
                # streaming restore: the KV cache is the cold tier.
                # Keep serving on the fresh cache — admission can
                # prefill new requests into free slots while the
                # checkpointed contents stream in — and land the
                # restored bytes just before the next full-batch
                # decode (_ensure_cache), which is the first moment
                # anything reads other slots' columns.
                self._pending_cache = kv
                self._touched_slots = set()
            else:
                host = fill_like(self.cache, kv)
                self.cache = jax.tree.map(
                    lambda t, v: jnp.asarray(np.asarray(v), dtype=t.dtype),
                    self.cache, host)
            sess = tree_from_paths(inc.entry_paths("sessions"))
            self.slot_pos = np.asarray(sess["slot_pos"], np.int32).copy()
            self.slot_tok = np.asarray(
                sess["slot_tok"], np.int32).copy().reshape(self.n_slots, 1)
            for s, r in slot_reqs:
                self.slot_req[s] = r
            self.queue = queue_reqs
        else:
            # elastic re-slot: former in-flight sessions (slot order)
            # lead the queue, then the waiting requests; admission
            # replays each one's history into its new slot. Sessions
            # beyond the new slot count wait their turn — nothing drops.
            self.queue = [r for _, r in slot_reqs] + queue_reqs
            self._admit()
        inc.release()   # every entry is rebound or rebuilt; drop the
        self.incarnation = inc  # host payload, keep timings + manifest

    def live_requests(self) -> List[Request]:
        """In-flight requests (slot order) + the waiting queue."""
        return [r for r in self.slot_req if r is not None] + list(self.queue)

    def extract_sessions(self, slots: Optional[List[int]] = None, *,
                         include_queue: bool = False) -> List[Request]:
        """Freeze and REMOVE live sessions — the migration source hook.

        The chosen ``slots`` (None = every occupied slot) give up their
        requests; each request carries its complete history (prompt +
        generated tokens + budget), which is all a target engine needs
        to rebuild the session's KV state through admission replay — KV
        bytes never travel. The freed slots zero their bookkeeping and
        refill from the queue on the next step; the decode loop never
        stops, so unaffected slots keep generating throughout a move.
        ``include_queue`` also drains the waiting queue (a full drain
        of this engine)."""
        chosen = range(self.n_slots) if slots is None else slots
        out: List[Request] = []
        for s in chosen:
            if not 0 <= s < self.n_slots:
                raise IndexError(f"slot {s} out of range "
                                 f"(engine has {self.n_slots})")
            r = self.slot_req[s]
            if r is None:
                continue
            out.append(r)
            self.slot_req[s] = None
            self.slot_pos[s] = 0
            self.slot_tok[s, 0] = 0
        if include_queue:
            out.extend(self.queue)
            self.queue = []
        return out

    # --- admission ------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                self._bind_slot(s, self.queue.pop(0))

    def _bind_slot(self, s: int, req: Request) -> None:
        """Admit ``req`` into slot ``s``, rebuilding the slot's decode
        state from the request's full token history. The last history
        token becomes the slot's pending token, so the next engine step
        produces the request's next output token."""
        seq = np.concatenate([np.asarray(req.prompt).ravel(),
                              np.asarray(req.out).ravel()]).astype(np.int32)
        hist = seq[:-1]
        if len(hist):
            if self._prefill_admission:
                self._prefill_slot(s, hist)
            else:
                self._replay_slot(s, hist)
        self.slot_req[s] = req
        self.slot_tok[s, 0] = int(seq[-1])
        self.slot_pos[s] = len(seq) - 1
        if self._pending_cache is not None:
            # admitted while the checkpointed cache is still streaming:
            # this slot's column now holds fresh prefill state that the
            # deferred merge must not overwrite
            self._touched_slots.add(s)

    def _prefill_slot(self, s: int, hist: np.ndarray) -> None:
        """One batched prefill call instead of O(len) full-slot decodes:
        the history is right-padded into a power-of-two bucket (few
        compilations, reused across requests) and prefilled at batch 1
        into a fresh single-slot cache, which then lands in slot ``s``.
        Pad garbage beyond the history writes cache entries at positions
        the causal mask hides until decode overwrites them (each decode
        step rewrites its own position before attending)."""
        width = max(8, 1 << (int(len(hist)) - 1).bit_length())
        width = min(width, self.max_seq)
        assert len(hist) <= width, (len(hist), self.max_seq)
        fn = self._admit_prefill.get(width)
        if fn is None:
            shape = ShapeConfig(f"admit_s{width}_b1", width, 1, "prefill")
            fn, _ = jit_prefill(self.cfg, shape, self.mesh,
                                cache_len=self.max_seq)
            self._admit_prefill[width] = fn
        toks = np.zeros((1, width), np.int32)
        toks[0, :len(hist)] = hist
        one = M.init_cache(self.cfg, 1, self.max_seq)
        _, one = fn(self.params, jnp.asarray(toks), one)
        self._merge_slot(s, one)

    def _replay_slot(self, s: int, hist: np.ndarray) -> None:
        """Recurrent families (SSM/hybrid/enc-dec): state is
        order-sensitive, so padding is off the table — replay the
        history through a batch-1 decode into a fresh single-slot state
        (one compile total, and no cross-slot pollution: the full-batch
        teacher-forcing this replaces re-advanced every *other* live
        slot's recurrent state once per history token)."""
        if self._slot_decode is None:
            shape = ShapeConfig(f"admit_s{self.max_seq}_b1",
                                self.max_seq, 1, "decode")
            self._slot_decode, _ = jit_decode_step(self.cfg, shape,
                                                   self.mesh)
        one = M.init_cache(self.cfg, 1, self.max_seq)
        for i, t in enumerate(hist):
            _, one = self._slot_decode(
                self.params, one, jnp.asarray([[int(t)]], jnp.int32),
                jnp.asarray([i], jnp.int32))
        self._merge_slot(s, one)

    def _merge_slot(self, s: int, one) -> None:
        """Land a single-slot cache tree in slot ``s`` of the engine
        cache. Batch is axis 1 on stacked-layer leaves (axis 0 only on
        rank-1 leaves) — same layout rule as kv_cache.cache_shardings."""
        def merge(full, sl):
            full = jnp.asarray(full)
            sl = jnp.asarray(sl, full.dtype)
            if full.ndim >= 2:
                return full.at[:, s:s + 1].set(sl)
            return full.at[s:s + 1].set(sl)
        self.cache = jax.tree.map(merge, self.cache, one)

    def _ensure_cache(self) -> None:
        """Land the streamed KV cache (first-touch page-in of the cold
        tier). Admission runs *before* this in ``step()`` on purpose:
        prefill compiles and runs while the restored cache is still
        fetching/decoding in the background, which is where streaming
        restore buys its time-to-first-admission. Slot columns admission
        already rewrote keep their fresh prefill state; every other
        column takes the restored bytes — exactly the state the eager
        path reaches by restoring first and letting admission overwrite,
        so the two paths stay bit-identical."""
        if self._pending_cache is None:
            return
        pending, self._pending_cache = self._pending_cache, None
        pending.wait()
        host = fill_like(self.cache, pending)
        touched = sorted(self._touched_slots)
        self._touched_slots = set()

        def land(cur, v):
            cur = jnp.asarray(cur)
            rest = jnp.asarray(np.asarray(v), cur.dtype)
            for s in touched:
                if rest.ndim >= 2:
                    rest = rest.at[:, s:s + 1].set(cur[:, s:s + 1])
                else:
                    rest = rest.at[s:s + 1].set(cur[s:s + 1])
            return rest
        self.cache = jax.tree.map(land, self.cache, host)

    def step(self) -> int:
        """One engine iteration; returns #active slots."""
        self._admit()
        self._ensure_cache()
        active = [s for s in range(self.n_slots) if self.slot_req[s]]
        if not active:
            return 0
        toks = jnp.asarray(self.slot_tok)
        poss = jnp.asarray(self.slot_pos)
        logits, self.cache = self.decode(self.params, self.cache, toks, poss)
        nxt = np.asarray(jax.device_get(jnp.argmax(logits, -1)))
        for s in active:
            req = self.slot_req[s]
            tok = int(nxt[s])
            req.out.append(tok)
            self.slot_tok[s, 0] = tok
            self.slot_pos[s] += 1
            if len(req.out) >= req.max_new or self.slot_pos[s] >= self.max_seq - 1:
                req.done = True
                self.slot_req[s] = None
        self.steps += 1
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000,
                          snapshot_every: Optional[int] = None) -> None:
        while (self.queue or any(self.slot_req)) and max_steps > 0:
            self.step()
            if snapshot_every and self.steps % snapshot_every == 0 \
                    and self.manager is not None:
                self.snapshot()
            max_steps -= 1
        if snapshot_every and self.manager is not None:
            self.manager.wait()


@register_app_kind("serving")
def _restore_engine(restore: RestoreContext, params,
                    n_slots: Optional[int] = None,
                    mesh=None) -> ServingEngine:
    """The "serving" restore binder: the Incarnation lifecycle, serving
    flavor. On a re-slot restore the checkpoint's KV cache and slot
    bookkeeping are rebuilt from scratch, so their delta chains — the
    bulk of the payload — are skipped at decode, not decoded and
    dropped; the op-log replays with CacheAlloc/Compile rewritten to
    the new slot count (composed with any session-level rewrite, e.g. a
    supervisor's DataReassign rewrite)."""
    job = restore.job
    arch = job.get("arch")
    if arch is None:
        raise RestoreError("checkpoint predates engine arch metadata; "
                           "cannot rebuild the engine from it")
    n_old, max_seq = int(job["n_slots"]), int(job["max_seq"])
    n_new = int(n_slots) if n_slots is not None else n_old
    reslot = n_new != n_old

    rewriters = [r for r in (restore.rewrite_op,
                             _reslot_rewriter(n_old, n_new) if reslot
                             else None) if r is not None]
    rewrite = None
    if rewriters:
        rewrite = rewriters[0] if len(rewriters) == 1 else \
            (lambda op: rewriters[1](rewriters[0](op)))
    mesh_factory = None
    if mesh is not None and restore.mesh_factory is None:
        mesh_factory = lambda m=mesh: m  # noqa: E731

    inc = restore.incarnation(
        skip_entries=("kv_cache", "sessions") if reslot else None,
        rewrite_op=rewrite, mesh_factory=mesh_factory)
    inc.materialize()
    lower = inc.build_lower()
    cfg = _resolve_cfg(arch)
    use_mesh = inc.mesh_or_none()
    if use_mesh is None:
        use_mesh = mesh
    if use_mesh is None:
        raise RestoreError("op-log bound no mesh (engine was built "
                           "outside the logged runtime); pass mesh=")
    vexec = inc.last_compile("decode_step")
    adopt = None
    if vexec is not None:
        vcache = inc.last_cache_alloc()
        adopt = {"decode": lower.executable(vexec),
                 "cache": (lower.cache(vcache) if vcache is not None
                           else M.init_cache(cfg, n_new, max_seq)),
                 "vexec": vexec, "vcache": vcache}
    eng = ServingEngine(cfg, params, use_mesh, n_slots=n_new,
                        max_seq=max_seq, manager=restore.manager,
                        lower=lower, arch=arch, _adopt=adopt)
    eng.bind(restore)
    return eng
