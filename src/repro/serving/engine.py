"""Serving: sharded prefill/decode steps + a continuous-batching engine.

The step builders are registered in the C/R function registry, so a
serving process restores exactly like a trainer: fresh lower half, replay
recompiles prefill/decode executables, CacheAlloc replay re-creates the
(zeroed) cache, and — if the operator checkpointed live sessions — the
cache contents re-materialize as an upper-half entry.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs import registry as cfg_registry
from repro.models import model as M
from repro.parallel.sharding import ParallelPlan, tree_specs
from repro.parallel.planner import make_plan
from repro.parallel import context as pctx
from repro.serving.kv_cache import cache_shardings, abstract_cache
from repro.core.split_state import register_step_fn
from repro.train.step import make_call_options, ContextualJit


def serve_param_shardings(cfg: ModelConfig, plan: ParallelPlan, mesh):
    ab = M.init_abstract(cfg)
    logical = M.logical_specs(cfg)
    specs = tree_specs(plan, logical, ab, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def jit_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh,
                plan: Optional[ParallelPlan] = None):
    plan = plan or make_plan(cfg, shape, mesh)
    opts = make_call_options(plan, mesh)

    def prefill_fn(params, tokens, cache, frames=None):
        return M.prefill(cfg, params, tokens, cache, opts, frames=frames)

    pshard = serve_param_shardings(cfg, plan, mesh)
    cshard = cache_shardings(cfg, plan, mesh,
                             abstract_cache(cfg, shape.global_batch,
                                            shape.seq_len))
    b = plan.batch_axes[0] if len(plan.batch_axes) == 1 \
        else tuple(plan.batch_axes)
    tshard = NamedSharding(mesh, PartitionSpec(b, None))
    in_sh = [pshard, tshard, cshard]
    fshard = None
    if cfg.is_encoder_decoder:
        fshard = NamedSharding(mesh, PartitionSpec(b, None, None))
        in_sh.append(fshard)
    jitted = jax.jit(prefill_fn, in_shardings=tuple(in_sh),
                     out_shardings=(None, cshard),
                     donate_argnums=(2,))
    return ContextualJit(jitted, mesh, plan), dict(
        plan=plan, cache_shardings=cshard, param_shardings=pshard)


def jit_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                    plan: Optional[ParallelPlan] = None):
    plan = plan or make_plan(cfg, shape, mesh)
    opts = make_call_options(plan, mesh)

    def decode_fn(params, cache, tokens, pos):
        return M.decode_step(cfg, params, cache, tokens, pos, opts)

    pshard = serve_param_shardings(cfg, plan, mesh)
    cshard = cache_shardings(cfg, plan, mesh,
                             abstract_cache(cfg, shape.global_batch,
                                            shape.seq_len))
    b = plan.batch_axes[0] if len(plan.batch_axes) == 1 \
        else tuple(plan.batch_axes)
    bdiv = int(np.prod([mesh.shape[a] for a in plan.batch_axes]))
    b_ok = b if shape.global_batch % bdiv == 0 else None
    tshard = NamedSharding(mesh, PartitionSpec(b_ok, None))
    qshard = NamedSharding(mesh, PartitionSpec(b_ok))
    jitted = jax.jit(decode_fn,
                     in_shardings=(pshard, cshard, tshard, qshard),
                     out_shardings=(None, cshard),
                     donate_argnums=(1,))
    return ContextualJit(jitted, mesh, plan), dict(
        plan=plan, cache_shardings=cshard, param_shardings=pshard)


def serve_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for serving steps (dry-run)."""
    b = shape.global_batch
    if shape.kind == "prefill":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
            "cache": abstract_cache(cfg, b, shape.seq_len),
        }
        if cfg.is_encoder_decoder:
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.frontend_dim), jnp.float32)
        return specs
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
        "cache": abstract_cache(cfg, b, shape.seq_len),
    }


# ---------------------------------------------------------------------------
# C/R registry builders
# ---------------------------------------------------------------------------

def _resolve_cfg(arch: str) -> ModelConfig:
    if arch in cfg_registry.ARCH_IDS:
        return cfg_registry.get_config(arch)
    return cfg_registry.get_smoke_config(arch.removesuffix("-smoke"))


@register_step_fn("prefill_step")
def _build_prefill(arch, shape_key, plan_key, lower):
    cfg = _resolve_cfg(arch)
    shape = cfg_registry.get_shape(shape_key)
    plan = make_plan(cfg, shape, lower.mesh)
    if plan_key:
        plan = plan.with_(**json.loads(plan_key))
    fn, _ = jit_prefill(cfg, shape, lower.mesh, plan)
    return fn


@register_step_fn("decode_step")
def _build_decode(arch, shape_key, plan_key, lower):
    cfg = _resolve_cfg(arch)
    shape = cfg_registry.get_shape(shape_key)
    plan = make_plan(cfg, shape, lower.mesh)
    if plan_key:
        plan = plan.with_(**json.loads(plan_key))
    fn, _ = jit_decode_step(cfg, shape, lower.mesh, plan)
    return fn


# ---------------------------------------------------------------------------
# continuous batching engine (host-side scheduler)
# ---------------------------------------------------------------------------

@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [P] int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Slot-based continuous batching over fixed-shape decode steps.

    Decode always runs the full slot batch (fixed shapes = no recompiles);
    finished slots are refilled from the queue between steps. Prefill for
    a new request runs single-request with right-aligned padding into its
    slot (the batched-prefill variant is a benchmark knob).
    """

    def __init__(self, cfg: ModelConfig, params, mesh, n_slots: int,
                 max_seq: int, plan: Optional[ParallelPlan] = None,
                 manager=None, lower=None):
        self.cfg = cfg
        self.params = params
        shape = ShapeConfig("engine", max_seq, n_slots, "decode")
        self.decode, dinfo = jit_decode_step(cfg, shape, mesh, plan)
        self.plan = dinfo["plan"]
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = M.init_cache(cfg, n_slots, max_seq)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.slot_tok = np.zeros((n_slots, 1), np.int32)
        self.queue: List[Request] = []
        self.steps = 0
        # optional live-session checkpointing (core.async_snapshot):
        # manager drains snapshots in the background, lower's op-log (if
        # the engine was built through the logged runtime) rides along so
        # a restore can replay CacheAlloc/Compile
        self.manager = manager
        self.lower = lower

    # --- live-session checkpointing ------------------------------------

    def session_state(self):
        """The engine's semantic (upper-half) state: cache contents plus
        slot bookkeeping. Params are the trainer's job, not ours."""
        from repro.core.split_state import UpperHalf
        up = UpperHalf()
        up.register("kv_cache", "cache", self.cache)
        up.register("sessions", "sessions", {
            "slot_pos": np.array(self.slot_pos),
            "slot_tok": np.array(self.slot_tok),
        })
        up.register("steps", "step", np.int64(self.steps))
        return up

    def snapshot(self):
        """Non-blocking snapshot of live sessions at an engine-step
        boundary; decode keeps running while the pipeline encodes and
        writes. Returns the SnapshotHandle (None if dropped under
        "skip" backpressure)."""
        assert self.manager is not None, "construct with manager= to snapshot"
        from repro.core.oplog import OpLog
        log = self.lower.oplog if self.lower is not None else OpLog()
        return self.manager.save(self.steps, self.session_state(), log,
                                 block=False,
                                 job_meta={"kind": "serving",
                                           "n_slots": self.n_slots,
                                           "max_seq": self.max_seq})

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[s] = req
                # "prefill" by teacher-forcing all but the last prompt
                # token through decode steps (unit scale; batched prefill
                # is exercised by jit_prefill separately). The last
                # prompt token is left as the slot's pending token so the
                # next engine step produces the first generated token.
                for i, t in enumerate(req.prompt[:-1]):
                    self._step_slot(s, int(t), i)
                self.slot_tok[s, 0] = int(req.prompt[-1])
                self.slot_pos[s] = len(req.prompt) - 1

    def _step_slot(self, s: int, token: int, pos: int) -> None:
        toks = np.array(self.slot_tok)
        toks[s, 0] = token
        poss = np.array(self.slot_pos)
        poss[s] = pos
        logits, self.cache = self.decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(poss))
        self._last_logits = np.asarray(jax.device_get(logits))
        self.slot_tok = toks

    def step(self) -> int:
        """One engine iteration; returns #active slots."""
        self._admit()
        active = [s for s in range(self.n_slots) if self.slot_req[s]]
        if not active:
            return 0
        toks = jnp.asarray(self.slot_tok)
        poss = jnp.asarray(self.slot_pos)
        logits, self.cache = self.decode(self.params, self.cache, toks, poss)
        nxt = np.asarray(jax.device_get(jnp.argmax(logits, -1)))
        for s in active:
            req = self.slot_req[s]
            tok = int(nxt[s])
            req.out.append(tok)
            self.slot_tok[s, 0] = tok
            self.slot_pos[s] += 1
            if len(req.out) >= req.max_new or self.slot_pos[s] >= self.max_seq - 1:
                req.done = True
                self.slot_req[s] = None
        self.steps += 1
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000,
                          snapshot_every: Optional[int] = None) -> None:
        while (self.queue or any(self.slot_req)) and max_steps > 0:
            self.step()
            if snapshot_every and self.steps % snapshot_every == 0 \
                    and self.manager is not None:
                self.snapshot()
            max_steps -= 1
        if snapshot_every and self.manager is not None:
            self.manager.wait()
