"""Synthetic many-client traffic for fleet experiments.

A fleet claim ("zero dropped requests during a live move") is only as
strong as the load it was proven under; this generator produces that
load deterministically. Arrivals are Poisson per engine step (the
standard open-loop serving model: clients don't wait for each other),
prompt lengths and token budgets draw uniformly from ranges, and
everything comes from one seeded ``RandomState`` — the same seed
replays the same traffic, which is what lets a migration run be
compared request-by-request against an undisturbed reference run.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np


class TrafficGenerator:
    """Open-loop Poisson arrivals over a FleetRouter (or any object
    with ``submit(prompt, max_new) -> rid``).

    ``rate``        mean arrivals per ``tick()`` (Poisson lambda).
    ``vocab``       token id range for synthetic prompts (exclusive).
    ``prompt_len``  inclusive (lo, hi) prompt-length range.
    ``max_new``     inclusive (lo, hi) token-budget range.
    ``limit``       total requests to emit (None = unbounded).
    """

    def __init__(self, rate: float, *, seed: int = 0, vocab: int = 32,
                 prompt_len: Tuple[int, int] = (3, 9),
                 max_new: Tuple[int, int] = (4, 12),
                 limit: Optional[int] = None) -> None:
        if rate < 0:
            raise ValueError(f"rate={rate}: arrivals per tick must be "
                             ">= 0")
        self.rate = float(rate)
        self.vocab = int(vocab)
        self.prompt_len = prompt_len
        self.max_new = max_new
        self.limit = limit
        self.rng = np.random.RandomState(seed)
        self.emitted: Dict[int, Dict[str, Any]] = {}   # rid -> shape

    def _draw(self) -> Tuple[np.ndarray, int]:
        plen = int(self.rng.randint(self.prompt_len[0],
                                    self.prompt_len[1] + 1))
        prompt = self.rng.randint(1, self.vocab,
                                  size=plen).astype(np.int32)
        budget = int(self.rng.randint(self.max_new[0],
                                      self.max_new[1] + 1))
        return prompt, budget

    def tick(self, router: Any, *, engine: Optional[str] = None) -> List[int]:
        """One step of arrivals: Poisson-many new requests submitted to
        ``router``; returns their rids."""
        n = int(self.rng.poisson(self.rate))
        if self.limit is not None:
            n = min(n, self.limit - len(self.emitted))
        rids = []
        for _ in range(n):
            prompt, budget = self._draw()
            kw = {"engine": engine} if engine is not None else {}
            rid = router.submit(prompt, budget, **kw)
            self.emitted[rid] = {"prompt": prompt, "max_new": budget}
            rids.append(rid)
        return rids

    def drained(self) -> bool:
        return self.limit is not None and len(self.emitted) >= self.limit
