"""Sharded decode-state allocation (KV caches, SSM states, RG-LRU
hiddens) and their shardings.

Caches are lower-half resources: allocated through the logged runtime API
(CacheAlloc), referenced by virtual ids, re-allocated fresh at restore by
replay. For *serving* restores, the cache contents can optionally be
checkpointed as an upper-half entry (they're semantic: the conversation's
context) — see engine.snapshot_cache.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig
from repro.configs import registry as cfg_registry
from repro.models import model as M
from repro.parallel.sharding import ParallelPlan


def cache_shardings(cfg: ModelConfig, plan: ParallelPlan, mesh,
                    spec_tree) -> Any:
    """Pattern-match cache leaves by path to assign shardings.

    Layout rules (see DESIGN.md §5):
      k/v      [L, B, S, Hkv, hd] -> batch over data; kv-heads over model
               when divisible, else seq over model (flash-decoding combine)
      pos      [L, B, S]          -> batch over data
      ssm state[L, B, H, hd, ds]  -> heads over model
      rg state [L?, B, W]         -> width over model
      conv     [...]              -> batch over data only
    """
    b_axes = plan.batch_axes[0] if len(plan.batch_axes) == 1 \
        else tuple(plan.batch_axes)
    m = plan.model_axis
    bdiv = int(np.prod([mesh.shape[a] for a in plan.batch_axes]))
    msize = int(mesh.shape[m]) if m else 1

    def leaf_spec(path: str, ab) -> PartitionSpec:
        shape = ab.shape
        batch_dim = 1 if len(shape) >= 2 else 0  # leading dim = layers
        b = b_axes if shape[batch_dim] % bdiv == 0 else None
        import re
        keys = re.findall(r"'(\w+)'", path)
        name = keys[-1] if keys else ""
        if name == "state" and len(shape) == 5:      # ssm [L,B,H,hd,ds]
            if m and shape[2] % msize == 0:
                return PartitionSpec(None, b, m, None, None)
            return PartitionSpec(None, b, None, None, None)
        if name == "state" and len(shape) == 3:      # rg [L,B,W]
            if m and shape[2] % msize == 0:
                return PartitionSpec(None, b, m)
            return PartitionSpec(None, b, None)
        if name == "pos":
            return PartitionSpec(None, b, None)
        if name in ("k", "v") or len(shape) == 5:    # [L,B,S,Hkv,hd]
            if m and shape[3] % msize == 0 and plan.cache_seq_axis is None:
                return PartitionSpec(None, b, None, m, None)
            if plan.cache_seq_axis and shape[2] % msize == 0:
                return PartitionSpec(None, b, plan.cache_seq_axis, None, None)
            return PartitionSpec(None, b, None, None, None)
        # conv states & misc: batch only
        return PartitionSpec(*([None, b] + [None] * (len(shape) - 2))[:len(shape)])

    leaves, treedef = jax.tree_util.tree_flatten_with_path(spec_tree)
    out = []
    for p, ab in leaves:
        ps = leaf_spec(jax.tree_util.keystr(p), ab)
        out.append(NamedSharding(mesh, ps))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return M.cache_spec(cfg, batch, max_seq)


def allocate_cache(arch: str, batch: int, max_seq: int, lower) -> Any:
    """Materialize a zeroed cache on the lower half's mesh (CacheAlloc)."""
    cfg = cfg_registry.resolve_config(arch)
    try:
        mesh = lower.mesh
    except Exception:
        mesh = None
    if mesh is None:
        return M.init_cache(cfg, batch, max_seq)
    from repro.parallel.planner import make_plan
    from repro.configs.base import ShapeConfig
    shape = ShapeConfig("alloc", max_seq, batch, "decode")
    plan = make_plan(cfg, shape, mesh)
    spec_tree = M.cache_spec(cfg, batch, max_seq)
    shardings = cache_shardings(cfg, plan, mesh, spec_tree)

    # build zeros directly sharded (no host materialization)
    def build():
        def z(ab):
            if ab.dtype == jnp.int32:
                return jnp.full(ab.shape, -1, jnp.int32)
            return jnp.zeros(ab.shape, ab.dtype)
        return jax.tree.map(z, spec_tree)

    return jax.jit(build, out_shardings=shardings)()
