"""CheckpointBackend ABC — the package-agnostic boundary (paper §II/§V).

Everything above this interface (split halves, op-log, virtual ids, delta
encoding, codecs, the async snapshot pipeline) is shared between
backends, which is the paper's agnosticism claim: the same core ran under
both CRIU and DMTCP. Here the two backends are LocalFSBackend
(CRIU-analogue: one monolithic image directory per checkpoint) and
ShardedBackend (DMTCP-analogue: coordinator manifest + per-host shard
files + optional peer replication).

Commit protocol (crash safety contract every backend must honor):

  1. blobs first — ``put_blob`` writes to a temp file, fsyncs, then
     atomically renames into place. Blob names are content-addressed, so
     a re-write after a crash is idempotent and a partial temp file is
     invisible garbage (swept by ``clean_tmp`` on open).
  2. manifest last — ``commit_manifest`` is the *only* publication
     point: temp write + fsync + rename (+ directory fsync). A
     checkpoint is visible iff its manifest file exists, so a crash at
     any earlier instant leaves the previous checkpoint as "latest",
     never a torn one.

Blob writes fan out: the async pipeline's writer pool issues many
concurrent ``put_blob`` calls per snapshot (and ShardedBackend further
fans each one to per-host writers + replicas), so implementations must
be thread-safe.
"""
from __future__ import annotations

import abc
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, List, Optional


def fsync_dir(d: Path) -> None:
    """Make a rename durable: fsync the directory holding the entry."""
    fd = os.open(d, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_atomic(path: Path, data: bytes, fsync: bool) -> None:
    """The commit-protocol write: temp file in the target directory,
    optional fsync, atomic rename, optional directory fsync; the temp
    file is unlinked on any failure. Both backends publish blobs and
    manifests through this one helper so durability fixes land once."""
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            if fsync:
                f.flush()
                os.fsync(f.fileno())
        os.rename(tmp, path)
        if fsync:
            fsync_dir(path.parent)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def clean_tmp_under(root: Path, max_age_seconds: float) -> int:
    """Sweep stale temp files under `root` (see clean_tmp contract)."""
    import time
    cutoff = time.time() - max_age_seconds
    n = 0
    for p in root.rglob(".tmp*"):
        try:
            if p.stat().st_mtime < cutoff:
                p.unlink()
                n += 1
        except FileNotFoundError:  # racing writer finished/cleaned it
            pass
    return n


class CheckpointBackend(abc.ABC):
    @abc.abstractmethod
    def put_blob(self, name: str, data: bytes) -> None:
        """Durably store a blob (idempotent by name; content-addressed
        names). Must be safe to call concurrently from many threads."""

    @abc.abstractmethod
    def get_blob(self, name: str) -> bytes:
        ...

    @abc.abstractmethod
    def has_blob(self, name: str) -> bool:
        ...

    @abc.abstractmethod
    def commit_manifest(self, step: int, manifest: Dict[str, Any]) -> None:
        """Atomically publish a checkpoint at `step` (fsync + rename).
        Must only be called after every blob the manifest references is
        durable; partial blob writes are harmless garbage."""

    @abc.abstractmethod
    def get_manifest(self, step: int) -> Dict[str, Any]:
        ...

    @abc.abstractmethod
    def list_steps(self) -> List[int]:
        ...

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return max(steps) if steps else None

    def clean_tmp(self, max_age_seconds: float = 3600.0) -> int:
        """Sweep temp files left by a crashed writer; returns count.
        Called on open. Only files older than ``max_age_seconds`` are
        removed: another live process may have in-flight writes in the
        same root, and unlinking a fresh temp file would break its
        rename. A no-op for backends without temp files."""
        return 0

    @abc.abstractmethod
    def delete_step(self, step: int) -> None:
        """Remove a manifest (blob GC handled separately)."""

    @abc.abstractmethod
    def gc_blobs(self, referenced: set) -> int:
        """Delete blobs not in `referenced`; returns count removed."""
