"""CheckpointBackend ABC — the package-agnostic boundary (paper §II/§V).

Everything above this interface (split halves, op-log, virtual ids, delta
encoding, codecs) is shared between backends, which is the paper's
agnosticism claim: the same core ran under both CRIU and DMTCP. Here the
two backends are LocalFSBackend (CRIU-analogue: one monolithic image
directory per checkpoint) and ShardedBackend (DMTCP-analogue: coordinator
manifest + per-host shard files + optional peer replication).

Blobs are content-addressed at the delta layer; a backend only needs
put/get/commit semantics with an atomic manifest commit.
"""
from __future__ import annotations

import abc
import json
from typing import Any, Dict, List, Optional


class CheckpointBackend(abc.ABC):
    @abc.abstractmethod
    def put_blob(self, name: str, data: bytes) -> None:
        """Store a blob (idempotent by name; content-addressed names)."""

    @abc.abstractmethod
    def get_blob(self, name: str) -> bytes:
        ...

    @abc.abstractmethod
    def has_blob(self, name: str) -> bool:
        ...

    @abc.abstractmethod
    def commit_manifest(self, step: int, manifest: Dict[str, Any]) -> None:
        """Atomically publish a checkpoint at `step`. A checkpoint is
        visible iff its manifest committed; partial blob writes are
        harmless garbage."""

    @abc.abstractmethod
    def get_manifest(self, step: int) -> Dict[str, Any]:
        ...

    @abc.abstractmethod
    def list_steps(self) -> List[int]:
        ...

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return max(steps) if steps else None

    @abc.abstractmethod
    def delete_step(self, step: int) -> None:
        """Remove a manifest (blob GC handled separately)."""

    @abc.abstractmethod
    def gc_blobs(self, referenced: set) -> int:
        """Delete blobs not in `referenced`; returns count removed."""
