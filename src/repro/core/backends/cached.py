"""CachedBackend — a local read-through blob cache over any other store.

``cached:/ssd-path?over=sharded:/remote?hosts=4`` makes restoring from a
slow or remote store just another ``--store`` string: reads hit the
local tier first and fall through to the inner store, warming the cache
on the way back (MANA's transport-agnostic image sourcing, applied to
the content-addressed blob layer). Because blob names are
content-addressed, a cached copy can never go stale — the cache needs no
invalidation protocol, only space.

Division of labor:

* **blobs** are the cached tier. ``get_blob`` serves a local hit
  without touching the inner store; a miss reads through and
  write-through-warms the local copy. ``put_blob`` writes both tiers so
  a snapshot taken through a cached front restores warm.
* **manifests** (and step listing, GC, deletion) always delegate to the
  inner store — publication/visibility must have exactly one source of
  truth, and a manifest read is tiny next to the blobs it references.
* **replication machinery** sees through the front via the ``inner``
  attribute (the replica-scan CLI unwraps it), and the streaming
  restore's fetch fan-out gets both tiers as independent hedgeable
  sources from ``blob_sources`` below — a fetch served by the remote
  store still warms the cache, which is how a streaming restore doubles
  as a cache-priming pass.
"""
from __future__ import annotations

import threading
from pathlib import Path
from typing import Any, Callable, Dict, List, Tuple

from repro.core.backends.base import (CheckpointBackend, clean_tmp_under,
                                      write_atomic)


class CachedBackend(CheckpointBackend):
    def __init__(self, cache_dir: str, inner: CheckpointBackend, *,
                 fsync: bool = False) -> None:
        # local tier is a cache, not the durability story — fsync
        # defaults off (losing it costs re-fetches, never data)
        self.cache_dir = Path(cache_dir)
        self.inner = inner
        self.fsync = fsync
        (self.cache_dir / "blobs").mkdir(parents=True, exist_ok=True)
        self._lock = threading.Lock()
        self.stats: Dict[str, int] = {"hits": 0, "misses": 0, "warmed": 0}
        self.clean_tmp()

    # --- blobs: local tier first, read-through + warm ------------------

    def _cache_path(self, name: str) -> Path:
        return self.cache_dir / "blobs" / name[:2] / name

    def _warm(self, name: str, data: bytes) -> None:
        p = self._cache_path(name)
        if p.exists():
            return  # content-addressed: identical by construction
        p.parent.mkdir(parents=True, exist_ok=True)
        write_atomic(p, data, self.fsync)
        with self._lock:
            self.stats["warmed"] += 1

    def put_blob(self, name: str, data: bytes) -> None:
        self.inner.put_blob(name, data)   # durability first
        self._warm(name, data)

    def get_blob(self, name: str) -> bytes:
        p = self._cache_path(name)
        try:
            data = p.read_bytes()
        except FileNotFoundError:
            pass
        else:
            with self._lock:
                self.stats["hits"] += 1
            return data
        with self._lock:
            self.stats["misses"] += 1
        data = self.inner.get_blob(name)
        self._warm(name, data)
        return data

    def has_blob(self, name: str) -> bool:
        return self._cache_path(name).exists() or self.inner.has_blob(name)

    def blob_sources(self, name: str) -> List[Tuple[str, Callable[[], bytes]]]:
        """Both tiers as independent fetch sources for the streaming
        restore: the local cache (preferred; raises on a miss so the
        fetcher falls to the next source immediately) and every source
        of the inner store, each wrapped to warm the cache on the way
        through. Hedging a slow remote read against the cache is a
        no-op on a cold cache and a free win on a warm one."""
        from repro.core.replication import blob_sources as inner_sources

        def read_cache() -> bytes:
            data = self._cache_path(name).read_bytes()
            with self._lock:
                self.stats["hits"] += 1
            return data

        out: List[Tuple[str, Callable[[], bytes]]] = [("cache", read_cache)]
        for label, read in inner_sources(self.inner, name):

            def read_and_warm(r=read) -> bytes:
                data = r()
                self._warm(name, data)
                return data

            out.append((label, read_and_warm))
        return out

    # --- everything with one source of truth delegates -----------------

    def commit_manifest(self, step: int, manifest: Dict[str, Any]) -> None:
        self.inner.commit_manifest(step, manifest)

    def get_manifest(self, step: int) -> Dict[str, Any]:
        return self.inner.get_manifest(step)

    def list_steps(self) -> List[int]:
        return self.inner.list_steps()

    def clean_tmp(self, max_age_seconds: float = 3600.0) -> int:
        return (clean_tmp_under(self.cache_dir, max_age_seconds)
                + self.inner.clean_tmp(max_age_seconds))

    def delete_step(self, step: int) -> None:
        self.inner.delete_step(step)

    def gc_blobs(self, referenced: set) -> int:
        n = self.inner.gc_blobs(referenced)
        # keep the cache in lockstep so it never outgrows the store
        for p in (self.cache_dir / "blobs").glob("*/*"):
            if p.name not in referenced:
                p.unlink()
                n += 1
        return n
