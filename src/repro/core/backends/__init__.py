from repro.core.backends.base import CheckpointBackend
from repro.core.backends.cached import CachedBackend
from repro.core.backends.localfs import LocalFSBackend
from repro.core.backends.sharded import ShardedBackend

BACKENDS = {"localfs": LocalFSBackend, "sharded": ShardedBackend,
            "cached": CachedBackend}


def make_backend(kind: str, root: str, **kw) -> CheckpointBackend:
    return BACKENDS[kind](root, **kw)
