"""ShardedBackend — the DMTCP-analogue.

DMTCP writes one checkpoint file per rank, coordinated by a central
coordinator that publishes completion. Here: blobs are hashed to N
virtual hosts; each host owns a directory and writes its blobs in
parallel (thread pool standing in for per-host writers); the coordinator
commits the manifest only after every host's writes land — and verifies
that claim at commit time: a manifest referencing a blob no live host
can serve is refused, never published silently partial. Optional peer
replication keeps each blob *also* on host (h+1) % N so a single-host
loss restores without the primary; ``core.replication`` rebuilds a lost
host's directory from those peer copies (``replication.repair``), and
``fail_host``/``heal_host`` here are the failure injection it and the
tests drive.
"""
from __future__ import annotations

import json
from concurrent.futures import ThreadPoolExecutor, wait
from pathlib import Path
from typing import Any, Dict, List, Optional

from repro.api.errors import BackendUnavailable
from repro.core.backends.base import (CheckpointBackend, clean_tmp_under,
                                      write_atomic)


def _host_of(name: str, n_hosts: int) -> int:
    # stable fnv-1a over the blob name
    h = 2166136261
    for ch in name.encode():
        h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
    return h % n_hosts


class ShardedBackend(CheckpointBackend):
    def __init__(self, root: str, n_hosts: int = 4, replicate: bool = False,
                 writers: int = 4, *, fsync: bool = True) -> None:
        self.root = Path(root)
        self.n_hosts = n_hosts
        self.replicate = replicate
        self.fsync = fsync
        self._pool = ThreadPoolExecutor(max_workers=writers)
        self._failed_hosts: set = set()  # failure injection for tests
        for h in range(n_hosts):
            (self.root / f"host_{h:03d}").mkdir(parents=True, exist_ok=True)
        (self.root / "coordinator").mkdir(parents=True, exist_ok=True)
        self.clean_tmp()

    # --- failure injection ----------------------------------------------

    def fail_host(self, h: int) -> None:
        self._failed_hosts.add(h)

    def heal_host(self, h: int) -> None:
        self._failed_hosts.discard(h)

    # --- blobs -----------------------------------------------------------

    def _placements(self, name: str) -> List[tuple]:
        """(host, path) for every copy the blob should have, primary
        first — the single definition of the placement/replication
        layout (reads, writes and replication repair all derive from
        it)."""
        h = _host_of(name, self.n_hosts)
        out = [(h, self.root / f"host_{h:03d}" / name)]
        if self.replicate:
            r = (h + 1) % self.n_hosts
            out.append((r, self.root / f"host_{r:03d}" / f"replica_{name}"))
        return out

    def _paths(self, name: str) -> List[Path]:
        return [p for _, p in self._placements(name)]

    def _write(self, path: Path, host: int, data: bytes) -> None:
        if host in self._failed_hosts:
            # the per-host writer is down: the write is LOST, and saying
            # so here is what lets the pipeline abort before the
            # manifest publishes a checkpoint it cannot serve
            raise IOError(f"host {host} down; write of {path.name} lost")
        if path.exists():
            return
        write_atomic(path, data, self.fsync)

    def put_blob(self, name: str, data: bytes) -> None:
        futures = [self._pool.submit(self._write, p, host, data)
                   for host, p in self._placements(name)]
        done, _ = wait(futures)
        for f in done:
            f.result()

    def get_blob(self, name: str) -> bytes:
        errors = []
        for host, p in self._placements(name):
            if host in self._failed_hosts:
                errors.append(f"host {host} down")
                continue
            if p.exists():
                return p.read_bytes()
            errors.append(f"{p} missing")
        raise FileNotFoundError(f"blob {name}: {'; '.join(errors)}")

    def has_blob(self, name: str) -> bool:
        return any(host not in self._failed_hosts and p.exists()
                   for host, p in self._placements(name))

    # --- coordinator manifests --------------------------------------------

    def _manifest_path(self, step: int) -> Path:
        return self.root / "coordinator" / f"step_{step:012d}.json"

    def commit_manifest(self, step: int, manifest: Dict[str, Any]) -> None:
        # the coordinator's completion check, made real: every blob the
        # manifest references must be servable by a live host *now*, or
        # the commit fails loudly instead of publishing a checkpoint
        # whose writes were silently lost (a down host's writer raises
        # in put_blob, but this also catches out-of-band loss between
        # write and commit). Blobs the parent chain link already
        # references were verified at ITS commit and are skipped, so
        # this stat pass is O(this snapshot's writes) — scaling with
        # the change rate like the rest of the dirty-capture pipeline —
        # not O(total checkpoint size). A vanished parent (GC race)
        # falls back to verifying everything.
        from repro.core.delta import referenced_hashes
        from repro.core.replication import verify_restorable
        exclude: set = set()
        base = manifest.get("base_step")
        if base is not None:
            try:
                exclude = referenced_hashes(self.get_manifest(base))
            except FileNotFoundError:
                pass
        missing = verify_restorable(self, manifest, exclude=exclude)
        if missing:
            raise BackendUnavailable(
                f"refusing to commit step {step}: {len(missing)} "
                f"referenced blob(s) unservable (first: {missing[0]})")
        write_atomic(self._manifest_path(step),
                     json.dumps(manifest).encode(), self.fsync)

    def clean_tmp(self, max_age_seconds: float = 3600.0) -> int:
        return clean_tmp_under(self.root, max_age_seconds)

    def get_manifest(self, step: int) -> Dict[str, Any]:
        return json.loads(self._manifest_path(step).read_text())

    def list_steps(self) -> List[int]:
        return sorted(int(p.stem.split("_")[1])
                      for p in (self.root / "coordinator").glob("step_*.json"))

    def delete_step(self, step: int) -> None:
        p = self._manifest_path(step)
        if p.exists():
            p.unlink()

    def gc_blobs(self, referenced: set) -> int:
        n = 0
        for h in range(self.n_hosts):
            for p in (self.root / f"host_{h:03d}").iterdir():
                name = p.name
                if name.startswith("replica_"):
                    name = name[len("replica_"):]
                if name not in referenced:
                    p.unlink()
                    n += 1
        return n
