"""ShardedBackend — the DMTCP-analogue.

DMTCP writes one checkpoint file per rank, coordinated by a central
coordinator that publishes completion. Here: blobs are hashed to N
virtual hosts; each host owns a directory and writes its blobs in
parallel (thread pool standing in for per-host writers); the coordinator
commits the manifest only after every host's writes land. Optional peer
replication keeps each blob *also* on host (h+1) % N so a single-host
loss restores without the primary (core.replication drives the failure
injection).
"""
from __future__ import annotations

import json
from concurrent.futures import ThreadPoolExecutor, wait
from pathlib import Path
from typing import Any, Dict, List, Optional

from repro.core.backends.base import (CheckpointBackend, clean_tmp_under,
                                      write_atomic)


def _host_of(name: str, n_hosts: int) -> int:
    # stable fnv-1a over the blob name
    h = 2166136261
    for ch in name.encode():
        h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
    return h % n_hosts


class ShardedBackend(CheckpointBackend):
    def __init__(self, root: str, n_hosts: int = 4, replicate: bool = False,
                 writers: int = 4, *, fsync: bool = True) -> None:
        self.root = Path(root)
        self.n_hosts = n_hosts
        self.replicate = replicate
        self.fsync = fsync
        self._pool = ThreadPoolExecutor(max_workers=writers)
        self._failed_hosts: set = set()  # failure injection for tests
        for h in range(n_hosts):
            (self.root / f"host_{h:03d}").mkdir(parents=True, exist_ok=True)
        (self.root / "coordinator").mkdir(parents=True, exist_ok=True)
        self.clean_tmp()

    # --- failure injection ----------------------------------------------

    def fail_host(self, h: int) -> None:
        self._failed_hosts.add(h)

    def heal_host(self, h: int) -> None:
        self._failed_hosts.discard(h)

    # --- blobs -----------------------------------------------------------

    def _paths(self, name: str) -> List[Path]:
        h = _host_of(name, self.n_hosts)
        paths = [self.root / f"host_{h:03d}" / name]
        if self.replicate:
            r = (h + 1) % self.n_hosts
            paths.append(self.root / f"host_{r:03d}" / f"replica_{name}")
        return paths

    def _write(self, path: Path, data: bytes) -> None:
        if path.exists():
            return
        write_atomic(path, data, self.fsync)

    def put_blob(self, name: str, data: bytes) -> None:
        futures = [self._pool.submit(self._write, p, data)
                   for p in self._paths(name)]
        done, _ = wait(futures)
        for f in done:
            f.result()

    def get_blob(self, name: str) -> bytes:
        primary_host = _host_of(name, self.n_hosts)
        errors = []
        for i, p in enumerate(self._paths(name)):
            host = primary_host if i == 0 else (primary_host + 1) % self.n_hosts
            if host in self._failed_hosts:
                errors.append(f"host {host} down")
                continue
            if p.exists():
                return p.read_bytes()
            errors.append(f"{p} missing")
        raise FileNotFoundError(f"blob {name}: {'; '.join(errors)}")

    def has_blob(self, name: str) -> bool:
        primary_host = _host_of(name, self.n_hosts)
        for i, p in enumerate(self._paths(name)):
            host = primary_host if i == 0 else (primary_host + 1) % self.n_hosts
            if host not in self._failed_hosts and p.exists():
                return True
        return False

    # --- coordinator manifests --------------------------------------------

    def _manifest_path(self, step: int) -> Path:
        return self.root / "coordinator" / f"step_{step:012d}.json"

    def commit_manifest(self, step: int, manifest: Dict[str, Any]) -> None:
        write_atomic(self._manifest_path(step),
                     json.dumps(manifest).encode(), self.fsync)

    def clean_tmp(self, max_age_seconds: float = 3600.0) -> int:
        return clean_tmp_under(self.root, max_age_seconds)

    def get_manifest(self, step: int) -> Dict[str, Any]:
        return json.loads(self._manifest_path(step).read_text())

    def list_steps(self) -> List[int]:
        return sorted(int(p.stem.split("_")[1])
                      for p in (self.root / "coordinator").glob("step_*.json"))

    def delete_step(self, step: int) -> None:
        p = self._manifest_path(step)
        if p.exists():
            p.unlink()

    def gc_blobs(self, referenced: set) -> int:
        n = 0
        for h in range(self.n_hosts):
            for p in (self.root / f"host_{h:03d}").iterdir():
                name = p.name
                if name.startswith("replica_"):
                    name = name[len("replica_"):]
                if name not in referenced:
                    p.unlink()
                    n += 1
        return n
