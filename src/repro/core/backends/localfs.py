"""LocalFSBackend — the CRIU-analogue.

One image directory; blobs under blobs/ (content-addressed, shared across
steps, which is what makes delta checkpoints cheap); manifests committed
by atomic rename — the equivalent of CRIU's complete-image-or-nothing
semantics.
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, List

from repro.core.backends.base import CheckpointBackend


class LocalFSBackend(CheckpointBackend):
    def __init__(self, root: str) -> None:
        self.root = Path(root)
        (self.root / "blobs").mkdir(parents=True, exist_ok=True)
        (self.root / "manifests").mkdir(parents=True, exist_ok=True)

    # --- blobs ---------------------------------------------------------

    def _blob_path(self, name: str) -> Path:
        # two-level fanout to keep directories small at scale
        return self.root / "blobs" / name[:2] / name

    def put_blob(self, name: str, data: bytes) -> None:
        p = self._blob_path(name)
        if p.exists():
            return  # content-addressed: identical by construction
        p.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=p.parent, prefix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.rename(tmp, p)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def get_blob(self, name: str) -> bytes:
        return self._blob_path(name).read_bytes()

    def has_blob(self, name: str) -> bool:
        return self._blob_path(name).exists()

    # --- manifests -----------------------------------------------------

    def _manifest_path(self, step: int) -> Path:
        return self.root / "manifests" / f"step_{step:012d}.json"

    def commit_manifest(self, step: int, manifest: Dict[str, Any]) -> None:
        p = self._manifest_path(step)
        fd, tmp = tempfile.mkstemp(dir=p.parent, prefix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, p)  # atomic publish

    def get_manifest(self, step: int) -> Dict[str, Any]:
        return json.loads(self._manifest_path(step).read_text())

    def list_steps(self) -> List[int]:
        out = []
        for p in (self.root / "manifests").glob("step_*.json"):
            out.append(int(p.stem.split("_")[1]))
        return sorted(out)

    def delete_step(self, step: int) -> None:
        p = self._manifest_path(step)
        if p.exists():
            p.unlink()

    def gc_blobs(self, referenced: set) -> int:
        n = 0
        for p in (self.root / "blobs").glob("*/*"):
            if p.name not in referenced:
                p.unlink()
                n += 1
        return n
