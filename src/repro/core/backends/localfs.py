"""LocalFSBackend — the CRIU-analogue.

One image directory; blobs under blobs/ (content-addressed, shared across
steps, which is what makes delta checkpoints cheap); blobs and manifests
both follow the temp-write + fsync + atomic-rename commit protocol of
``backends.base`` — the equivalent of CRIU's complete-image-or-nothing
semantics. Stale ``.tmp`` files from a crashed writer are swept on open.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List

from repro.core.backends.base import (CheckpointBackend, clean_tmp_under,
                                      write_atomic)


class LocalFSBackend(CheckpointBackend):
    def __init__(self, root: str, *, fsync: bool = True) -> None:
        self.root = Path(root)
        self.fsync = fsync
        (self.root / "blobs").mkdir(parents=True, exist_ok=True)
        (self.root / "manifests").mkdir(parents=True, exist_ok=True)
        self.clean_tmp()

    # --- blobs ---------------------------------------------------------

    def _blob_path(self, name: str) -> Path:
        # two-level fanout to keep directories small at scale
        return self.root / "blobs" / name[:2] / name

    def put_blob(self, name: str, data: bytes) -> None:
        p = self._blob_path(name)
        if p.exists():
            return  # content-addressed: identical by construction
        p.parent.mkdir(parents=True, exist_ok=True)
        write_atomic(p, data, self.fsync)

    def get_blob(self, name: str) -> bytes:
        return self._blob_path(name).read_bytes()

    def has_blob(self, name: str) -> bool:
        return self._blob_path(name).exists()

    # --- manifests -----------------------------------------------------

    def _manifest_path(self, step: int) -> Path:
        return self.root / "manifests" / f"step_{step:012d}.json"

    def commit_manifest(self, step: int, manifest: Dict[str, Any]) -> None:
        write_atomic(self._manifest_path(step),
                     json.dumps(manifest).encode(), self.fsync)

    def get_manifest(self, step: int) -> Dict[str, Any]:
        return json.loads(self._manifest_path(step).read_text())

    def list_steps(self) -> List[int]:
        out = []
        for p in (self.root / "manifests").glob("step_*.json"):
            out.append(int(p.stem.split("_")[1]))
        return sorted(out)

    def clean_tmp(self, max_age_seconds: float = 3600.0) -> int:
        return clean_tmp_under(self.root, max_age_seconds)

    def delete_step(self, step: int) -> None:
        p = self._manifest_path(step)
        if p.exists():
            p.unlink()

    def gc_blobs(self, referenced: set) -> int:
        n = 0
        for p in (self.root / "blobs").glob("*/*"):
            if p.name not in referenced:
                p.unlink()
                n += 1
        return n
