"""Core: split-state transparent checkpoint/restart (the paper's
contribution). See DESIGN.md §4."""
from repro.core.virtual_ids import (VirtualId, HandleTable, DeviceMap,
                                    HostMap, StaleHandleError)
from repro.core.oplog import (
    OpLog, MeshCreate, Compile, CacheAlloc, CacheFree, DataAdvance,
    DataReassign, ScheduleSet,
)
from repro.core.split_state import (
    UpperHalf, LowerHalf, StateEntry, register_step_fn, FUNCTION_REGISTRY,
    fill_like, flatten_with_paths, tree_from_paths,
)
from repro.core.checkpoint import CheckpointManager, RestoredState
from repro.core.async_snapshot import (
    AsyncSnapshotter, SnapshotHandle,
    materialize_manifest_chain, manifest_chain_steps,
)
from repro.core.restore import (fresh_lower_half, materialize_entry,
                                restorable_steps)
from repro.core.incarnation import Incarnation, LifecycleError
from repro.core.backends import make_backend, LocalFSBackend, ShardedBackend
from repro.core.failure import (
    HeartbeatMonitor, StragglerDetector, FailurePolicy, FailureAction,
    rebalance_shards,
)
from repro.core import replication
from repro.core.supervisor import (ClusterSupervisor, Incident,
                                   RestoreTarget, SupervisorError)
from repro.core.churn import (ChurnEngine, ChurnEvent, ChurnTrace,
                              GoodputReport, IncidentLog,
                              parse_churn_spec, read_incident_log)
