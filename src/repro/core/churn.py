"""Churn: trace-driven fleet turbulence, survived — and measured.

The fault-injection suite kills one host at one step; a real fleet sees
*continuous* churn: spot preemptions that arrive with a grace window,
Poisson host deaths, correlated rack failures, and — crucially — hosts
coming *back*. MANA-for-MPI and CRIUgpu (PAPERS.md) both frame C/R as a
fleet primitive precisely because failure there is a process, not an
event. This module makes churn a first-class, replayable input:

``ChurnTrace``   an ordered stream of ``ChurnEvent``s (``die``,
                 ``preempt`` with a grace window, ``return``, operator
                 ``drain``), serializable as JSONL so any observed or
                 generated churn pattern can be replayed bit-for-bit.
                 Seeded generators: ``poisson`` (independent exponential
                 interarrivals, a preemption fraction, deterministic
                 returns) and ``correlated_racks`` (a rack incident
                 takes every present member at the same instant).

``ChurnEngine``  drives a ``ClusterSupervisor`` through a trace on the
                 virtual clock. A ``preempt`` with sufficient grace is
                 handled *proactively* — snapshot + ``planned_move``
                 (drain onto a spare, or a deliberate shrink) before
                 the deadline, so the heartbeat-timeout path never
                 fires for it; an insufficient grace degrades to a
                 plain death at the deadline. A ``return`` re-admits
                 the host to the spare pool, and the engine *grows* the
                 world back toward its target size (``supervisor.grow``
                 — the inverse of shrink) the moment capacity is idle:
                 a recovered host rejoins as capacity, not dead weight.

``GoodputMeter`` the number that justifies all of the above: useful
                 steps ÷ attempted steps (deterministic on the virtual
                 clock) and useful steps ÷ wall-clock, with a
                 per-incident breakdown of steps lost to rollbacks.
                 ``benchmarks/goodput.py`` publishes it as
                 BENCH_goodput.json and CI soft-gates the floor.

``IncidentLog``  the supervisor's event stream as operator-readable
                 JSONL, written as it happens — a churn run stays
                 post-mortem-able even if the supervisor itself dies.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

EVENT_KINDS = ("die", "preempt", "return", "drain")


@dataclass(frozen=True)
class ChurnEvent:
    """One fleet event at virtual time ``t`` (the step clock).

    ``die``      host stops heartbeating at ``t`` — the supervisor only
                 learns of it when the silence crosses the timeout;
    ``preempt``  a preemption *notice*: the host will be reclaimed at
                 ``t + grace_s``. Enough grace → proactive snapshot +
                 drain; too little → it is just a death at the deadline;
    ``return``   the host rejoins the fleet as idle capacity (spare
                 pool; an engine configured to grow consumes it);
    ``drain``    operator-initiated planned move of a healthy host that
                 stays in the fleet afterwards (maintenance).
    """
    t: float
    kind: str
    host: int
    grace_s: float = 0.0

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(
                f"unknown churn event kind {self.kind!r} "
                f"(have {'/'.join(EVENT_KINDS)})")
        # times are floats on disk and in memory, so an int-authored
        # trace roundtrips through JSONL byte-for-byte
        object.__setattr__(self, "t", float(self.t))
        object.__setattr__(self, "grace_s", float(self.grace_s))

    def to_json(self) -> Dict[str, Any]:
        d = {"t": self.t, "kind": self.kind, "host": self.host}
        if self.kind == "preempt":
            d["grace_s"] = self.grace_s
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "ChurnEvent":
        try:
            return cls(t=float(d["t"]), kind=str(d["kind"]),
                       host=int(d["host"]),
                       grace_s=float(d.get("grace_s", 0.0)))
        except (KeyError, TypeError, ValueError) as e:
            raise ValueError(f"bad churn event {d!r}: {e}") from e


class ChurnTrace:
    """An ordered, replayable churn event stream."""

    def __init__(self, events: Sequence[ChurnEvent] = ()) -> None:
        # stable sort: same-tick events keep authoring order, which is
        # what makes a recorded trace replay bit-for-bit
        self.events: List[ChurnEvent] = sorted(events, key=lambda e: e.t)

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    # --- JSONL record / replay ------------------------------------------

    def to_jsonl(self) -> str:
        return "".join(json.dumps(e.to_json()) + "\n" for e in self.events)

    @classmethod
    def from_jsonl(cls, text: str) -> "ChurnTrace":
        events = []
        for i, line in enumerate(text.splitlines(), 1):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"churn trace line {i} is not JSON: "
                                 f"{line!r} ({e})") from e
            events.append(ChurnEvent.from_json(d))
        return cls(events)

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())

    @classmethod
    def load(cls, path) -> "ChurnTrace":
        with open(path) as f:
            return cls.from_jsonl(f.read())

    # --- seeded generators ----------------------------------------------

    @classmethod
    def poisson(cls, hosts: Sequence[int], *, rate: float, seed: int = 0,
                horizon: float = 100.0, preempt: float = 0.5,
                grace: float = 3.0, return_after: Optional[float] = 8.0,
                max_events: Optional[int] = None) -> "ChurnTrace":
        """Independent churn: fleet-wide exponential interarrivals at
        ``rate`` incidents per tick; each incident takes one present
        host — a preemption notice carrying ``grace`` ticks with
        probability ``preempt``, a hard death otherwise. A departed
        host returns ``return_after`` ticks after it left (None: gone
        for good). Same seed → identical trace, always."""
        if rate <= 0:
            raise ValueError(f"poisson rate must be > 0, got {rate}")
        rng = np.random.RandomState(seed)
        events: List[ChurnEvent] = []
        present = set(int(h) for h in hosts)
        returns: List[Tuple[float, int]] = []   # (t, host), sorted
        t = 0.0
        while max_events is None or len(events) < max_events:
            t += float(rng.exponential(1.0 / rate))
            # hosts scheduled to be back by now are victims again
            while returns and returns[0][0] <= t:
                present.add(returns.pop(0)[1])
            if t >= horizon:
                break
            if not present:
                if not returns:
                    break
                t = max(t, returns[0][0])
                continue
            victim = int(rng.choice(sorted(present)))
            present.discard(victim)
            if rng.random_sample() < preempt:
                events.append(ChurnEvent(t=t, kind="preempt", host=victim,
                                         grace_s=grace))
                gone_at = t + grace
            else:
                events.append(ChurnEvent(t=t, kind="die", host=victim))
                gone_at = t
            if return_after is not None:
                back = gone_at + return_after
                if back < horizon and (max_events is None
                                       or len(events) < max_events):
                    events.append(ChurnEvent(t=back, kind="return",
                                             host=victim))
                    returns.append((back, victim))
                    returns.sort()
        return cls(events)

    @classmethod
    def correlated_racks(cls, hosts: Sequence[int], *, rate: float,
                         rack_size: int = 2, seed: int = 0,
                         horizon: float = 100.0,
                         return_after: Optional[float] = 8.0,
                         max_events: Optional[int] = None) -> "ChurnTrace":
        """Correlated churn: hosts partition into racks of
        ``rack_size`` consecutive members; a rack incident kills every
        present member at the same instant (a top-of-rack switch, a
        power feed). The whole rack returns together ``return_after``
        ticks later."""
        if rate <= 0:
            raise ValueError(f"rack rate must be > 0, got {rate}")
        if rack_size < 1:
            raise ValueError(f"rack_size must be >= 1, got {rack_size}")
        rng = np.random.RandomState(seed)
        ordered = [int(h) for h in hosts]
        racks = [ordered[i:i + rack_size]
                 for i in range(0, len(ordered), rack_size)]
        events: List[ChurnEvent] = []
        present = set(ordered)
        returns: List[Tuple[float, int]] = []
        t = 0.0
        while max_events is None or len(events) < max_events:
            t += float(rng.exponential(1.0 / rate))
            while returns and returns[0][0] <= t:
                present.add(returns.pop(0)[1])
            if t >= horizon:
                break
            live_racks = [r for r in racks if any(h in present for h in r)]
            if not live_racks:
                if not returns:
                    break
                t = max(t, returns[0][0])
                continue
            rack = live_racks[int(rng.randint(len(live_racks)))]
            for h in rack:
                if h not in present:
                    continue
                if max_events is not None and len(events) >= max_events:
                    break
                present.discard(h)
                events.append(ChurnEvent(t=t, kind="die", host=h))
                if return_after is not None and t + return_after < horizon:
                    events.append(ChurnEvent(t=t + return_after,
                                             kind="return", host=h))
                    returns.append((t + return_after, h))
            returns.sort()
        return cls(events)

    @classmethod
    def from_spec(cls, spec: str, hosts: Sequence[int],
                  horizon: float) -> "ChurnTrace":
        kind, params = parse_churn_spec(spec)
        params.setdefault("horizon", horizon)
        if kind == "poisson":
            return cls.poisson(hosts, **params)
        return cls.correlated_racks(hosts, **params)


# spec key -> (generator kwarg, parser); shared keys first
_SPEC_KEYS = {
    "rate": ("rate", float), "seed": ("seed", int),
    "horizon": ("horizon", float), "events": ("max_events", int),
    "return": ("return_after", float),
}
_POISSON_KEYS = {**_SPEC_KEYS, "preempt": ("preempt", float),
                 "grace": ("grace", float)}
_RACK_KEYS = {**_SPEC_KEYS, "size": ("rack_size", int)}


def parse_churn_spec(spec: str) -> Tuple[str, Dict[str, Any]]:
    """``poisson:rate=0.1,seed=1[,preempt=0.5][,grace=3][,return=8]
    [,events=50][,horizon=100]`` or ``racks:rate=0.05,size=2,seed=1`` →
    (generator kind, kwargs). Unknown kinds/keys and bad values raise
    ``ValueError`` with the fix in the message."""
    kind, _, rest = spec.partition(":")
    keys = {"poisson": _POISSON_KEYS, "racks": _RACK_KEYS}.get(kind)
    if keys is None:
        raise ValueError(f"unknown churn generator {kind!r}; expected "
                         "'poisson:...' or 'racks:...'")
    params: Dict[str, Any] = {}
    for part in filter(None, rest.split(",")):
        k, eq, v = part.partition("=")
        if not eq or k not in keys:
            raise ValueError(
                f"bad churn spec parameter {part!r} for {kind}; known "
                f"keys: {', '.join(sorted(keys))}")
        name, cast = keys[k]
        try:
            params[name] = cast(v)
        except ValueError as e:
            raise ValueError(f"churn spec {k}={v!r}: {e}") from e
    if "rate" not in params:
        raise ValueError(f"churn spec {spec!r} needs rate= (incidents "
                         "per tick)")
    return kind, params


# --- goodput accounting -------------------------------------------------------


@dataclass
class GoodputReport:
    """Useful work under churn. ``goodput`` (useful ÷ attempted steps)
    is deterministic on the virtual clock — the gateable number;
    ``steps_per_s`` folds in real restore/repair wall time."""
    useful_steps: int
    attempted_steps: int
    lost_steps: int
    wall_s: float
    goodput: float
    steps_per_s: float
    incidents: List[Dict[str, Any]] = field(default_factory=list)
    proactive_preempts: int = 0
    degraded_preempts: int = 0
    grows: int = 0

    def to_json(self) -> Dict[str, Any]:
        return {
            "useful_steps": self.useful_steps,
            "attempted_steps": self.attempted_steps,
            "lost_steps": self.lost_steps,
            "wall_s": self.wall_s,
            "goodput": self.goodput,
            "steps_per_s": self.steps_per_s,
            "proactive_preempts": self.proactive_preempts,
            "degraded_preempts": self.degraded_preempts,
            "grows": self.grows,
            "incidents": self.incidents,
        }


class IncidentLog:
    """Supervisor ``event_sink`` → operator-readable JSONL, one line
    per event, flushed as it happens (the log survives the process)."""

    def __init__(self, path) -> None:
        self.path = str(path)
        self._f = open(self.path, "a")

    def __call__(self, t: float, kind: str, detail: Dict[str, Any]) -> None:
        self._f.write(json.dumps({"t": t, "event": kind, **detail},
                                 default=str, sort_keys=True) + "\n")
        self._f.flush()

    def close(self) -> None:
        self._f.close()


def read_incident_log(path) -> List[Dict[str, Any]]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# --- the engine ---------------------------------------------------------------


class ChurnEngine:
    """Drives a ``ClusterSupervisor`` through a ``ChurnTrace`` on the
    virtual clock: one ``tick(step)`` per runner step fires due events,
    fans heartbeats out (silent hosts excluded), polls the supervisor,
    and grows the world back toward ``target_world`` whenever idle
    capacity exists. Construct first, hand ``engine.clock`` to the
    supervisor, then ``attach`` it.

    ``snapshot``  zero-arg hook taking a *blocking* snapshot of the
                  current runner (``lambda: sess.snapshot(block=True)``)
                  — the proactive half of preemption survival, and what
                  makes a grow lose zero steps. Without it, preemptions
                  still drain but fall back to the latest committed
                  step, and grows roll back like a shrink would.
    ``min_grace`` ticks of grace below which a preemption notice is not
                  actionable — the host simply dies at its deadline
                  (the heartbeat-timeout path, counted as degraded).
    ``grow``      False freezes the world at whatever churn leaves
                  (shrink-only fleets).
    ``target_world`` world size grows aim for; default: the attached
                  supervisor's initial world size.
    """

    def __init__(self, trace: ChurnTrace, *,
                 snapshot: Optional[Callable[[], Any]] = None,
                 min_grace: float = 1.0,
                 grow: bool = True,
                 target_world: Optional[int] = None) -> None:
        self.trace = trace
        self.pending: List[ChurnEvent] = list(trace.events)
        self.snapshot = snapshot
        self.min_grace = min_grace
        self.grow_enabled = grow
        self.target_world = target_world
        self.sup: Any = None
        self._t = 0.0
        self.silent: set = set()        # in-world hosts gone quiet
        self.gone: set = set()          # hosts that left the fleet
        # accounting
        self._ticks = 0
        self._start: Optional[int] = None
        self._high = 0
        self._wall0: Optional[float] = None
        self.incident_rows: List[Dict[str, Any]] = []
        self.proactive_preempts = 0
        self.degraded_preempts = 0
        self.grows = 0

    def clock(self) -> float:
        return self._t

    def attach(self, sup) -> "ChurnEngine":
        self.sup = sup
        if self.target_world is None:
            self.target_world = len(sup.world)
        return self

    # --- the tick -------------------------------------------------------

    def tick(self, step: int) -> List[Any]:
        """Advance the world one step: fire due events, heartbeat the
        live hosts, poll, grow. Returns every executed decision's
        ``RestoreTarget`` (empty list on a quiet tick)."""
        self._t += 1.0
        self._ticks += 1
        if self._wall0 is None:
            self._wall0 = time.monotonic()
        if self._start is None:
            self._start = int(step) - 1
        self._high = max(self._high, int(step))
        executed: List[Any] = []
        self._fire_due(step, executed)
        for h in self.sup.world:
            if h not in self.silent:
                self.sup.beat(h, step)
        self._execute(step, executed, self.sup.poll)
        self._maybe_grow(step, executed)
        return executed

    def unfired_events(self) -> List[ChurnEvent]:
        return list(self.pending)

    def unresolved_hosts(self) -> List[int]:
        """Silent hosts whose death never produced an incident."""
        return sorted(self.silent)

    # --- event handling -------------------------------------------------

    def _fire_due(self, step: int, executed: List[Any]) -> None:
        due = [e for e in self.pending if step >= e.t]
        self.pending = [e for e in self.pending if step < e.t]
        for ev in due:
            if ev.kind == "die":
                self._on_die(ev)
            elif ev.kind == "preempt":
                self._on_preempt(ev, executed)
            elif ev.kind == "return":
                self._on_return(ev)
            elif ev.kind == "drain":
                self._on_drain(ev, step, executed)

    def _on_die(self, ev: ChurnEvent) -> None:
        sup = self.sup
        if ev.host in sup.world:
            self.silent.add(ev.host)
            self.gone.add(ev.host)
        elif ev.host in sup.policy.spares:
            # an idle spare dying costs nothing now, but it must not be
            # handed a workload later
            sup.policy.spares.remove(ev.host)
            self.gone.add(ev.host)
            sup._event("spare_lost", host=ev.host)

    def _on_preempt(self, ev: ChurnEvent, executed: List[Any]) -> None:
        sup = self.sup
        if ev.host not in sup.world:
            # a spare being reclaimed: it just leaves the pool
            if ev.host in sup.policy.spares:
                sup.policy.spares.remove(ev.host)
                self.gone.add(ev.host)
                sup._event("spare_preempted", host=ev.host)
            return
        if ev.grace_s >= self.min_grace:
            # enough grace to act: snapshot proactively, then drain the
            # host BEFORE the deadline — onto a spare if one is idle
            # (hot-spare-class blackout), else a deliberate shrink. The
            # heartbeat-timeout path never sees this host.
            sup._event("preempt_notice", host=ev.host, grace_s=ev.grace_s,
                       deadline=ev.t + ev.grace_s)
            if self.snapshot is not None:
                self.snapshot()
            target = self._execute(self._high, executed,
                                   sup.planned_move, ev.host)
            # planned_move returns the drained host to the spare pool
            # (it is healthy) — but a preempted host is being RECLAIMED:
            # it must not be handed a later workload
            if ev.host in sup.policy.spares:
                sup.policy.spares.remove(ev.host)
            self.gone.add(ev.host)
            self.proactive_preempts += 1
            assert target is not None
        else:
            # notice too short to act on: the host is simply gone at
            # the deadline, detected like any other death
            sup._event("preempt_degraded", host=ev.host,
                       grace_s=ev.grace_s)
            self.degraded_preempts += 1
            self.pending.append(ChurnEvent(t=ev.t + ev.grace_s,
                                           kind="die", host=ev.host))
            self.pending.sort(key=lambda e: e.t)

    def _on_return(self, ev: ChurnEvent) -> None:
        sup = self.sup
        self.gone.discard(ev.host)
        self.silent.discard(ev.host)   # a flaky host resuming heartbeats
        if ev.host not in sup.world and ev.host not in sup.policy.spares:
            sup.policy.spares.append(ev.host)
            sup._event("host_return", host=ev.host,
                       spares=list(sup.policy.spares))

    def _on_drain(self, ev: ChurnEvent, step: int,
                  executed: List[Any]) -> None:
        sup = self.sup
        if ev.host not in sup.world:
            sup._event("drain_skipped", host=ev.host,
                       reason="not in world")
            return
        if self.snapshot is not None:
            self.snapshot()
        self._execute(step, executed, sup.planned_move, ev.host)
        # unlike a preemption, a drained host stays in the fleet:
        # planned_move already returned it to the spare pool

    # --- grow -----------------------------------------------------------

    def _maybe_grow(self, step: int, executed: List[Any]) -> None:
        if not self.grow_enabled or self.sup is None:
            return
        while len(self.sup.world) < (self.target_world or 0) \
                and self.sup.policy.spares:
            host = self.sup.policy.spares[0]
            if self.snapshot is not None:
                self.snapshot()   # grow restores from the latest step;
                # a fresh snapshot makes that THIS step — zero rollback
            target = self._execute(step, executed, self.sup.grow, host)
            self.grows += 1
            assert target is not None

    # --- accounting -----------------------------------------------------

    def _runner_step(self, fallback: int) -> int:
        fn = getattr(getattr(self.sup, "runner", None),
                     "checkpoint_step", None)
        return int(fn()) if callable(fn) else int(fallback)

    def _execute(self, step: int, executed: List[Any],
                 fn: Callable, *args) -> Any:
        """Run one decision source (poll / planned_move / grow) with
        per-incident rollback accounting."""
        n0 = len(self.sup.incidents)
        target = fn(*args)
        if target is not None:
            executed.append(target)
            for d in getattr(target, "dead", ()):   # resolved, whichever
                self.silent.discard(d)              # policy ran
        after = self._runner_step(step)
        for inc in self.sup.incidents[n0:]:
            self.incident_rows.append({
                "t": self._t, "action": inc.action,
                "dead": list(inc.dead), "step": inc.step,
                "lost_steps": max(0, int(step) - after),
                "wall_s": inc.wall_s})
        return target

    def report(self) -> GoodputReport:
        useful = self._high - (self._start or 0) if self._ticks else 0
        wall = (time.monotonic() - self._wall0) if self._wall0 else 0.0
        return GoodputReport(
            useful_steps=useful,
            attempted_steps=self._ticks,
            lost_steps=sum(r["lost_steps"] for r in self.incident_rows),
            wall_s=wall,
            goodput=useful / self._ticks if self._ticks else 0.0,
            steps_per_s=useful / wall if wall > 0 else 0.0,
            incidents=list(self.incident_rows),
            proactive_preempts=self.proactive_preempts,
            degraded_preempts=self.degraded_preempts,
            grows=self.grows)
