"""Streaming restore: fetch, decode and page in a checkpoint as a
pipeline instead of a barrier.

Eager restore (``materialize_manifest_chain``) reads every blob, decodes
every leaf, and only then lets replay and rebinding start — at
production model sizes that wall-clock is the MTTR floor (BENCH_mttr:
restart ~9s vs hot-spare ~0.05s). CRIU's lazy-pages restore and MANA's
transport-agnostic blob sourcing are the precedents this module applies
to the delta-chain format:

fetch   every blob the target step's chain references streams in from
        *all* of its live sources concurrently — the owning host and
        its (h+1)%N replica peer on a sharded store
        (``replication.blob_sources``), the local cache tier and the
        remote store on a ``cached:`` front. A slow source is hedged:
        after ``hedge_s`` without a byte, the next copy is raced and
        the first success wins.
decode  a per-leaf dependency counter (sized by ``delta.
        leaf_blob_names`` over the leaf's XOR run) releases each leaf's
        chain decode the moment its *own* blobs land — decode overlaps
        fetch, and the decode code path is byte-for-byte the eager
        one (``_decode_chain_leaf``), which is what makes streaming
        restore bit-identical by construction.
page-in leaves are split into priority tiers by entry kind: hot
        entries (session/scheduler state, params) are fetched first and
        ``hot_result`` returns as soon as they are decoded; cold
        entries (optimizer moments, the serving KV cache) become
        ``LazyLeaves`` placeholders that keep streaming in the
        background and block only the first toucher — a touch before
        arrival is a *lazy fault*, which promotes the leaf's remaining
        blobs to the front of the fetch queue.

The result is that a restored serving engine admits requests while the
bulk of the payload is still in flight; ``core.incarnation`` folds the
per-phase counters (bytes/s per source, decode overlap, faults served)
into ``Incarnation.timings``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor
from concurrent.futures import wait as futures_wait
from typing import Any, Dict, Iterator, List, Mapping, Optional, Tuple

import numpy as np

from repro.api.errors import RestoreError
from repro.core import delta as deltamod
from repro.core.async_snapshot import (_decode_chain_leaf,
                                       leaf_chain_start,
                                       manifest_chain_steps)
from repro.core.backends.base import CheckpointBackend

# entry kinds that default to the cold (lazy) tier: optimizer moments
# are untouched until the first optimizer step after resume, and the
# serving KV cache is consumed only at the first decode step — both can
# stream in behind admission / replay / hot rebinding
DEFAULT_LAZY_KINDS = ("opt_state", "cache")

# hedge a multi-source blob read after this long without a result
DEFAULT_HEDGE_S = 0.05

_LeafKey = Tuple[str, str]           # (entry name, leaf path)


class _BlobView:
    """``get_blob`` view over the fetcher's in-memory buffers, handed to
    the (unchanged) eager decode path — identical bytes in, identical
    arrays out."""

    def __init__(self, blobs: Dict[str, bytes]) -> None:
        self._blobs = blobs

    def get_blob(self, name: str) -> bytes:
        return self._blobs[name]


class LazyLeaves(Mapping):
    """One entry's leaf-path -> array map, resolving per leaf.

    Transparent to every consumer of ``RestoredState.entries`` values
    (``fill_like``, ``tree_from_paths``, ``restore_scalar`` only need
    Mapping semantics); a lookup of a leaf still in flight blocks that
    caller — and only that caller — after promoting the leaf to the
    front of the fetch queue (a *lazy fault*). ``wait()`` resolves the
    whole entry at once (bulk consumers like the serving engine's
    deferred cache merge)."""

    def __init__(self, name: str, paths: List[str],
                 materializer: "StreamingMaterializer") -> None:
        self._name = name
        self._paths = list(paths)
        self._m = materializer

    def __getitem__(self, path: str) -> np.ndarray:
        if path not in self._paths:
            raise KeyError(path)
        return self._m.leaf_value(self._name, path)

    def __iter__(self) -> Iterator[str]:
        return iter(self._paths)

    def __len__(self) -> int:
        return len(self._paths)

    def __contains__(self, path: object) -> bool:
        return path in self._paths

    def ready(self, path: str) -> bool:
        return self._m.leaf_ready(self._name, path)

    def wait(self) -> None:
        """Block until every leaf of this entry is decoded."""
        self._m.wait_entry(self._name)

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        done = sum(1 for p in self._paths if self.ready(p))
        return (f"LazyLeaves({self._name!r}, {done}/{len(self._paths)} "
                "decoded)")


class StreamingMaterializer:
    """One streaming materialization of one checkpoint step.

    Single-use, thread-owning: ``start()`` launches the fetch workers
    and decode pool, ``hot_result()`` blocks for the hot tier only, and
    the object shuts its pools down by itself once the last leaf
    decodes (or ``wait_all`` / an error drains it)."""

    def __init__(self, backend: CheckpointBackend, step: int, *,
                 skip_entries=(), lazy_kinds=DEFAULT_LAZY_KINDS,
                 fetch_workers: Optional[int] = None,
                 decode_workers: Optional[int] = None,
                 hedge_s: float = DEFAULT_HEDGE_S) -> None:
        import os
        self.backend = backend
        self.step = step
        self.hedge_s = hedge_s
        self.lazy_kinds = frozenset(lazy_kinds or ())
        cpus = os.cpu_count() or 1
        self.fetch_workers = fetch_workers or min(8, cpus)
        self.decode_workers = decode_workers or min(8, cpus)

        self.manifests = [backend.get_manifest(s)
                          for s in manifest_chain_steps(backend, step)]
        self.final = self.manifests[-1]
        skip = self._skip = set(skip_entries)

        self._lock = threading.Lock()
        self._futures: Dict[_LeafKey, Future] = {}
        self._hot_keys: List[_LeafKey] = []
        self._cold_keys: List[_LeafKey] = []
        # blob name -> bytes (held only while some leaf still needs it)
        self._blobs: Dict[str, bytes] = {}
        self._blob_refs: Dict[str, int] = {}
        self._blob_waiters: Dict[str, List[_LeafKey]] = {}
        self._leaf_pending: Dict[_LeafKey, set] = {}
        self._leaf_blobs: Dict[_LeafKey, List[str]] = {}
        self._view = _BlobView(self._blobs)

        for name, entry in self.final["entries"].items():
            if name in skip:
                continue
            cold = entry.get("kind") in self.lazy_kinds
            for path in entry["leaves"]:
                key = (name, path)
                self._futures[key] = Future()
                (self._cold_keys if cold else self._hot_keys).append(key)
                blobs: List[str] = []
                # THE run-start walk of the eager decoder — shared, so
                # the planner's blob set is the decode's blob set by
                # construction (an entry or leaf first introduced
                # mid-chain bounds the walk instead of KeyError-ing)
                i = leaf_chain_start(self.manifests, name, path)
                for m in self.manifests[i:]:
                    blobs.extend(deltamod.leaf_blob_names(
                        m["entries"][name]["leaves"][path]))
                uniq = list(dict.fromkeys(blobs))
                self._leaf_blobs[key] = uniq
                self._leaf_pending[key] = set(uniq)
                for b in uniq:
                    self._blob_refs[b] = self._blob_refs.get(b, 0) + 1
                    self._blob_waiters.setdefault(b, []).append(key)

        # fetch order: hot leaves' blobs first, then cold — dedup keeps
        # a blob shared across tiers at its earliest position
        order: List[str] = []
        for key in self._hot_keys + self._cold_keys:
            order.extend(self._leaf_blobs[key])
        self._queue: deque = deque(dict.fromkeys(order))
        self._queued: set = set(self._queue)
        self._hot_set = set(self._hot_keys)
        self._in_flight: set = set()
        self._leaves_left = len(self._futures)
        self._hot_left = len(self._hot_keys)
        self._hot_done = threading.Event()
        if self._hot_left == 0:
            self._hot_done.set()

        # observability
        self.stats: Dict[str, Any] = {
            "hot_leaves": len(self._hot_keys),
            "cold_leaves": len(self._cold_keys),
            "blobs": len(self._queued),
            "source_bytes": {},
            "hedges": 0,
            "hedge_wins": 0,
            "lazy_faults": 0,
        }
        self._t0: Optional[float] = None
        self._fetch_end: Optional[float] = None
        self._hot_ready_s: Optional[float] = None
        self._decode_busy_s = 0.0
        self._decode_overlap_s = 0.0
        self._started = False
        self._closed = False
        self._fetch_pool: Optional[ThreadPoolExecutor] = None
        self._hedge_pool: Optional[ThreadPoolExecutor] = None
        self._decode_pool: Optional[ThreadPoolExecutor] = None

    # --- lifecycle ------------------------------------------------------

    def start(self) -> "StreamingMaterializer":
        assert not self._started, "start() already ran"
        self._started = True
        self._t0 = time.monotonic()
        if not self._queue:
            self._fetch_end = self._t0
        self._decode_pool = ThreadPoolExecutor(
            max_workers=self.decode_workers,
            thread_name_prefix="stream-decode")
        # zero-blob leaves (all-zero tensors, empty arrays) decode now
        for key, pending in list(self._leaf_pending.items()):
            if not pending:
                self._decode_pool.submit(self._decode_leaf, key)
        if self._queue:
            self._fetch_pool = ThreadPoolExecutor(
                max_workers=self.fetch_workers,
                thread_name_prefix="stream-fetch")
            # hedge slots: every fetch worker may hold one primary and
            # one hedge read in flight
            self._hedge_pool = ThreadPoolExecutor(
                max_workers=max(4, 2 * self.fetch_workers),
                thread_name_prefix="stream-hedge")
            for _ in range(self.fetch_workers):
                self._fetch_pool.submit(self._fetch_loop)
        return self

    def _shutdown_pools(self) -> None:
        # called from a decode worker after the last leaf resolves, so
        # nothing may join its own pool
        if self._closed:
            return
        self._closed = True
        for pool in (self._fetch_pool, self._hedge_pool,
                     self._decode_pool):
            if pool is not None:
                pool.shutdown(wait=False)

    # --- fetch side -----------------------------------------------------

    def _next_blob(self) -> Optional[str]:
        with self._lock:
            if not self._queue:
                return None
            name = self._queue.popleft()
            self._queued.discard(name)
            self._in_flight.add(name)
            return name

    def _fetch_loop(self) -> None:
        while True:
            name = self._next_blob()
            if name is None:
                return
            try:
                label, data = self._fetch_one(name)
            except Exception as e:  # all sources failed
                self._blob_failed(name, e)
                continue
            self._blob_done(name, label, data)

    def _fetch_one(self, name: str) -> Tuple[str, bytes]:
        from repro.core.replication import blob_sources
        sources = blob_sources(self.backend, name)
        if len(sources) == 1:
            label, read = sources[0]
            return label, read()
        futs: Dict[Future, str] = {}
        idx = 0

        def submit_next() -> bool:
            nonlocal idx
            if idx >= len(sources) or self._closed:
                return False
            label, read = sources[idx]
            idx += 1
            f = self._hedge_pool.submit(read)
            futs[f] = label
            return True

        submit_next()
        hedged = False
        errors: List[str] = []
        while futs:
            can_hedge = idx < len(sources)
            done, _ = futures_wait(
                list(futs), timeout=self.hedge_s if can_hedge else None,
                return_when=FIRST_COMPLETED)
            if not done:
                # the preferred copy is slow: race the next one
                hedged = True
                with self._lock:
                    self.stats["hedges"] += 1
                submit_next()
                continue
            for f in done:
                label = futs.pop(f)
                try:
                    data = f.result()
                except Exception as e:
                    errors.append(f"{label}: {e}")
                    continue
                if hedged and label != sources[0][0]:
                    with self._lock:
                        self.stats["hedge_wins"] += 1
                return label, data
            if not futs and not submit_next():
                break
        raise FileNotFoundError(
            f"blob {name}: no source served it ({'; '.join(errors)})")

    def _blob_done(self, name: str, label: str, data: bytes) -> None:
        ready: List[_LeafKey] = []
        with self._lock:
            # a blob whose every owning leaf already resolved (e.g. the
            # leaves failed while this read was in flight) has no one
            # left to decode it: keeping the bytes would leak them until
            # the materializer dies
            if self._blob_refs.get(name, 0) > 0:
                self._blobs[name] = data
            self._in_flight.discard(name)
            sb = self.stats["source_bytes"]
            sb[label] = sb.get(label, 0) + len(data)
            for key in self._blob_waiters.get(name, ()):
                pending = self._leaf_pending.get(key)
                if pending is None:
                    continue
                pending.discard(name)
                if not pending:
                    ready.append(key)
            if not self._queue and not self._in_flight \
                    and self._fetch_end is None:
                self._fetch_end = time.monotonic()
        for key in ready:
            self._decode_pool.submit(self._decode_leaf, key)

    def _blob_failed(self, name: str, exc: Exception) -> None:
        err = RestoreError(f"streaming restore: {exc}")
        err.__cause__ = exc
        with self._lock:
            self._in_flight.discard(name)
            keys = [k for k in self._blob_waiters.get(name, ())
                    if self._leaf_pending.pop(k, None) is not None]
            if not self._queue and not self._in_flight \
                    and self._fetch_end is None:
                self._fetch_end = time.monotonic()
        for key in keys:
            self._leaf_failed(key, err)

    # --- decode side ----------------------------------------------------

    def _decode_leaf(self, key: _LeafKey) -> None:
        fut = self._futures[key]
        if fut.done():
            return
        name, path = key
        t0 = time.monotonic()
        try:
            val = _decode_chain_leaf(self.manifests, self._view, name,
                                     path)
        except Exception as e:
            self._leaf_failed(key, e)
            return
        t1 = time.monotonic()
        fut.set_result(val)
        self._leaf_resolved(key, busy=t1 - t0, t0=t0, t1=t1)

    def _leaf_resolved(self, key: _LeafKey, *, busy: float = 0.0,
                       t0: float = 0.0, t1: float = 0.0) -> None:
        hot = False
        with self._lock:
            self._decode_busy_s += busy
            if busy:
                # decode time spent while blobs were still arriving —
                # the pipeline's whole point, reported as overlap
                fe = self._fetch_end
                if fe is None:
                    self._decode_overlap_s += t1 - t0
                elif t0 < fe:
                    self._decode_overlap_s += fe - t0
            for b in self._leaf_blobs.get(key, ()):
                n = self._blob_refs.get(b, 0) - 1
                if n <= 0:
                    self._blob_refs.pop(b, None)
                    self._blobs.pop(b, None)
                    self._blob_waiters.pop(b, None)
                    # ownerless and never fetched (this leaf failed
                    # before its blobs landed): drop the queue entry so
                    # the fetch workers don't read bytes nobody wants
                    if b in self._queued:
                        self._queue.remove(b)
                        self._queued.discard(b)
                else:
                    self._blob_refs[b] = n
            if not self._queue and not self._in_flight \
                    and self._fetch_end is None:
                self._fetch_end = time.monotonic()
            self._leaf_pending.pop(key, None)
            self._leaves_left -= 1
            done = self._leaves_left == 0
            if key in self._hot_set:
                self._hot_left -= 1
                if self._hot_left == 0 and self._hot_ready_s is None:
                    # first writer wins; hot_result()'s fallback (for a
                    # hot tier that was empty at plan time) takes the
                    # same lock and honours the same None check
                    self._hot_ready_s = time.monotonic() - self._t0
                    hot = True
        if hot:
            self._hot_done.set()
        if done:
            self._shutdown_pools()

    def _leaf_failed(self, key: _LeafKey, exc: Exception) -> None:
        fut = self._futures[key]
        if not fut.done():
            fut.set_exception(exc)
        self._leaf_resolved(key)

    # --- page-in surface ------------------------------------------------

    def _promote(self, key: _LeafKey) -> None:
        """Move a faulted leaf's not-yet-fetched blobs to the front of
        the queue so the toucher waits on the shortest possible path."""
        with self._lock:
            pending = self._leaf_pending.get(key)
            if not pending:
                return
            head = [b for b in self._queue if b in pending]
            if not head:
                return
            for b in head:
                self._queue.remove(b)
            self._queue.extendleft(reversed(head))

    def leaf_ready(self, name: str, path: str) -> bool:
        return self._futures[(name, path)].done()

    def leaf_value(self, name: str, path: str) -> np.ndarray:
        fut = self._futures[(name, path)]
        if not fut.done():
            with self._lock:
                self.stats["lazy_faults"] += 1
            self._promote((name, path))
        return fut.result()

    def wait_entry(self, name: str) -> None:
        keys = [k for k in self._futures if k[0] == name]
        for k in keys:
            self._promote(k)
        for k in keys:
            self._futures[k].result()

    def wait_hot(self) -> None:
        self._hot_done.wait()
        for k in self._hot_keys:
            self._futures[k].result()   # surface a hot-tier failure

    def wait_all(self) -> None:
        for fut in self._futures.values():
            fut.result()

    @property
    def complete(self) -> bool:
        with self._lock:
            return self._leaves_left == 0

    # --- results --------------------------------------------------------

    def hot_result(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """(manifest, entries) as soon as the hot tier is decoded: hot
        entries as plain dicts, cold entries as ``LazyLeaves`` still
        streaming behind them. Same key set as the eager materializer,
        including leafless entries (e.g. an empty request queue)."""
        self.wait_hot()
        with self._lock:
            if self._hot_ready_s is None:   # empty hot tier: first
                self._hot_ready_s = time.monotonic() - self._t0
        entries: Dict[str, Any] = {}
        for name, path in self._hot_keys:
            entries.setdefault(name, {})[path] = \
                self._futures[(name, path)].result()
        cold_paths: Dict[str, List[str]] = {}
        for name, path in self._cold_keys:
            cold_paths.setdefault(name, []).append(path)
        for name, paths in cold_paths.items():
            entries[name] = LazyLeaves(name, paths, self)
        # leafless entries (e.g. an empty request queue) stay present,
        # exactly as the eager materializer keeps them
        for name in self.final["entries"]:
            if name not in self._skip:
                entries.setdefault(name, {})
        return self.final, entries

    def timings(self) -> Dict[str, Any]:
        """Per-phase restore counters for ``Incarnation.timings``."""
        now = time.monotonic()
        t0 = self._t0 or now
        fetch_s = (self._fetch_end or now) - t0
        with self._lock:
            src = dict(self.stats["source_bytes"])
            busy = self._decode_busy_s
            overlap = self._decode_overlap_s
            out: Dict[str, Any] = {
                "fetch_s": fetch_s,
                "decode_busy_s": busy,
                "decode_overlap_pct":
                    100.0 * overlap / busy if busy > 0 else 0.0,
                "lazy_faults": self.stats["lazy_faults"],
                "hedges": self.stats["hedges"],
                "hedge_wins": self.stats["hedge_wins"],
                "hot_leaves": self.stats["hot_leaves"],
                "cold_leaves": self.stats["cold_leaves"],
            }
            if self._hot_ready_s is not None:
                out["hot_ready_s"] = self._hot_ready_s
        out["fetch_bytes_per_source"] = src
        if fetch_s > 0:
            out["fetch_mb_s_per_source"] = {
                k: v / fetch_s / 1e6 for k, v in src.items()}
        return out


def materialize_streaming(
    backend: CheckpointBackend, step: int, *,
    workers: Optional[int] = None, skip_entries=(),
    lazy_kinds=DEFAULT_LAZY_KINDS, hedge_s: float = DEFAULT_HEDGE_S,
) -> Tuple[Dict[str, Any], Dict[str, Any], StreamingMaterializer]:
    """Streaming counterpart of ``materialize_manifest_chain``: returns
    as soon as the hot tier is decoded, with cold entries as
    ``LazyLeaves`` still streaming, plus the materializer for stats and
    explicit waits. Bit-identical to the eager path — the decode code is
    the same function over the same bytes; only the schedule differs."""
    sm = StreamingMaterializer(
        backend, step, skip_entries=skip_entries, lazy_kinds=lazy_kinds,
        fetch_workers=workers, decode_workers=workers, hedge_s=hedge_s)
    sm.start()
    manifest, entries = sm.hot_result()
    return manifest, entries, sm
