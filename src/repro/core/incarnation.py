"""Incarnation: the restore lifecycle as a first-class object.

The paper's restart (§II-III) is a fixed sequence — materialize the
checkpoint payload, load a fresh copy of the driver, replay the logged
calls, rebind the application's handles — and its headline demo (§IV)
is bringing back a *live* application with the user's session intact.
Before this module, that sequence lived as free functions every caller
hand-assembled; now one object owns it, in order, with timings:

    inc   = Incarnation(manager, step=..., mesh_factory=...)
    state = inc.materialize()     # 0: delta chain -> host arrays
                                  #    (decoded across a worker pool)
    lower = inc.build_lower()     # 1-2: fresh LowerHalf, new_incarnation
                                  #      handle generation, op-log replay
    tree  = inc.bind(name, template, plan=p, logical=l)   # 3: upper half
    n     = inc.scalar(name)      #    rebinds with logical-axes shardings

Phases are enforced in order (bind before materialize is a bug, not a
silent None), each phase is timed (``inc.timings``), and both the
trainer (`train/loop.py`) and the serving engine (`serving/engine.py`)
resume through this object — there is exactly one restart protocol.

Elastic restores hand the incarnation a *replacement* for a logged
resource's geometry: ``mesh_factory`` swaps the mesh topology (the
multi-device case), and ``rewrite_op`` transforms individual ops before
replay — the serving engine uses it to re-slot a continuous-batching
checkpoint onto a different slot count (CacheAlloc batch N -> M,
decode Compile recompiled at the new batch) while keeping every virtual
id stable across the rewrite.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

from repro.api.errors import CheckpointError
from repro.core.checkpoint import CheckpointManager, RestoredState
from repro.core.oplog import CacheAlloc, Compile, Op, OpLog
from repro.core.split_state import LowerHalf
from repro.core.virtual_ids import VirtualId


class LifecycleError(CheckpointError, RuntimeError):
    """An Incarnation phase was invoked out of order (or twice)."""


class Incarnation:
    """One restart of a checkpointed job. Single-use: a second restore
    constructs a second Incarnation."""

    def __init__(self, manager: CheckpointManager,
                 step: Optional[int] = None,
                 mesh_factory: Optional[Callable] = None,
                 rewrite_op: Optional[Callable[[Op], Op]] = None,
                 decode_workers: Optional[int] = None,
                 skip_entries: Optional[List[str]] = None,
                 streaming: bool = False,
                 lazy_kinds=None) -> None:
        self.manager = manager
        self.step = step
        self.mesh_factory = mesh_factory
        self.rewrite_op = rewrite_op
        self.decode_workers = decode_workers
        # entries the caller will rebuild rather than rebind (e.g. the
        # KV cache on a re-slot restore) — skipped at decode, so their
        # chains never inflate materialize latency
        self.skip_entries = tuple(skip_entries or ())
        # streaming: materialize() returns at hot-tier-decoded instead
        # of everything-decoded; cold entries (lazy_kinds) page in on
        # first touch while replay/rebind proceed (core.streaming)
        self.streaming = streaming
        self.lazy_kinds = lazy_kinds
        self.streamer = None
        self.restored: Optional[RestoredState] = None
        self.lower: Optional[LowerHalf] = None
        self.released = False
        self.timings: Dict[str, Any] = {}

    # --- phase 0: materialize the payload ------------------------------

    def materialize(self) -> RestoredState:
        """Walk the manifest's ``base_step`` delta chain back to its full
        base and decode every leaf forward, fanned out across a decode
        worker pool. Dense links (formats 1-2) XOR-apply whole buffers;
        sparse links (format 3, dirty-chunk capture) patch only the
        chunks the link recorded — so restoring a long chain of sparse
        snapshots costs the base decode plus the sum of the deltas, not
        chain length x state size. Unknown newer manifest formats are
        rejected up front rather than misread. The result is plain host
        arrays + the pruned op-log — everything restore needs, on any
        topology.

        With ``streaming=True`` this returns once the *hot* tier is
        decoded (``materialize_s`` then measures time-to-hot, the
        latency the resumed app actually waits); the cold tier keeps
        streaming behind replay and rebind, and ``stream_timings()``
        reports the per-phase fetch/decode/fault counters."""
        if self.restored is not None:
            raise LifecycleError("materialize() already ran")
        t0 = time.monotonic()
        kw: Dict[str, Any] = {}
        if self.streaming:
            kw["streaming"] = True
            if self.lazy_kinds is not None:
                kw["lazy_kinds"] = self.lazy_kinds
        self.restored = self.manager.restore(self.step,
                                             workers=self.decode_workers,
                                             skip_entries=self.skip_entries,
                                             **kw)
        self.streamer = self.restored.streamer
        self.step = self.restored.step
        self.timings["materialize_s"] = time.monotonic() - t0
        return self.restored

    # --- phases 1-2: fresh lower half + replay -------------------------

    def build_lower(self) -> LowerHalf:
        """Construct a fresh LowerHalf (the 'load a fresh copy of the
        driver' moment) and replay the pruned op-log through it:
        recompiles step functions, re-allocates caches, fast-forwards
        data assignment — rebinding the checkpoint's virtual ids to this
        incarnation's real objects.

        ``mesh_factory`` substitutes the topology at the MeshCreate op;
        ``rewrite_op`` transforms each op before replay (elastic
        re-slotting). The replayed (possibly rewritten) ops become the
        new incarnation's log, so a later checkpoint of this process
        carries a self-consistent history forward."""
        if self.restored is None:
            raise LifecycleError("build_lower() before materialize()")
        if self.lower is not None:
            raise LifecycleError("build_lower() already ran")
        t0 = time.monotonic()
        lower = LowerHalf(mesh_factory=self.mesh_factory)
        ops: List[Op] = []
        for op in self.restored.oplog.ops:
            if self.rewrite_op is not None:
                op = self.rewrite_op(op)
            lower.apply_op(op)
            ops.append(op)
        lower.oplog = OpLog(ops)
        self.lower = lower
        self.timings["replay_s"] = time.monotonic() - t0
        return lower

    # --- phase 3: upper-half rebinding ---------------------------------

    def bind(self, name: str, template, plan=None, logical=None):
        """Rematerialize one upper-half entry onto this incarnation's
        mesh: path-matched host leaves -> device arrays, sharded by the
        NamedSharding derived from each leaf's *logical* axes and the
        new mesh's plan (elastic: the payload references no devices)."""
        from repro.core.restore import materialize_entry
        if self.lower is None:
            raise LifecycleError("bind() before build_lower()")
        if self.released:
            raise LifecycleError("payload released; bind() must run "
                                 "before release()")
        t0 = time.monotonic()
        mesh = self.mesh_or_none()
        out = materialize_entry(self.restored, name, template, plan, mesh,
                                logical)
        self.timings["rebind_s"] = \
            self.timings.get("rebind_s", 0.0) + time.monotonic() - t0
        return out

    def scalar(self, name: str):
        """Plain scalar/int-tree entries (step counters, cursors)."""
        from repro.core.restore import restore_scalar
        if self.restored is None:
            raise LifecycleError("scalar() before materialize()")
        if self.released:
            raise LifecycleError("payload released; scalar() must run "
                                 "before release()")
        return restore_scalar(self.restored, name)

    def entry_paths(self, name: str) -> Dict[str, Any]:
        """Raw path->host-array map for one entry (callers that rebuild
        structure themselves, e.g. the serving scheduler)."""
        if self.restored is None:
            raise LifecycleError("entry_paths() before materialize()")
        if self.released:
            raise LifecycleError("payload released; entry_paths() must "
                                 "run before release()")
        return self.restored.entries[name]

    def release(self) -> None:
        """Drop the host-side payload once every entry is rebound. The
        decoded arrays otherwise stay referenced for the life of the
        resumed process — the full checkpoint size held in host RAM
        just to keep timings readable. Manifest, job metadata, timings
        and the lower half survive."""
        if self.restored is not None:
            self.restored.entries = {}
        self.released = True

    def stream_timings(self) -> Optional[Dict[str, Any]]:
        """Per-phase streaming-restore counters (fetch bytes/s per
        source, decode overlap %, lazy faults served, hedges won), or
        None on an eager restore. Safe to call at any point after
        materialize(); counters reflect progress so far, and the
        snapshot is also folded into ``timings['stream']`` so a later
        reader of the plain timings dict sees it."""
        if self.streamer is None:
            return None
        t = self.streamer.timings()
        self.timings["stream"] = t
        return t

    def has_entry(self, name: str) -> bool:
        if self.restored is None:
            raise LifecycleError("has_entry() before materialize()")
        return name in self.restored.entries

    # --- log introspection (find the vids replay rebound) --------------

    def last_compile(self, fn_name: str) -> Optional[VirtualId]:
        """vexec of the last Compile of ``fn_name`` in the replayed log —
        the executable a resumed loop should step with."""
        if self.lower is None:
            raise LifecycleError("last_compile() before build_lower()")
        vexec = None
        for op in self.lower.oplog.ops:
            if isinstance(op, Compile) and op.fn_name == fn_name:
                vexec = op.vexec
        return vexec

    def last_cache_alloc(self) -> Optional[VirtualId]:
        """vcache of the last live CacheAlloc in the replayed log."""
        if self.lower is None:
            raise LifecycleError("last_cache_alloc() before build_lower()")
        vcache = None
        for op in self.lower.oplog.ops:
            if isinstance(op, CacheAlloc) \
                    and self.lower.handles.is_bound(op.vcache):
                vcache = op.vcache
        return vcache

    # --- convenience ---------------------------------------------------

    @property
    def job(self) -> Dict[str, Any]:
        """The checkpoint's job metadata (arch, shape, seeds, ...)."""
        if self.restored is None:
            raise LifecycleError("job before materialize()")
        return self.restored.manifest.get("job", {})

    @property
    def manifest(self) -> Dict[str, Any]:
        if self.restored is None:
            raise LifecycleError("manifest before materialize()")
        return self.restored.manifest

    def mesh_or_none(self):
        """The replayed mesh, or None when the log bound no hardware
        (e.g. a checkpoint from an unlogged runtime)."""
        try:
            return self.lower.mesh if self.lower is not None else None
        except Exception:
            return None
