"""Split-process state partition (paper §II), adapted to a JAX runtime.

``UpperHalf`` — the application half: semantic training/serving state
(params, optimizer moments, RNG counters, data cursor, step). Stored as
*logically addressed* pytrees: every leaf is reachable by a stable path
string and annotated with logical sharding axes. Nothing here references
a device, a mesh, or a compiled object; this is the only state a
checkpoint saves.

``LowerHalf`` — the driver half: mesh bound to physical devices, compiled
executables, live cache allocations, schedule overrides, data-shard
assignment. Never serialized. Every mutating entry point both executes
and appends to the op-log, so a fresh LowerHalf can be driven back into an
equivalent state by replay (core.oplog).
"""
from __future__ import annotations

import json
import re
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.api.errors import RestoreError
from repro.core.oplog import (
    OpLog, Op, MeshCreate, Compile, CacheAlloc, CacheFree, DataAdvance,
    DataReassign, ScheduleSet,
)
from repro.core.virtual_ids import HandleTable, DeviceMap, VirtualId


# ---------------------------------------------------------------------------
# upper half
# ---------------------------------------------------------------------------

def flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(p), v) for p, v in leaves]


# keystr renders dict keys with repr(), which picks double quotes when
# the key itself contains a single quote — accept both forms
_DICT_KEY = re.compile(
    r"\[(?:'((?:[^'\\]|\\.)*)'|\"((?:[^\"\\]|\\.)*)\")\]")


def tree_from_paths(by_path: Dict[str, Any]) -> Any:
    """Rebuild a nested dict from keystr paths, no template required.

    Inverse of ``flatten_with_paths`` for dict-only pytrees (paths like
    ``['queue']['0']['prompt']``). State whose *structure* is data — the
    serving scheduler's request queue, whose shape differs checkpoint to
    checkpoint — restores through this instead of ``fill_like``. The
    path "" (a bare leaf) returns the leaf itself."""
    if list(by_path) == [""]:
        return by_path[""]
    out: Dict[str, Any] = {}
    for path, leaf in by_path.items():
        keys = []
        pos = 0
        for m in _DICT_KEY.finditer(path):
            if m.start() != pos:
                break
            k = m.group(1) if m.group(1) is not None else m.group(2)
            keys.append(k.replace("\\'", "'").replace('\\"', '"')
                         .replace("\\\\", "\\"))
            pos = m.end()
        if pos != len(path) or not keys:
            raise RestoreError(f"non-dict path {path!r}; use fill_like with "
                               "a structural template instead")
        node = out
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = leaf
    return out


def fill_like(template, by_path: Dict[str, Any]):
    """Rebuild a pytree with `template`'s structure from path->leaf map."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, tleaf in paths:
        key = jax.tree_util.keystr(p)
        if key not in by_path:
            raise KeyError(f"checkpoint missing leaf {key}")
        leaves.append(by_path[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclass
class StateEntry:
    kind: str                  # params | opt_state | rng | data_cursor | ...
    tree: Any                  # pytree (device or host arrays / scalars)
    logical: Any = None        # matching pytree of logical axis tuples


class UpperHalf:
    """Named registry of semantic state entries."""

    def __init__(self) -> None:
        self._entries: Dict[str, StateEntry] = {}

    def register(self, name: str, kind: str, tree, logical=None) -> None:
        self._entries[name] = StateEntry(kind, tree, logical)

    def update(self, name: str, tree) -> None:
        self._entries[name].tree = tree

    def get(self, name: str):
        return self._entries[name].tree

    def entry(self, name: str) -> StateEntry:
        return self._entries[name]

    def names(self) -> List[str]:
        return list(self._entries)

    def items(self):
        return self._entries.items()

    def to_host(self) -> Dict[str, Dict[str, np.ndarray]]:
        """Pull every tensor off device: {entry: {leaf_path: np.ndarray}}.

        This is the checkpoint's payload — note it contains no handles,
        no devices, no executables (the split-process guarantee).

        np.array (not asarray): host-resident numpy leaves must be
        COPIED at the snapshot point, or a caller mutating them after
        save() returns would race the async background writer."""
        out: Dict[str, Dict[str, np.ndarray]] = {}
        for name, e in self._entries.items():
            out[name] = {
                p: np.array(jax.device_get(v))
                for p, v in flatten_with_paths(e.tree)
            }
        return out

    def structure(self) -> Dict[str, Any]:
        """JSON-able description (kinds + leaf shapes/dtypes + logical)."""
        desc = {}
        for name, e in self._entries.items():
            leaves = {}
            for p, v in flatten_with_paths(e.tree):
                # shape/dtype description needs no device transfer:
                # array-likes carry both already; scalar/non-array
                # leaves (int, float, list) are viewed through numpy
                arr = v if hasattr(v, "shape") else np.asarray(v)
                leaves[p] = {"shape": list(arr.shape),
                             "dtype": str(arr.dtype)}
            logical = None
            if e.logical is not None:
                logical = {p: list(ax) for p, ax in flatten_with_paths(e.logical)}
            desc[name] = {"kind": e.kind, "leaves": leaves, "logical": logical}
        return desc


# ---------------------------------------------------------------------------
# function registry: Compile ops resolve through here
# ---------------------------------------------------------------------------

# fn_name -> builder(arch, shape_key, plan_key, lower_half) -> callable
FUNCTION_REGISTRY: Dict[str, Callable] = {}


def register_step_fn(name: str):
    def deco(builder):
        FUNCTION_REGISTRY[name] = builder
        return builder
    return deco


# ---------------------------------------------------------------------------
# lower half
# ---------------------------------------------------------------------------

class LowerHalf:
    """The reinitializable driver half.

    Construction is cheap and touches no devices; ``mesh_create`` (direct
    or via replay) binds hardware. A restart constructs a new LowerHalf
    (or calls ``reset()``) and replays the op-log.
    """

    def __init__(self, handles: Optional[HandleTable] = None,
                 oplog: Optional[OpLog] = None,
                 mesh_factory: Optional[Callable] = None) -> None:
        self.handles = handles or HandleTable()
        self.oplog = oplog or OpLog()
        self.devices = DeviceMap()
        # mesh_factory overrides logged mesh geometry (elastic restore)
        self.mesh_factory = mesh_factory
        self.vmesh: Optional[VirtualId] = None
        self.schedule_overrides: Dict[str, float] = {}
        self.data_cursor_replayed = 0
        self.data_assignment: Optional[Tuple[Tuple[int, int], ...]] = None
        self._compiled: Dict[Tuple[str, str, str, str], VirtualId] = {}
        self._lock = threading.RLock()

    # --- logged public API (execute + append) --------------------------

    def mesh_create(self, shape, axes) -> VirtualId:
        with self._lock:
            vmesh = self.handles.allocate("mesh")
            op = self.oplog.append(MeshCreate, vmesh=vmesh,
                                   shape=tuple(shape), axes=tuple(axes))
            self._apply(op)
            return vmesh

    def compile_step(self, fn_name: str, arch: str, shape_key: str,
                     plan_key: str = "") -> VirtualId:
        with self._lock:
            vexec = self.handles.allocate("exec")
            op = self.oplog.append(Compile, vexec=vexec, fn_name=fn_name,
                                   arch=arch, shape_key=shape_key,
                                   plan_key=plan_key)
            self._apply(op)
            return vexec

    def cache_alloc(self, arch: str, batch: int, max_seq: int) -> VirtualId:
        with self._lock:
            vcache = self.handles.allocate("cache")
            op = self.oplog.append(CacheAlloc, vcache=vcache, arch=arch,
                                   batch=batch, max_seq=max_seq)
            self._apply(op)
            return vcache

    def cache_free(self, vcache: VirtualId) -> None:
        with self._lock:
            op = self.oplog.append(CacheFree, vcache=vcache)
            self._apply(op)

    def data_advance(self, n: int) -> None:
        with self._lock:
            op = self.oplog.append(DataAdvance, n=n)
            self._apply(op)

    def data_reassign(self, assignment) -> None:
        with self._lock:
            op = self.oplog.append(
                DataReassign, assignment=tuple(map(tuple, assignment)))
            self._apply(op)

    def schedule_set(self, key: str, value: float) -> None:
        with self._lock:
            op = self.oplog.append(ScheduleSet, key=key, value=float(value))
            self._apply(op)

    # --- resolution ------------------------------------------------------

    @property
    def mesh(self):
        return self.devices.mesh

    def executable(self, vexec: VirtualId):
        return self.handles.translate(vexec)

    def cache(self, vcache: VirtualId):
        return self.handles.translate(vcache)

    # --- replay side -----------------------------------------------------

    def reset(self) -> None:
        """Fresh incarnation: drop all real bindings (the 'kill the driver'
        moment). vids stay allocated; replay rebinds them."""
        self.handles.new_incarnation()
        self.devices = DeviceMap()
        self.vmesh = None
        self.schedule_overrides = {}
        self.data_cursor_replayed = 0
        self.data_assignment = None
        self._compiled = {}

    def apply_op(self, op: Op) -> None:
        """Execute one op without logging (replay path)."""
        self._apply(op)

    def _apply(self, op: Op) -> None:
        if isinstance(op, MeshCreate):
            if self.mesh_factory is not None:
                mesh = self.mesh_factory()
            else:
                mesh = jax.make_mesh(tuple(op.shape), tuple(op.axes))
            self.devices.bind_mesh(mesh)
            self.handles.bind(op.vmesh, mesh)
            self.vmesh = op.vmesh
        elif isinstance(op, Compile):
            key = (op.fn_name, op.arch, op.shape_key, op.plan_key)
            if key in self._compiled and self.handles.is_bound(
                    self._compiled[key]):
                # identical compilation already live: alias the vid to the
                # existing executable instead of recompiling
                fn = self.handles.translate(self._compiled[key])
            else:
                builder = FUNCTION_REGISTRY[op.fn_name]
                fn = builder(op.arch, op.shape_key, op.plan_key, self)
                self._compiled[key] = op.vexec
            self.handles.bind(op.vexec, fn)
        elif isinstance(op, CacheAlloc):
            from repro.serving.kv_cache import allocate_cache
            cache = allocate_cache(op.arch, op.batch, op.max_seq, self)
            self.handles.bind(op.vcache, cache)
        elif isinstance(op, CacheFree):
            self.handles.release(op.vcache)
        elif isinstance(op, DataAdvance):
            self.data_cursor_replayed += op.n
        elif isinstance(op, DataReassign):
            self.data_assignment = op.assignment
        elif isinstance(op, ScheduleSet):
            self.schedule_overrides[op.key] = op.value
        else:
            raise TypeError(f"unknown op {op}")

    # --- observability (for tests / prune equivalence) -------------------

    def fingerprint(self) -> Dict[str, Any]:
        mesh_shape = None
        try:
            mesh_shape = dict(self.devices.mesh.shape)
        except Exception:
            pass
        compiled = sorted(
            key for key, vexec in self._compiled.items()
            if self.handles.is_bound(vexec))
        live_caches = sorted(
            v.uid for v in self.handles.live_vids() if v.kind == "cache")
        return {
            "mesh": mesh_shape,
            "compiled": compiled,
            "caches": live_caches,
            "schedule": dict(self.schedule_overrides),
            "data_cursor": self.data_cursor_replayed,
            "assignment": self.data_assignment,
        }
