"""Log-and-replay of runtime-mutating operations (paper §III).

The lower half's state machine (mesh, compiled executables, cache
allocations, data-shard assignment, schedule mutations) cannot be
serialized — but every call that mutates it flows through this log. On
restore the log is replayed against a *fresh* lower half, driving it into
an equivalent state, exactly as the paper replays OpenGL calls against a
freshly loaded driver.

Pruning implements the record-prune-replay idea the paper cites as future
work (§VI): ops whose effects are dead (freed caches, superseded
compilations, coalesced data seeks, overwritten schedule sets) are removed
so the log stays O(live state) instead of O(history). The invariant —
``replay(prune(log)) == replay(log)`` up to observable lower-half state —
is property-tested.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, asdict
from typing import Any, Dict, List, Optional, Tuple

from repro.core.virtual_ids import VirtualId


# ---------------------------------------------------------------------------
# ops — pure-data records; only vids + JSON-able args
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Op:
    seq: int

    def is_mutating(self) -> bool:
        return True


@dataclass(frozen=True)
class MeshCreate(Op):
    vmesh: VirtualId = None
    shape: Tuple[int, ...] = ()
    axes: Tuple[str, ...] = ()


@dataclass(frozen=True)
class Compile(Op):
    """Request compilation of a registered step function."""
    vexec: VirtualId = None
    fn_name: str = ""            # key in the FunctionRegistry
    arch: str = ""
    shape_key: str = ""          # input-shape cell
    plan_key: str = ""           # serialized plan knobs


@dataclass(frozen=True)
class CacheAlloc(Op):
    vcache: VirtualId = None
    arch: str = ""
    batch: int = 0
    max_seq: int = 0


@dataclass(frozen=True)
class CacheFree(Op):
    vcache: VirtualId = None


@dataclass(frozen=True)
class DataAdvance(Op):
    """The data pipeline consumed n batches (cursor moves forward)."""
    n: int = 0


@dataclass(frozen=True)
class DataReassign(Op):
    """Straggler mitigation re-balanced host->shard ownership."""
    assignment: Tuple[Tuple[int, int], ...] = ()   # (host, shard) pairs


@dataclass(frozen=True)
class ScheduleSet(Op):
    key: str = ""
    value: float = 0.0


OP_TYPES = {c.__name__: c for c in
            (MeshCreate, Compile, CacheAlloc, CacheFree, DataAdvance,
             DataReassign, ScheduleSet)}


# ---------------------------------------------------------------------------
# the log
# ---------------------------------------------------------------------------

class OpLog:
    def __init__(self, ops: Optional[List[Op]] = None) -> None:
        self._ops: List[Op] = list(ops or [])
        self._next_seq = (self._ops[-1].seq + 1) if self._ops else 0

    def append(self, op_cls, **kw) -> Op:
        op = op_cls(seq=self._next_seq, **kw)
        self._next_seq += 1
        self._ops.append(op)
        return op

    @property
    def ops(self) -> List[Op]:
        return list(self._ops)

    def __len__(self) -> int:
        return len(self._ops)

    # --- pruning (record-prune-replay) ---------------------------------

    def prune(self) -> "OpLog":
        """Remove ops with dead effects. Keeps relative order of survivors."""
        ops = self._ops
        keep = [True] * len(ops)

        # 1) CacheAlloc cancelled by a later CacheFree (and the free itself)
        freed = {}
        for i, op in enumerate(ops):
            if isinstance(op, CacheFree):
                freed[op.vcache] = i
        for i, op in enumerate(ops):
            if isinstance(op, CacheAlloc) and op.vcache in freed \
                    and freed[op.vcache] > i:
                keep[i] = False
                keep[freed[op.vcache]] = False

        # 2) duplicate Compile of the same (fn, arch, shape, plan): keep first
        seen_compiles = set()
        for i, op in enumerate(ops):
            if isinstance(op, Compile):
                key = (op.fn_name, op.arch, op.shape_key, op.plan_key)
                if key in seen_compiles:
                    keep[i] = False
                else:
                    seen_compiles.add(key)

        # 3) coalesce DataAdvance runs into a single seek (replace last)
        total_advance = sum(op.n for op in ops if isinstance(op, DataAdvance))
        seen_advance = False
        for i in range(len(ops) - 1, -1, -1):
            if isinstance(ops[i], DataAdvance):
                if seen_advance:
                    keep[i] = False
                seen_advance = True

        # 4) ScheduleSet: keep only the last per key
        seen_sched = set()
        for i in range(len(ops) - 1, -1, -1):
            if isinstance(ops[i], ScheduleSet):
                if ops[i].key in seen_sched:
                    keep[i] = False
                else:
                    seen_sched.add(ops[i].key)

        # 5) DataReassign: keep only the last
        seen_reassign = False
        for i in range(len(ops) - 1, -1, -1):
            if isinstance(ops[i], DataReassign):
                if seen_reassign:
                    keep[i] = False
                seen_reassign = True

        out = []
        for i, op in enumerate(ops):
            if not keep[i]:
                continue
            if isinstance(op, DataAdvance):
                op = DataAdvance(seq=op.seq, n=total_advance)
            out.append(op)
        return OpLog(out)

    # --- replay ----------------------------------------------------------

    def replay(self, runtime) -> None:
        """Drive a fresh lower half through the logged mutations.
        ``runtime`` is core.split_state.LowerHalf (duck-typed for tests)."""
        for op in self._ops:
            runtime.apply_op(op)

    # --- serialization ----------------------------------------------------

    def to_json(self) -> str:
        def enc(op: Op) -> Dict[str, Any]:
            d = asdict(op)
            d["__type__"] = type(op).__name__
            for k, v in list(d.items()):
                if isinstance(v, dict) and set(v) == {"kind", "uid"}:
                    d[k] = {"__vid__": True, **v}
            return d

        return json.dumps([enc(op) for op in self._ops])

    @classmethod
    def from_json(cls, s: str) -> "OpLog":
        raw = json.loads(s)
        ops: List[Op] = []
        for d in raw:
            t = OP_TYPES[d.pop("__type__")]
            for k, v in list(d.items()):
                if isinstance(v, dict) and v.get("__vid__"):
                    d[k] = VirtualId(v["kind"], v["uid"])
                elif isinstance(v, list):
                    d[k] = tuple(tuple(x) if isinstance(x, list) else x
                                 for x in v)
            ops.append(t(**d))
        return cls(ops)
