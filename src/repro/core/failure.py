"""Failure detection and straggler mitigation for multi-host jobs.

The detection logic is real (injectable clock makes it unit-testable);
host liveness is fed by the launcher's heartbeat loop on hardware, or by
tests/simulators here. Policies yield *decisions*;
``core.supervisor.ClusterSupervisor`` executes them end-to-end, routing
every runtime mutation through the logged API so the decision replays
correctly after a later restart (e.g. a DataReassign op for shard
rebalancing).

Policies:
  restart_last_ckpt — classic C/R: tear down, restore latest checkpoint
                      (the paper's Maya flow);
  hot_spare         — rebind the failed host's logical coordinates to a
                      spare host (virtual-id remap; no rollback needed if
                      peer-replicated state covers the loss);
  shrink            — elastic restore onto the surviving topology.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List, Optional, Tuple


class FailureAction(Enum):
    NONE = "none"
    RESTART_LAST_CKPT = "restart_last_ckpt"
    HOT_SPARE = "hot_spare"
    SHRINK = "shrink"
    # not a failure: an operator-initiated drain/move of a healthy host
    # (maintenance, defrag). Decided by ClusterSupervisor.planned_move,
    # never by FailurePolicy — nothing is dead.
    PLANNED_MOVE = "planned_move"
    # not a failure either: elastic expansion — an idle host joins the
    # world and the runner rebuilds onto the larger topology (the
    # inverse of SHRINK). Decided by ClusterSupervisor.grow, never by
    # FailurePolicy.
    GROW = "grow"


@dataclass
class HostState:
    last_heartbeat: float
    last_step: int = 0
    step_ewma: float = 0.0       # seconds per step
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, hosts: List[int], timeout: float = 60.0,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.timeout = timeout
        self.clock = clock
        now = clock()
        self.hosts: Dict[int, HostState] = {
            h: HostState(last_heartbeat=now) for h in hosts}

    def beat(self, host: int, step: int) -> None:
        now = self.clock()
        st = self.hosts[host]
        if step > st.last_step:
            dt = (now - st.last_heartbeat) / max(step - st.last_step, 1)
            st.step_ewma = dt if st.step_ewma == 0.0 else \
                0.8 * st.step_ewma + 0.2 * dt
        st.last_heartbeat = now
        st.last_step = step
        st.alive = True

    def dead_hosts(self) -> List[int]:
        now = self.clock()
        out = []
        for h, st in self.hosts.items():
            if now - st.last_heartbeat > self.timeout:
                st.alive = False
                out.append(h)
        return out

    def alive_hosts(self) -> List[int]:
        self.dead_hosts()
        return [h for h, st in self.hosts.items() if st.alive]


class StragglerDetector:
    """Flags hosts whose per-step time exceeds k x median EWMA."""

    def __init__(self, monitor: HeartbeatMonitor, k: float = 1.5,
                 min_steps: int = 3) -> None:
        self.monitor = monitor
        self.k = k
        self.min_steps = min_steps

    def stragglers(self) -> List[int]:
        sts = [(h, s) for h, s in self.monitor.hosts.items()
               if s.alive and s.last_step >= self.min_steps and s.step_ewma > 0]
        if len(sts) < 3:
            return []
        times = sorted(s.step_ewma for _, s in sts)
        median = times[len(times) // 2]
        return [h for h, s in sts if s.step_ewma > self.k * median]


@dataclass
class FailurePolicy:
    spares: List[int] = field(default_factory=list)
    allow_shrink: bool = True

    def decide(self, dead: List[int], world: List[int]) -> Tuple[FailureAction, dict]:
        if not dead:
            return FailureAction.NONE, {}
        if self.spares and len(dead) <= len(self.spares):
            mapping = {d: s for d, s in zip(dead, self.spares)}
            return FailureAction.HOT_SPARE, {"mapping": mapping}
        survivors = [h for h in world if h not in dead]
        # shrinking requires someone to shrink ONTO: an empty survivor
        # set (last host died) must restart-in-place, not divide by zero
        if self.allow_shrink and survivors \
                and len(survivors) >= len(world) // 2:
            return FailureAction.SHRINK, {"survivors": survivors}
        return FailureAction.RESTART_LAST_CKPT, {}


def rebalance_shards(n_shards: int, hosts: List[int]) -> List[Tuple[int, int]]:
    """Even host->shard assignment; returned pairs are logged via
    DataReassign so the decision replays after restore."""
    out = []
    for i in range(n_shards):
        out.append((hosts[i % len(hosts)], i))
    return out
