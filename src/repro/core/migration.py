"""Live migration as a fleet primitive: planned snapshot → restore
moves of serving sessions between engines, with bounded blackout.

The paper's split-process design exists so a running GPU application
can be moved off its hardware and reincarnated elsewhere without the
app noticing; CRIUgpu carries the same primitive into container live
migration, and MANA's agnostic transport shows the state can land on a
*different* world than it left. The supervisor (core/supervisor.py)
covers the reactive half — something died; this module is the
proactive half: nothing died, the operator wants the sessions
somewhere else (defrag, maintenance, rebalancing), and the move must
cost milliseconds of per-session blackout, not a restart.

The mechanism is deliberately the C/R protocol, not object handoff:

  freeze    ``ServingEngine.extract_sessions`` removes the chosen
            slots' live requests from the source WITHOUT stopping its
            decode loop — unaffected slots keep generating, freed
            slots refill from the source queue;
  capture   the frozen sessions become a ``SessionBundle`` — a
            CheckpointableApp whose upper half is the request trees —
            snapshotted through a dedicated *move channel*: its own
            store under ``<store>/_moves/...`` with ``chain=1``, so
            migration traffic can never interleave with (or corrupt)
            the source engine's periodic delta chain;
  restore   the bundle restores (streaming by default) on the target
            side and every session re-enters through admission, which
            replays prompt + generated-so-far into its new slot — the
            PR 2 re-slot machinery, so an N-slot engine's sessions
            land on an M-slot engine token-identically;
  cutover   requests that arrived mid-move for the draining engine
            were held by the router and are replayed on the target.

Per-session blackout is bounded by the *batch size*, not the engine
size: ``migrate_batch`` sessions freeze per round while the rest keep
decoding — the knob (``Policy.migrate_batch``) trades total move time
against worst-case per-session stall. ``benchmarks/migration_blackout``
publishes the numbers next to MTTR.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api.errors import MigrationError

# entry kinds follow the serving engine's vocabulary: request trees are
# scheduler state (hot tier under a streaming restore — a move wants
# the sessions first, always)
_BUNDLE_KIND = "serving-move"


def _request_cls():
    # serving imports core, never the reverse — resolve the concrete
    # Request type lazily, only where a bundle rebuilds one
    from repro.serving.engine import Request
    return Request


def _request_tree(r: Any) -> Dict[str, np.ndarray]:
    from repro.serving.engine import _request_tree as enc
    return enc(r)


def _request_from_tree(t: Dict[str, Any]) -> Any:
    from repro.serving.engine import _request_from_tree as dec
    return dec(t)


class SessionBundle:
    """The unit of migration: frozen live sessions as a protocol
    citizen. Snapshotting and restoring it through a CheckpointSession
    IS the transport — the bundle never assumes source and target share
    a process, only a store."""

    kind = _BUNDLE_KIND

    def __init__(self, requests: Sequence[Any] = (),
                 source_step: int = 0) -> None:
        self.requests: List[Any] = list(requests)
        self.source_step = int(source_step)

    # --- CheckpointableApp protocol ------------------------------------

    def checkpoint_state(self):
        from repro.core.split_state import UpperHalf
        up = UpperHalf()
        up.register("moved", "sched",
                    {f"{i:06d}": _request_tree(r)
                     for i, r in enumerate(self.requests)})
        up.register("source_step", "step", np.int64(self.source_step))
        return up

    def checkpoint_step(self) -> int:
        return self.source_step

    def job_meta(self) -> Dict[str, Any]:
        return {"kind": self.kind, "n_sessions": len(self.requests)}

    def bind(self, restore) -> None:
        moved = restore.tree("moved") if restore.has("moved") else {}
        self.requests = [_request_from_tree(v)
                         for _, v in sorted(moved.items())]
        self.source_step = int(restore.scalar("source_step"))
        restore.release()


def _register_bundle_kind() -> None:
    from repro.api.registry import resolve_app_kind, register_app_kind
    try:
        resolve_app_kind(_BUNDLE_KIND)
        return
    except Exception:
        pass

    @register_app_kind(_BUNDLE_KIND)
    def _restore_bundle(restore) -> SessionBundle:
        bundle = SessionBundle()
        bundle.bind(restore)
        return bundle


_register_bundle_kind()


@dataclass
class MoveResult:
    """One executed move, with its blackout accounting. ``blackout_s``
    is the WORST per-batch freeze→serving-again wall time — the number
    a session could observe; totals are what the operator paid."""
    move_id: int
    source: str
    target: str
    moved: List[int] = field(default_factory=list)   # rids, move order
    batches: List[Dict[str, float]] = field(default_factory=list)
    blackout_s: float = 0.0
    capture_s: float = 0.0
    restore_s: float = 0.0
    replayed: int = 0            # held mid-move requests flushed at cutover
    deadline_s: Optional[float] = None
    within_deadline: bool = True
    requests: List[Any] = field(default_factory=list)  # landed objects


def _channel_spec(via: str, sub: str) -> str:
    """A store spec for one move channel under ``via``: same scheme,
    sub-path appended — migration traffic lives beside the engine's
    chain, never inside it."""
    from repro.api.registry import parse_store_spec
    if via.startswith("/"):
        via = f"localfs:{via}"
    scheme, path, params = parse_store_spec(via)
    q = "&".join(f"{k}={v}" for k, v in params.items())
    return f"{scheme}:{path.rstrip('/')}/{sub}" + (f"?{q}" if q else "")


def _chunks(seq: List[Any], n: int) -> List[List[Any]]:
    return [list(seq[i:i + n]) for i in range(0, len(seq), n)]


def migrate_sessions(source: Any, target: Any, *, via: str,
                     slots: Optional[Sequence[int]] = None,
                     include_queue: bool = False,
                     batch: Optional[int] = None,
                     deadline_s: Optional[float] = None,
                     streaming: bool = True,
                     move_id: int = 0,
                     source_name: str = "source",
                     target_name: str = "target",
                     settle: bool = True) -> MoveResult:
    """Move live sessions from ``source`` onto ``target`` through the
    C/R protocol, batch by batch.

    Each batch freezes at most ``batch`` slots (None = all chosen slots
    at once), snapshots them as a ``SessionBundle`` on a fresh move
    channel under ``via``, restores the bundle (``streaming`` by
    default) and re-admits every session on the target; ``settle`` runs
    one target engine step so the batch's blackout clock stops at
    "serving again", not "queued". The source keeps decoding its
    remaining slots between batches. ``deadline_s`` is judged against
    the worst per-batch blackout and reported on the result — a planned
    move that missed its drain deadline must be visible, not silent."""
    from repro.api.policy import Policy
    from repro.api.session import CheckpointSession

    for attr, owner, role in (("extract_sessions", source, "source"),
                              ("submit", target, "target"),
                              ("step", target, "target")):
        if not callable(getattr(owner, attr, None)):
            raise MigrationError(
                f"{role} {type(owner).__name__} has no {attr}(); live "
                "migration needs a serving-style engine on both ends")

    active = [s for s in range(source.n_slots)
              if source.slot_req[s] is not None]
    chosen = active if slots is None else \
        [s for s in slots if source.slot_req[s] is not None]
    if batch is not None and batch < 1:
        raise MigrationError(f"batch={batch}: a move batch freezes at "
                             "least one slot")

    res = MoveResult(move_id=move_id, source=source_name,
                     target=target_name, deadline_s=deadline_s)
    batches = _chunks(chosen, batch or max(1, len(chosen))) or [[]]
    policy = Policy(chain=1, async_save=False)
    for bi, group in enumerate(batches):
        last = bi == len(batches) - 1
        t0 = time.monotonic()
        reqs = source.extract_sessions(group) if group else []
        if last and include_queue:
            reqs += source.extract_sessions([], include_queue=True)
        if not reqs:
            continue
        spec = _channel_spec(via, f"_moves/m{move_id:04d}_{bi}")
        with CheckpointSession(spec, policy) as chan:
            chan.attach(SessionBundle(reqs, source.steps))
            chan.snapshot(block=True)
            t1 = time.monotonic()
            landed = chan.restore("latest", expect_kind=_BUNDLE_KIND,
                                  streaming=streaming)
        t2 = time.monotonic()
        for r in landed.requests:
            target.submit(r)
        if settle:
            target.step()      # admission replay + the next token: the
        t3 = time.monotonic()  # moved sessions are being served again
        res.moved += [r.rid for r in landed.requests]
        res.requests += list(landed.requests)
        res.capture_s += t1 - t0
        res.restore_s += t2 - t1
        res.batches.append({"n": float(len(reqs)),
                            "blackout_s": t3 - t0,
                            "capture_s": t1 - t0,
                            "restore_s": t2 - t1})
        res.blackout_s = max(res.blackout_s, t3 - t0)
    if deadline_s is not None and res.blackout_s > deadline_s:
        res.within_deadline = False
    return res


class FleetRouter:
    """Routes requests over named live engines and moves sessions
    between them with bounded blackout.

    The router is the fleet's front door: ``submit`` picks the least
    loaded engine (or honors a pin), ``step`` advances every engine one
    decode round and collects finished requests exactly once —
    ``duplicates`` and ``dropped()`` make the zero-loss claim a counter,
    not a hope. ``migrate``/``drain`` run the snapshot→restore move
    while the source keeps serving; requests pinned to a draining
    engine are *held* and replayed on the target at cutover."""

    def __init__(self, engines: Dict[str, Any], via: str, *,
                 migrate_batch: Optional[int] = None,
                 drain_deadline_s: Optional[float] = None) -> None:
        if not engines:
            raise MigrationError("FleetRouter needs at least one engine")
        self.engines = dict(engines)
        self.via = via
        self.migrate_batch = migrate_batch
        self.drain_deadline_s = drain_deadline_s
        self.owner: Dict[int, str] = {}
        self.inflight: Dict[int, Any] = {}
        self.completed: Dict[int, Any] = {}
        self.duplicates = 0
        self.draining: set = set()
        self.moves: List[MoveResult] = []
        self._held: List[Tuple[str, Any]] = []
        self._next_rid = 1
        self._next_move = 0

    # --- routing --------------------------------------------------------

    def _load(self, name: str) -> int:
        return len(self.engines[name].live_requests())

    def submit(self, prompt, max_new: int, *,
               engine: Optional[str] = None) -> int:
        """Route one request; returns its rid. A request pinned to a
        draining engine is held and replayed on the move's target."""
        rid = self._next_rid
        self._next_rid += 1
        req = _request_cls()(rid=rid,
                             prompt=np.asarray(prompt, np.int32),
                             max_new=int(max_new))
        self.inflight[rid] = req
        if engine is not None and engine in self.draining:
            self._held.append((engine, req))
            self.owner[rid] = engine
            return rid
        open_engines = [n for n in self.engines if n not in self.draining]
        if not open_engines:
            raise MigrationError("every engine is draining; nowhere to "
                                 "route the request")
        name = engine if engine is not None else \
            min(open_engines, key=self._load)
        if name not in self.engines:
            raise MigrationError(f"unknown engine {name!r} "
                                 f"(have {sorted(self.engines)})")
        self.engines[name].submit(req)
        self.owner[rid] = name
        return rid

    def step(self) -> int:
        """One decode round across the fleet; returns active slots."""
        active = 0
        for name, eng in self.engines.items():
            if name in self.draining and not eng.live_requests():
                continue
            active += eng.step()
        self._collect()
        return active

    def _collect(self) -> None:
        for rid, req in list(self.inflight.items()):
            if req.done:
                if rid in self.completed:
                    self.duplicates += 1
                else:
                    self.completed[rid] = req
                del self.inflight[rid]

    def dropped(self) -> List[int]:
        """rids that are neither in flight nor completed — must be
        empty at all times for the zero-loss claim to hold."""
        return sorted(set(self.owner) - set(self.completed)
                      - set(self.inflight))

    # --- moves ----------------------------------------------------------

    def migrate(self, src: str, dst: str, *,
                slots: Optional[Sequence[int]] = None,
                include_queue: bool = False,
                batch: Optional[int] = None,
                deadline_s: Optional[float] = None,
                streaming: bool = True,
                keep_draining: bool = False) -> MoveResult:
        """Move ``slots`` (default: every live session) from engine
        ``src`` to ``dst``. The source serves its unaffected slots
        throughout; held mid-move requests replay on the target."""
        for name in (src, dst):
            if name not in self.engines:
                raise MigrationError(f"unknown engine {name!r} "
                                     f"(have {sorted(self.engines)})")
        if src == dst:
            raise MigrationError(f"migrate {src!r} -> itself is a no-op "
                                 "asked loudly; pick a different target")
        move_id = self._next_move
        self._next_move += 1
        self.draining.add(src)
        try:
            res = migrate_sessions(
                self.engines[src], self.engines[dst], via=self.via,
                slots=slots, include_queue=include_queue,
                batch=batch if batch is not None else self.migrate_batch,
                deadline_s=deadline_s if deadline_s is not None
                else self.drain_deadline_s,
                streaming=streaming, move_id=move_id,
                source_name=src, target_name=dst)
            # the landed request objects are the live ones now — the
            # router must watch them, not the frozen source-side twins
            for r in res.requests:
                self.inflight[r.rid] = r
                self.owner[r.rid] = dst
            held, self._held = self._held, []
            for name, req in held:
                if name == src:
                    self.engines[dst].submit(req)
                    self.owner[req.rid] = dst
                    res.replayed += 1
                else:
                    self._held.append((name, req))
        finally:
            if not keep_draining:
                self.draining.discard(src)
        self._collect()
        self.moves.append(res)
        return res

    def drain(self, src: str, dst: str, *,
              deadline_s: Optional[float] = None) -> MoveResult:
        """Move EVERYTHING off ``src`` — live slots and waiting queue —
        and keep it out of the routing rotation afterwards (the
        maintenance form of ``migrate``)."""
        return self.migrate(src, dst, include_queue=True,
                            deadline_s=deadline_s, keep_draining=True)

    # --- observability --------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        return {
            "engines": {n: self._load(n) for n in self.engines},
            "draining": sorted(self.draining),
            "submitted": self._next_rid - 1,
            "completed": len(self.completed),
            "inflight": len(self.inflight),
            "held": len(self._held),
            "duplicates": self.duplicates,
            "dropped": len(self.dropped()),
            "moves": len(self.moves),
            "worst_blackout_s": max((m.blackout_s for m in self.moves),
                                    default=0.0),
        }
