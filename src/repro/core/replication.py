"""Peer-replication repair for ``ShardedBackend`` (the DMTCP-analogue's
failure half).

With ``replicate=True`` every blob lives twice: the primary copy on its
owner host ``h = _host_of(name)`` and a ``replica_``-prefixed copy on
the ring successor ``(h+1) % N``. Losing any single host therefore
loses no data — but it *does* leave the store degraded: the next
checkpoint's writes to the dead host fail loudly, and a second failure
on an adjacent host would be unrecoverable. ``repair`` closes that
window: it re-creates the lost host's directory and rebuilds every blob
that should live there from its surviving peer copy, returning the
store to full redundancy before a restore (or the next snapshot) runs.

This is the supervisor's storage-repair step: ``ClusterSupervisor``
calls ``repair`` after a host death and before driving the Incarnation
restore, so the restore never depends on the dead host.

``scan`` is the read-only half (what's missing, what's unrecoverable);
``repair`` is scan + rewrite through the backend's atomic write
protocol, so a crash mid-repair leaves only invisible temp files.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.backends.base import write_atomic
from repro.core.backends.sharded import ShardedBackend

_REPLICA = "replica_"


@dataclass
class RepairReport:
    """What a scan/repair pass found (and, for repair, fixed)."""
    hosts: int = 0
    blobs: int = 0                       # distinct blob names seen
    missing_primaries: int = 0
    missing_replicas: int = 0
    restored: int = 0                    # copies rewritten by repair()
    unrecoverable: List[str] = field(default_factory=list)

    @property
    def degraded(self) -> bool:
        return bool(self.missing_primaries or self.missing_replicas
                    or self.unrecoverable)


def _survey(backend: ShardedBackend) -> Dict[str, List[Path]]:
    """name -> every path the blob *should* occupy (primary first).

    Names come from two sources: the surviving host directories (covers
    garbage blobs a not-yet-committed manifest may still reference) and
    every committed manifest's referenced hashes — the latter is what
    lets a blob that lost *all* its copies still be named as
    unrecoverable instead of silently forgotten."""
    names = set()
    for h in range(backend.n_hosts):
        d = backend.root / f"host_{h:03d}"
        if not d.is_dir():
            continue
        for p in d.iterdir():
            n = p.name
            if n.startswith(".tmp"):
                continue
            names.add(n[len(_REPLICA):] if n.startswith(_REPLICA) else n)
    from repro.core.delta import referenced_hashes
    for step in backend.list_steps():
        try:
            names |= referenced_hashes(backend.get_manifest(step))
        except FileNotFoundError:   # raced a concurrent GC
            pass
    return {n: backend._paths(n) for n in sorted(names)}


def _account(rep: RepairReport, backend: ShardedBackend, name: str,
             paths: List[Path]) -> List[Path]:
    """Classify one blob into the report; returns its surviving paths
    (empty = unrecoverable). The single definition of 'degraded' that
    scan and repair both count with."""
    rep.blobs += 1
    alive = [p for p in paths if p.exists()]
    if not alive:
        rep.unrecoverable.append(name)
        return alive
    if not paths[0].exists():
        rep.missing_primaries += 1
    if backend.replicate and len(paths) > 1 and not paths[1].exists():
        rep.missing_replicas += 1
    return alive


def scan(backend: ShardedBackend) -> RepairReport:
    """Read-only integrity survey: which blobs are missing their primary
    or replica copy, and which have lost *every* copy (named in
    ``unrecoverable`` — the checkpoints referencing them are gone for
    good and ``restorable_steps`` / manifest verification will say so
    loudly)."""
    rep = RepairReport(hosts=backend.n_hosts)
    for name, paths in _survey(backend).items():
        _account(rep, backend, name, paths)
    return rep


def repair(backend: ShardedBackend, host: Optional[int] = None,
           heal: bool = True) -> RepairReport:
    """Rebuild every missing blob copy from its surviving peer.

    ``host``: if given, that host's directory is (re)created first —
    the caller is telling us this host's storage was lost wholesale
    (e.g. ``rm -rf host_002``); repair then restores both the primaries
    it owned and the replicas it held for its ring predecessor. With
    ``host=None`` the whole store is swept — same result, useful when
    the caller only knows "something is degraded".

    ``heal``: drop ``host`` (or, when sweeping, every host) from the
    backend's failure-injection set once its data is rebuilt, so
    subsequent reads/writes reach it again.

    Every rewrite goes through the backend's atomic temp+fsync+rename
    protocol; a crash mid-repair is invisible and re-running repair is
    idempotent. Blobs with no surviving copy are reported, not raised:
    the caller decides whether the manifests that reference them are
    restorable (``restorable_steps`` / manifest verification will fail
    loudly for those)."""
    if heal:
        for h in ((host,) if host is not None else
                  range(backend.n_hosts)):
            backend.heal_host(h)
    for h in range(backend.n_hosts):
        (backend.root / f"host_{h:03d}").mkdir(parents=True, exist_ok=True)
    rep = RepairReport(hosts=backend.n_hosts)
    for name, paths in _survey(backend).items():
        alive = _account(rep, backend, name, paths)
        if not alive:
            continue
        data = None
        for p in paths:
            if not p.exists():
                if data is None:
                    data = alive[0].read_bytes()
                write_atomic(p, data, backend.fsync)
                rep.restored += 1
    return rep


def blob_sources(backend, name: str) -> List[Tuple[str, Callable[[], bytes]]]:
    """Every place one blob can be read from, as ordered
    ``(label, read_callable)`` pairs — the preferred source first.

    This is the streaming restore's fetch fan-out: a ``ShardedBackend``
    exposes the primary copy on the owner host and the ``replica_`` copy
    on its (h+1)%N ring successor as *independent* sources, so the
    fetcher can hedge a slow or dead primary with its peer instead of
    serializing behind ``get_blob``'s internal failover. Backends with
    their own tiering (e.g. the ``cached:`` read-through store) override
    the enumeration via a ``blob_sources`` method; anything else is a
    single opaque source. Each callable raises (``FileNotFoundError``,
    ``IOError``) when its copy is unavailable *at read time* — liveness
    is judged per read, not per plan."""
    own = getattr(backend, "blob_sources", None)
    if callable(own):
        return own(name)
    if isinstance(backend, ShardedBackend):
        out: List[Tuple[str, Callable[[], bytes]]] = []
        for host, path in backend._placements(name):
            if host in backend._failed_hosts:
                continue

            def read(p=path, h=host) -> bytes:
                if h in backend._failed_hosts:
                    raise IOError(f"host {h} down; read of {p.name} lost")
                return p.read_bytes()

            out.append((f"host_{host:03d}", read))
        if out:
            return out
        # every placement's host is failed: fall through to get_blob so
        # the error message names each dead copy
    return [("store", lambda: backend.get_blob(name))]


def verify_restorable(backend: ShardedBackend, manifest: dict,
                      exclude: Optional[set] = None) -> List[str]:
    """Blob names a manifest references that no live host can serve —
    empty means the checkpoint is servable right now. (Used by
    ``ShardedBackend.commit_manifest`` to fail loudly instead of
    publishing a checkpoint whose writes were lost.)

    ``exclude``: hashes already verified elsewhere and skipped here —
    the commit path passes the parent chain link's references, which
    were verified when *that* manifest committed, so per-commit
    verification cost stays O(this snapshot's writes), not O(total
    checkpoint size)."""
    from repro.core.delta import referenced_hashes
    refs = referenced_hashes(manifest)
    if exclude:
        refs -= exclude
    return sorted(h for h in refs if not backend.has_blob(h))


# ---------------------------------------------------------------------------
# operator CLI: survey (and optionally repair) replica health
# ---------------------------------------------------------------------------

def report_json(rep: RepairReport) -> Dict:
    """A ``RepairReport`` as the stable JSON shape the CLI emits (the
    dataclass fields plus the derived ``degraded`` verdict)."""
    out = asdict(rep)
    out["degraded"] = rep.degraded
    return out


def main(argv: Optional[List[str]] = None) -> int:
    """``python -m repro.core.replication STORE [--json] [--repair]``

    Survey replica health before a planned restore: which blobs lost
    their primary or replica copy, and which lost every copy. Exits 0
    on a healthy (or fully repaired) store, 1 when degraded — so
    ``scan --json || page-someone`` works as an operator probe. The
    store spec goes through the same registry as ``--store``
    (``sharded:/path?hosts=4&replicate=1``, or ``cached:`` over it)."""
    import argparse
    import json as jsonmod
    import sys

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.replication",
        description="survey (and repair) peer-replica health of a "
                    "sharded checkpoint store")
    ap.add_argument("store", help="store spec, e.g. "
                                  "'sharded:/path?hosts=4&replicate=1'")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit the report as JSON on stdout")
    ap.add_argument("--repair", action="store_true",
                    help="rebuild missing copies from surviving peers "
                         "(scan only, by default)")
    ap.add_argument("--host", type=int, default=None,
                    help="with --repair: the host whose storage was "
                         "lost wholesale")
    args = ap.parse_args(argv)

    from repro.api.registry import resolve_backend
    backend = resolve_backend(args.store)
    # a cached: front is a read-through view; replication health is a
    # property of the replicating store underneath it
    backend = getattr(backend, "inner", backend)
    if not isinstance(backend, ShardedBackend):
        print(f"error: {args.store!r} resolves to "
              f"{type(backend).__name__}, but replica scan needs a "
              "sharded store (scheme 'sharded:', or 'cached:' over it)",
              file=sys.stderr)
        return 2
    rep = repair(backend, host=args.host) if args.repair else scan(backend)
    if args.as_json:
        print(jsonmod.dumps(report_json(rep), indent=2, sort_keys=True))
    else:
        verb = "repair" if args.repair else "scan"
        print(f"{verb}: {rep.blobs} blobs across {rep.hosts} hosts; "
              f"{rep.missing_primaries} missing primaries, "
              f"{rep.missing_replicas} missing replicas, "
              f"{rep.restored} restored, "
              f"{len(rep.unrecoverable)} unrecoverable")
    # a repair's report keeps what it *found* (and fixed); the exit code
    # answers "is the store healthy now" — so re-survey after a repair
    health = scan(backend) if args.repair else rep
    return 1 if health.degraded else 0


if __name__ == "__main__":  # pragma: no cover — exercised via main()
    import sys
    sys.exit(main())
