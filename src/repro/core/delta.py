"""Incremental (delta) checkpoints via content-addressed chunking —
the record-prune-replay idea (paper §VI) applied to snapshot payloads.

Every tensor is split into fixed-size chunks; each chunk is stored under
its blake2b hash. Unchanged data (frozen embeddings, stale optimizer
slots, the previous step's identical tensors when checkpointing more often
than updating) re-uses existing blobs for free, so the marginal cost of a
checkpoint is proportional to what actually changed.

Optional codec: int8 block quantization (see kernels/ckpt_codec) for
error-tolerant entries (optimizer moments), cutting bytes ~4x. The codec
is applied before chunking; its metadata travels in the leaf manifest.
"""
from __future__ import annotations

import hashlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

try:  # bfloat16 numpy interop (ships with jax)
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except Exception:  # pragma: no cover
    ml_dtypes = None
    _BF16 = None

CHUNK_BYTES = 4 * 1024 * 1024


def _hash(data: bytes) -> str:
    return hashlib.blake2b(data, digest_size=20).hexdigest()


def _np_dtype(name: str) -> np.dtype:
    if name == "bfloat16":
        if _BF16 is None:
            raise RuntimeError("ml_dtypes unavailable for bfloat16")
        return _BF16
    return np.dtype(name)


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

def _int8_encode(arr: np.ndarray) -> Dict[str, np.ndarray]:
    from repro.kernels.ckpt_codec.ref import quantize_ref
    q, scale = quantize_ref(np.asarray(arr, np.float32))
    return {"q": q, "scale": scale}


def _int8_decode(parts: Dict[str, np.ndarray], dtype: np.dtype,
                 shape: Tuple[int, ...]) -> np.ndarray:
    from repro.kernels.ckpt_codec.ref import dequantize_ref
    out = dequantize_ref(parts["q"], parts["scale"])
    return np.asarray(out[:int(np.prod(shape))].reshape(shape), dtype)


CODECS: Dict[str, Tuple[Callable, Callable]] = {
    "int8": (_int8_encode, _int8_decode),
}


# ---------------------------------------------------------------------------
# tensor <-> chunked blobs
# ---------------------------------------------------------------------------

def serialize_tensor(
    arr: np.ndarray,
    put_blob: Callable[[str, bytes], None],
    has_blob: Callable[[str], bool],
    codec: Optional[str] = None,
) -> Dict[str, Any]:
    """Chunk + store a tensor; returns its leaf manifest. Blobs whose hash
    already exists are skipped (the delta)."""
    arr = np.asarray(arr)
    meta: Dict[str, Any] = {
        "shape": list(arr.shape),
        "dtype": str(arr.dtype),
        "codec": codec,
        "parts": {},
    }
    parts: Dict[str, np.ndarray] = {"raw": arr}
    if codec is not None and arr.dtype.kind == "f" and arr.size >= 256:
        parts = CODECS[codec][0](arr)
    else:
        meta["codec"] = None

    written = 0
    for pname, p in parts.items():
        data = np.ascontiguousarray(p).tobytes()
        hashes: List[str] = []
        for off in range(0, max(len(data), 1), CHUNK_BYTES):
            chunk = data[off:off + CHUNK_BYTES]
            h = _hash(chunk)
            hashes.append(h)
            if not has_blob(h):
                put_blob(h, chunk)
                written += len(chunk)
        meta["parts"][pname] = {
            "dtype": str(p.dtype), "shape": list(p.shape), "chunks": hashes}
    meta["bytes_written"] = written
    return meta


def deserialize_tensor(meta: Dict[str, Any],
                       get_blob: Callable[[str], bytes]) -> np.ndarray:
    parts: Dict[str, np.ndarray] = {}
    for pname, pmeta in meta["parts"].items():
        data = b"".join(get_blob(h) for h in pmeta["chunks"])
        dt = _np_dtype(pmeta["dtype"])
        flat = np.frombuffer(data, dtype=dt)
        parts[pname] = flat.reshape(pmeta["shape"])
    dtype = _np_dtype(meta["dtype"])
    shape = tuple(meta["shape"])
    if meta.get("codec"):
        return CODECS[meta["codec"]][1](parts, dtype, shape)
    return np.asarray(parts["raw"], dtype).reshape(shape)


def referenced_hashes(manifest: Dict[str, Any]) -> set:
    out = set()
    for entry in manifest.get("entries", {}).values():
        for leaf in entry["leaves"].values():
            for pmeta in leaf["parts"].values():
                out.update(pmeta["chunks"])
    return out
