"""Delta codec: chunked, content-addressed, optionally chained snapshot
payloads (the record-prune-replay idea of paper §VI applied to bytes).

Three leaf encodings, chosen per tensor by the snapshot pipeline:

``full``   raw bytes, split into fixed-size chunks, each stored under its
           blake2b hash. Unchanged data (frozen embeddings, stale
           optimizer slots) re-uses existing blobs for free.
``codec``  lossy int8 block quantization (kernels/ckpt_codec — Pallas on
           TPU, numpy ref on host) applied before chunking; used for
           error-tolerant entries (optimizer moments), ~4x smaller.
``xor``    byte-level XOR against the *previous snapshot's* copy of the
           same leaf (through the ckpt_codec Pallas kernel when an
           accelerator is attached, numpy on host), forming a delta
           chain back to a full base snapshot.
           All-zero chunks (unchanged regions) are elided entirely, and
           non-zero chunks are zlib-compressed when that shrinks them, so
           the marginal cost of a snapshot is proportional to the entropy
           of what actually changed.

The encode API is *streaming*: ``encode_leaf`` walks a tensor one chunk
at a time (no whole-tensor XOR materialization) and hands each chunk to a
``put_blob`` callable, which the async snapshot pipeline backs with a
writer thread pool. ``decode_leaf`` inverts one link; chain walking lives
in ``core.async_snapshot.materialize_manifest_chain``.

Manifest leaf format (format 2) — format-1 metas (no "mode" key) are
still decoded for old checkpoints:

    {"shape": [...], "dtype": "f32", "mode": "full|codec|xor",
     "codec": "int8"|None,
     "parts": {part: {"dtype", "shape", "chunks": [hash|None, ...],
                      "enc": ["raw"|"zlib", ...]}}}

Format 3 adds *sparse* xor parts, produced by the dirty-chunk capture
path (``encode_leaf_sparse``): instead of a dense chunk list with None
placeholders, the part records only the chunks that changed —

    {"dtype", "shape", "chunk_bytes": int, "n_chunks": int,
     "dirty": [[chunk_idx, hash, enc], ...]}

Decoding a sparse part copies the base value and XOR-patches the dirty
chunks, so chain application cost also scales with the delta. Formats
1-3 are all decoded by this module (compatibility matrix in README).
"""
from __future__ import annotations

import hashlib
import zlib
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.api.errors import RestoreError, SnapshotError

try:  # bfloat16 numpy interop (ships with jax)
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except Exception:  # pragma: no cover
    ml_dtypes = None
    _BF16 = None

CHUNK_BYTES = 4 * 1024 * 1024

# chunk-level storage encodings
ENC_RAW = "raw"
ENC_ZLIB = "zlib"
# zlib level 1: ~GB/s on mostly-zero XOR streams, which is the case that
# matters; random float chunks fail the "did it shrink" test and stay raw
_ZLIB_LEVEL = 1


def _hash(data: bytes) -> str:
    return hashlib.blake2b(data, digest_size=20).hexdigest()


def _np_dtype(name: str) -> np.dtype:
    if name == "bfloat16":
        if _BF16 is None:
            raise SnapshotError("ml_dtypes unavailable for bfloat16")
        return _BF16
    return np.dtype(name)


# ---------------------------------------------------------------------------
# codecs (lossy, pre-chunking)
# ---------------------------------------------------------------------------

def _int8_encode(arr: np.ndarray) -> Dict[str, np.ndarray]:
    from repro.kernels.ckpt_codec.ref import quantize_ref
    q, scale = quantize_ref(np.asarray(arr, np.float32))
    return {"q": q, "scale": scale}


def _int8_decode(parts: Dict[str, np.ndarray], dtype: np.dtype,
                 shape: Tuple[int, ...]) -> np.ndarray:
    from repro.kernels.ckpt_codec.ref import dequantize_ref
    out = dequantize_ref(parts["q"], parts["scale"])
    return np.asarray(out[:int(np.prod(shape))].reshape(shape), dtype)


CODECS: Dict[str, Tuple[Callable, Callable]] = {
    "int8": (_int8_encode, _int8_decode),
}


def codec_applicable(arr: np.ndarray, codec: Optional[str]) -> bool:
    return (codec is not None and arr.dtype.kind == "f" and arr.size >= 256)


# ---------------------------------------------------------------------------
# streaming chunk encode/decode
# ---------------------------------------------------------------------------

def iter_chunk_views(arr: np.ndarray) -> Iterator[memoryview]:
    """Yield CHUNK_BYTES-sized byte views of a tensor without copying the
    whole thing (one contiguous materialization at most)."""
    flat = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
    n = flat.nbytes
    if n == 0:
        yield memoryview(b"")
        return
    mv = memoryview(flat)
    for off in range(0, n, CHUNK_BYTES):
        yield mv[off:off + CHUNK_BYTES]


_PROBE_BYTES = 64 * 1024


def _store_chunk(chunk: bytes, put_blob, has_blob,
                 compress: bool) -> Tuple[str, str, int]:
    """Store one chunk; returns (hash, enc, bytes_written)."""
    enc = ENC_RAW
    if compress and len(chunk) > 0:
        # probe a prefix first: full-chunk zlib on incompressible float
        # noise costs real encode-thread CPU for nothing, and snapshot
        # payloads are bimodal (sparse XOR deltas ~ all compressible,
        # fresh random weights ~ not at all)
        probe = chunk[:_PROBE_BYTES]
        if len(zlib.compress(probe, _ZLIB_LEVEL)) < len(probe) * 9 // 10:
            packed = zlib.compress(chunk, _ZLIB_LEVEL)
            if len(packed) < len(chunk) * 9 // 10:
                chunk, enc = packed, ENC_ZLIB
    h = _hash(chunk)
    if has_blob(h):
        return h, enc, 0
    put_blob(h, chunk)
    return h, enc, len(chunk)


def _load_chunk(entry: Optional[str], enc: str, length: int,
                get_blob) -> bytes:
    if entry is None:  # elided all-zero chunk
        return bytes(length)
    data = get_blob(entry)
    if enc == ENC_ZLIB:
        data = zlib.decompress(data)
    return data


_DEVICE_XOR_MIN_BYTES = 1 << 20
_device_xor: Optional[bool] = None


def _use_device_xor() -> bool:
    """XOR through the Pallas kernel when an accelerator is attached
    (kernels/ckpt_codec); the host path stays pure numpy so the encode
    thread never initializes jax on CPU-only deployments."""
    global _device_xor
    if _device_xor is None:
        try:
            import jax
            _device_xor = jax.default_backend() == "tpu"
        except Exception:  # pragma: no cover
            _device_xor = False
    return _device_xor


def _encode_part(p: np.ndarray, put_blob, has_blob, *,
                 prev: Optional[np.ndarray] = None,
                 compress: bool = True) -> Tuple[Dict[str, Any], int]:
    """Chunk one part array; XOR against `prev` chunk-by-chunk when given
    (streaming — never materializes the full delta)."""
    chunks: List[Optional[str]] = []
    encs: List[str] = []
    written = 0
    # the device-vs-host XOR decision is per-part, not per-chunk: the
    # backend probe is hoisted out of the chunk loop
    if prev is not None and _use_device_xor():
        from repro.kernels.ckpt_codec import ops

        def xor(a, b):
            if a.nbytes >= _DEVICE_XOR_MIN_BYTES:
                return ops.delta_encode(a, b)
            return np.bitwise_xor(a, b)
    else:
        xor = np.bitwise_xor
    prev_iter = iter_chunk_views(p if prev is None else prev)
    for view in iter_chunk_views(p):
        if prev is not None:
            pview = next(prev_iter)
            delta = xor(np.frombuffer(view, np.uint8),
                        np.frombuffer(pview, np.uint8))
            if not delta.any():
                chunks.append(None)   # unchanged region: costs nothing
                encs.append(ENC_RAW)
                continue
            data = delta.tobytes()
        else:
            data = view.tobytes()
        h, enc, w = _store_chunk(data, put_blob, has_blob, compress)
        chunks.append(h)
        encs.append(enc)
        written += w
    meta = {"dtype": str(p.dtype), "shape": list(p.shape),
            "chunks": chunks, "enc": encs}
    return meta, written


def _decode_part(pmeta: Dict[str, Any], get_blob,
                 prev: Optional[np.ndarray] = None) -> np.ndarray:
    if "dirty" in pmeta:  # format-3 sparse dirty-chunk part
        if prev is None:
            raise RestoreError("sparse xor part needs its base-step value")
        return _decode_part_sparse(pmeta, get_blob, prev)
    dt = _np_dtype(pmeta["dtype"])
    shape = pmeta["shape"]
    total = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
    encs = pmeta.get("enc") or [ENC_RAW] * len(pmeta["chunks"])
    out = np.empty(total, np.uint8)
    off = 0
    for entry, enc in zip(pmeta["chunks"], encs):
        length = min(CHUNK_BYTES, total - off) if total else 0
        data = _load_chunk(entry, enc, length, get_blob)
        buf = np.frombuffer(data, np.uint8)
        out[off:off + len(buf)] = buf
        off += len(buf)
    if prev is not None:
        pb = np.ascontiguousarray(prev).reshape(-1).view(np.uint8)
        np.bitwise_xor(out, pb, out=out)
    return out.view(dt).reshape(shape)


# ---------------------------------------------------------------------------
# sparse (dirty-chunk) encode/decode — manifest format 3 leaves
# ---------------------------------------------------------------------------

def encode_leaf_sparse(
    shape: Tuple[int, ...],
    dtype: np.dtype,
    chunk_bytes: int,
    n_chunks: int,
    dirty_idx: np.ndarray,
    dirty_bytes: np.ndarray,
    prev: np.ndarray,
    put_blob: Callable[[str, bytes], None],
    has_blob: Callable[[str], bool],
    *,
    compress: bool = True,
    patch_prev: bool = True,
) -> Dict[str, Any]:
    """Encode one leaf from a sparse dirty-chunk capture.

    ``dirty_bytes`` is the gather-compacted [k, chunk_bytes] uint8 payload
    from capture (tail chunk zero-padded); ``prev`` is the previous
    snapshot's full value of this leaf (the XOR base). Only the dirty
    chunks are XORed, hashed and stored — encode work scales with what
    changed, not with the leaf.

    When ``patch_prev`` (the pipeline's mode), ``prev`` is updated IN
    PLACE chunk by chunk, so after the leaf is encoded the buffer holds
    the *current* snapshot's bytes — the pipeline keeps one full host
    mirror alive instead of two.
    """
    dtype = np.dtype(dtype)
    total = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
    prev_b = np.ascontiguousarray(prev).reshape(-1).view(np.uint8)
    assert prev_b.size == total, (prev_b.size, total)
    dirty: List[List[Any]] = []
    written = encoded = 0
    idxs = np.asarray(dirty_idx, np.int64)
    if idxs.size and np.any(np.diff(idxs) < 0):  # capture emits sorted;
        order = np.argsort(idxs)                 # guard other callers
        idxs = idxs[order]
        dirty_bytes = dirty_bytes[order]
    # one vectorized XOR + fancy-index patch over every full dirty chunk
    # (the hot path: k SIMD row ops instead of a k-iteration Python
    # loop); only a partial tail chunk — at most one, and only when the
    # leaf isn't a chunk multiple — takes the scalar path below. idxs
    # arrive in ascending chunk order from capture, so the tail (the
    # largest index) is last and the manifest order is unchanged.
    n_full = total // chunk_bytes
    k_full = int(np.searchsorted(idxs, n_full))
    if k_full:
        grid = prev_b[:n_full * chunk_bytes].reshape(n_full, chunk_bytes)
        fi = idxs[:k_full]
        cur_rows = dirty_bytes[:k_full]
        deltas = np.bitwise_xor(cur_rows, grid[fi])
        changed = deltas.any(axis=1)
        if patch_prev:
            grid[fi] = cur_rows  # in-place mirror advance, one scatter
        encoded += k_full * chunk_bytes
        for j in np.nonzero(changed)[0]:
            h, enc, w = _store_chunk(deltas[j].tobytes(), put_blob,
                                     has_blob, compress)
            dirty.append([int(idxs[j]), h, enc])
            written += w
    for j in range(k_full, idxs.size):  # partial tail chunk
        off = int(idxs[j]) * chunk_bytes
        ln = total - off
        cur = dirty_bytes[j, :ln]
        pv = prev_b[off:off + ln]
        delta = np.bitwise_xor(cur, pv)
        encoded += ln
        if patch_prev:
            pv[:] = cur
        if not delta.any():
            continue  # conservative dirty mark; nothing actually changed
        h, enc, w = _store_chunk(delta.tobytes(), put_blob, has_blob,
                                 compress)
        dirty.append([int(idxs[j]), h, enc])
        written += w
    return {
        "shape": list(shape),
        "dtype": str(dtype),
        "codec": None,
        "mode": "xor",
        "parts": {"raw": {"dtype": str(dtype), "shape": list(shape),
                          "chunk_bytes": int(chunk_bytes),
                          "n_chunks": int(n_chunks),
                          "dirty": dirty}},
        "bytes_written": written,
        "bytes_encoded": encoded,
    }


def _decode_part_sparse(pmeta: Dict[str, Any], get_blob,
                        prev: np.ndarray) -> np.ndarray:
    """Sparse chain link: copy the base value and XOR-patch only the
    dirty chunks — chain application cost scales with the delta."""
    dt = _np_dtype(pmeta["dtype"])
    shape = pmeta["shape"]
    cb = pmeta["chunk_bytes"]
    total = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
    out = np.ascontiguousarray(prev).reshape(-1).view(np.uint8).copy()
    assert out.size == total, (out.size, total)
    for idx, entry, enc in pmeta["dirty"]:
        off = idx * cb
        ln = min(cb, total - off)
        data = _load_chunk(entry, enc, ln, get_blob)
        np.bitwise_xor(out[off:off + ln], np.frombuffer(data, np.uint8),
                       out=out[off:off + ln])
    return out.view(dt).reshape(shape)


# ---------------------------------------------------------------------------
# leaf encode/decode (one tensor, one chain link)
# ---------------------------------------------------------------------------

def encode_leaf(
    arr: np.ndarray,
    put_blob: Callable[[str, bytes], None],
    has_blob: Callable[[str], bool],
    *,
    codec: Optional[str] = None,
    prev: Optional[np.ndarray] = None,
    compress: bool = True,
) -> Dict[str, Any]:
    """Encode one tensor into blobs + leaf manifest.

    ``prev`` (same shape/dtype tensor from the previous snapshot) selects
    xor mode; ``codec`` selects the lossy codec (mutually exclusive with
    xor — quantized entries rely on chunk dedup instead, so requantization
    noise never accumulates along a chain)."""
    arr = np.asarray(arr)
    meta: Dict[str, Any] = {
        "shape": list(arr.shape),
        "dtype": str(arr.dtype),
        "codec": None,
        "parts": {},
    }
    written = 0
    if codec_applicable(arr, codec):
        meta["mode"] = "codec"
        meta["codec"] = codec
        for pname, p in CODECS[codec][0](arr).items():
            pmeta, w = _encode_part(p, put_blob, has_blob, compress=compress)
            meta["parts"][pname] = pmeta
            written += w
    elif (prev is not None and prev.shape == arr.shape
          and prev.dtype == arr.dtype):
        meta["mode"] = "xor"
        pmeta, w = _encode_part(arr, put_blob, has_blob, prev=prev,
                                compress=compress)
        meta["parts"]["raw"] = pmeta
        written += w
    else:
        meta["mode"] = "full"
        pmeta, w = _encode_part(arr, put_blob, has_blob, compress=compress)
        meta["parts"]["raw"] = pmeta
        written += w
    meta["bytes_written"] = written
    # dense modes read + process the whole leaf regardless of how little
    # changed; the sparse encoder reports only its dirty-chunk bytes here
    meta["bytes_encoded"] = arr.nbytes
    return meta


def decode_leaf(meta: Dict[str, Any],
                get_blob: Callable[[str], bytes],
                prev: Optional[np.ndarray] = None) -> np.ndarray:
    """Decode one leaf. xor-mode leaves need ``prev`` — the decoded value
    of the same leaf at the manifest's base step."""
    dtype = _np_dtype(meta["dtype"])
    shape = tuple(meta["shape"])
    mode = meta.get("mode")
    if mode is None:  # format-1 manifest
        mode = "codec" if meta.get("codec") else "full"
    if mode == "xor":
        if prev is None:
            raise RestoreError("xor leaf needs its base-step value")
        return _decode_part(meta["parts"]["raw"], get_blob,
                            prev=prev).reshape(shape)
    parts = {pname: _decode_part(pmeta, get_blob)
             for pname, pmeta in meta["parts"].items()}
    if mode == "codec":
        return CODECS[meta["codec"]][1](parts, dtype, shape)
    return np.asarray(parts["raw"], dtype).reshape(shape)


# ---------------------------------------------------------------------------
# format-1 compatibility shims (whole-tree, no chaining)
# ---------------------------------------------------------------------------

def serialize_tensor(
    arr: np.ndarray,
    put_blob: Callable[[str, bytes], None],
    has_blob: Callable[[str], bool],
    codec: Optional[str] = None,
) -> Dict[str, Any]:
    """Chunk + store a tensor (full/codec only). Kept for callers that
    predate the chained API; equivalent to ``encode_leaf`` without
    ``prev``."""
    return encode_leaf(arr, put_blob, has_blob, codec=codec)


def deserialize_tensor(meta: Dict[str, Any],
                       get_blob: Callable[[str], bytes],
                       prev: Optional[np.ndarray] = None) -> np.ndarray:
    return decode_leaf(meta, get_blob, prev=prev)


def leaf_blob_names(meta: Dict[str, Any]) -> List[str]:
    """Every blob hash one leaf's manifest meta references, in decode
    order (elided zero chunks excluded). The streaming-restore fetch
    planner sizes its per-leaf dependency counters from this — a leaf
    becomes decodable the moment the last of exactly these blobs lands."""
    out: List[str] = []
    for pmeta in meta["parts"].values():
        if "dirty" in pmeta:
            out.extend(h for _, h, _ in pmeta["dirty"])
        else:
            out.extend(h for h in pmeta["chunks"] if h is not None)
    return out


def referenced_hashes(manifest: Dict[str, Any]) -> set:
    out = set()
    for entry in manifest.get("entries", {}).values():
        for leaf in entry["leaves"].values():
            out.update(leaf_blob_names(leaf))
    return out
