"""Restore protocol (paper §II-III): fresh lower half + log replay +
upper-half rebinding, with elastic resharding.

Sequence (mirrors the paper's restart exactly):
  0. materialize the payload: ``CheckpointManager.restore`` walks the
     format-2 manifest's ``base_step`` delta chain back to its full base
     snapshot, decodes the base, and XOR-applies each delta link forward
     (core.async_snapshot.materialize_manifest_chain) — the caller sees
     plain host arrays regardless of how the snapshot was encoded.
  1. construct a fresh LowerHalf — the 'load a fresh copy of OpenGL'
     moment. An elastic restore passes a mesh_factory for the *new*
     topology; the logged MeshCreate then binds the replacement mesh to
     the same virtual mesh id.
  2. replay the (pruned) op-log: recompiles step functions, re-allocates
     caches, fast-forwards the data assignment — rebuilding driver state.
  3. materialize the upper half: every leaf is device_put with a
     NamedSharding derived from its *logical* axes and the new mesh's
     plan. Because nothing in the payload references physical devices,
     the same checkpoint lands on 512 chips, 256 chips, or 1 CPU.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.checkpoint import CheckpointManager, RestoredState
from repro.core.split_state import LowerHalf, UpperHalf, fill_like, flatten_with_paths
from repro.parallel.sharding import ParallelPlan, spec_for_axes
from jax.sharding import NamedSharding, PartitionSpec


def restorable_steps(backend) -> List[int]:
    """Committed steps whose full delta chain is still present — a step
    whose base manifest was GC'd (or never landed) is excluded. What an
    operator should consult before picking a restore target."""
    from repro.core.async_snapshot import manifest_chain_steps
    have = set(backend.list_steps())
    out = []
    for s in sorted(have):
        try:
            chain = manifest_chain_steps(backend, s)
        except FileNotFoundError:
            continue
        if all(b in have for b in chain):
            out.append(s)
    return out


def fresh_lower_half(restored: RestoredState,
                     mesh_factory: Optional[Callable] = None) -> LowerHalf:
    """Steps 1-2: fresh runtime, replay the log."""
    lower = LowerHalf(mesh_factory=mesh_factory)
    restored.oplog.replay(lower)
    # the replayed ops become the new incarnation's log (so a subsequent
    # checkpoint of this incarnation carries the full history forward)
    lower.oplog = restored.oplog
    return lower


def materialize_entry(
    restored: RestoredState,
    name: str,
    template,
    plan: Optional[ParallelPlan],
    mesh,
    logical_template=None,
):
    """Step 3 for one entry: path-matched leaves -> sharded device arrays.

    template: abstract pytree (ShapeDtypeStructs or arrays) giving
    structure + dtypes; logical_template: matching pytree of logical axis
    tuples (None leaves -> replicated)."""
    by_path = restored.entries[name]
    host_tree = fill_like(template, by_path)

    if mesh is None:
        return jax.tree.map(
            lambda ab, v: jax.numpy.asarray(v, dtype=ab.dtype),
            template, host_tree)

    if logical_template is None:
        shardings = jax.tree.map(
            lambda ab: NamedSharding(mesh, PartitionSpec()), template)
    else:
        # logical leaves are tuples of axis names, which tree.map would
        # recurse into — match by path instead
        lpaths = dict(flatten_with_paths_tuples(logical_template))
        tleaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_leaves = []
        for p, ab in tleaves:
            axes = lpaths.get(jax.tree_util.keystr(p))
            spec = spec_for_axes(plan, axes, ab.shape, mesh) \
                if axes is not None and plan is not None else PartitionSpec()
            shard_leaves.append(NamedSharding(mesh, spec))
        shardings = jax.tree_util.tree_unflatten(treedef, shard_leaves)

    def put(ab, v, sh):
        arr = np.asarray(v)
        if str(arr.dtype) != str(ab.dtype):
            arr = arr.astype(ab.dtype)
        return jax.device_put(arr, sh)

    return jax.tree.map(put, template, host_tree, shardings)


def flatten_with_paths_tuples(tree):
    """Flatten a logical-axes pytree whose leaves are tuples of
    axis-name strings (tuples must not be recursed into)."""
    out = []
    paths = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))[0]
    for p, v in paths:
        out.append((jax.tree_util.keystr(p), v))
    return out


def restore_scalar(restored: RestoredState, name: str):
    """Entries that are plain scalars/int trees (step counters, cursors)."""
    by_path = restored.entries[name]
    if list(by_path) == [""]:
        v = by_path[""]
        return v.item() if hasattr(v, "item") and v.ndim == 0 else v
    return by_path
