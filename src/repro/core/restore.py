"""Restore primitives (paper §II-III): the per-phase building blocks of
the restart sequence.

The lifecycle that *orders* these — materialize the delta chain, fresh
LowerHalf + ``new_incarnation()``, op-log replay, upper-half rebinding
with logical-axes shardings — is owned by ``core.incarnation.
Incarnation``; both the trainer and the serving engine resume through
it. This module keeps the phase primitives it calls (``materialize_
entry``, ``restore_scalar``) plus operator-facing queries over a
checkpoint directory (``restorable_steps``).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.checkpoint import CheckpointManager, RestoredState
from repro.core.split_state import LowerHalf, UpperHalf, fill_like, flatten_with_paths
from repro.parallel.sharding import ParallelPlan, spec_for_axes
from jax.sharding import NamedSharding, PartitionSpec


def restorable_steps(backend) -> List[int]:
    """Committed steps whose full delta chain is still present — a step
    whose base manifest was GC'd (or never landed) is excluded. What an
    operator should consult before picking a restore target.

    Each manifest is read exactly once: one ascending pass memoizes the
    ``base_step`` links, and chain validity propagates base-first (a
    sorted step's base is always <= it, so its verdict is already
    known). O(n) manifest reads, not O(n * chain length)."""
    have = set(backend.list_steps())
    base: Dict[int, Optional[int]] = {}
    for s in sorted(have):
        try:
            base[s] = backend.get_manifest(s).get("base_step")
        except FileNotFoundError:
            continue  # raced a concurrent GC; treat as not restorable
    ok: Dict[int, bool] = {}
    for s in sorted(base):
        b = base[s]
        ok[s] = b is None or ok.get(b, False)
    return [s for s in sorted(have) if ok.get(s, False)]


def fresh_lower_half(restored: RestoredState,
                     mesh_factory: Optional[Callable] = None) -> LowerHalf:
    """Steps 1-2: fresh runtime, replay the log. (Single-phase shim —
    new callers should drive core.incarnation.Incarnation instead.)"""
    lower = LowerHalf(mesh_factory=mesh_factory)
    restored.oplog.replay(lower)
    # the replayed ops become the new incarnation's log (so a subsequent
    # checkpoint of this incarnation carries the full history forward)
    lower.oplog = restored.oplog
    return lower


def materialize_entry(
    restored: RestoredState,
    name: str,
    template,
    plan: Optional[ParallelPlan],
    mesh,
    logical_template=None,
):
    """Step 3 for one entry: path-matched leaves -> sharded device arrays.

    template: abstract pytree (ShapeDtypeStructs or arrays) giving
    structure + dtypes; logical_template: matching pytree of logical axis
    tuples (None leaves -> replicated)."""
    by_path = restored.entries[name]
    # a streaming restore hands us a LazyLeaves Mapping: binding a whole
    # entry is a bulk page-in, so wait for it as one promoted batch
    # instead of faulting leaf-by-leaf through fill_like
    waiter = getattr(by_path, "wait", None)
    if callable(waiter):
        waiter()
    host_tree = fill_like(template, by_path)

    if mesh is None:
        return jax.tree.map(
            lambda ab, v: jax.numpy.asarray(v, dtype=ab.dtype),
            template, host_tree)

    if logical_template is None:
        shardings = jax.tree.map(
            lambda ab: NamedSharding(mesh, PartitionSpec()), template)
    else:
        # logical leaves are tuples of axis names, which tree.map would
        # recurse into — match by path instead
        lpaths = dict(flatten_with_paths_tuples(logical_template))
        tleaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_leaves = []
        for p, ab in tleaves:
            axes = lpaths.get(jax.tree_util.keystr(p))
            spec = spec_for_axes(plan, axes, ab.shape, mesh) \
                if axes is not None and plan is not None else PartitionSpec()
            shard_leaves.append(NamedSharding(mesh, spec))
        shardings = jax.tree_util.tree_unflatten(treedef, shard_leaves)

    def put(ab, v, sh):
        arr = np.asarray(v)
        if str(arr.dtype) != str(ab.dtype):
            arr = arr.astype(ab.dtype)
        return jax.device_put(arr, sh)

    return jax.tree.map(put, template, host_tree, shardings)


def flatten_with_paths_tuples(tree):
    """Flatten a logical-axes pytree whose leaves are tuples of
    axis-name strings (tuples must not be recursed into)."""
    out = []
    paths = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))[0]
    for p, v in paths:
        out.append((jax.tree_util.keystr(p), v))
    return out


def restore_scalar(restored: RestoredState, name: str):
    """Entries that are plain scalars/int trees (step counters, cursors)."""
    by_path = restored.entries[name]
    if list(by_path) == [""]:
        v = by_path[""]
        return v.item() if hasattr(v, "item") and v.ndim == 0 else v
    return by_path
