"""CheckpointManager: periodic, asynchronous, atomic snapshots of the
upper half (paper §I: "taking periodic snapshots of the editor program in
the background").

Save path:
  1. (caller thread, blocking, fast) pull upper-half tensors to host —
     the only step that must pause the step loop;
  2. (background thread) codec + chunk + content-addressed blob writes
     (delta vs whatever already exists) through the backend;
  3. atomic manifest commit — a checkpoint exists iff its manifest does.

The manifest bundles the PRUNED op-log (record-prune-replay) and the
upper-half structure (leaf paths, dtypes, logical sharding axes), which is
everything restore needs on any topology.
"""
from __future__ import annotations

import json
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Dict, Optional

import numpy as np

from repro.core.backends.base import CheckpointBackend
from repro.core.delta import (serialize_tensor, deserialize_tensor,
                              referenced_hashes)
from repro.core.oplog import OpLog
from repro.core.split_state import UpperHalf


@dataclass
class RestoredState:
    step: int
    manifest: Dict[str, Any]
    # entry -> leaf path -> np.ndarray
    entries: Dict[str, Dict[str, np.ndarray]]
    oplog: OpLog


class CheckpointManager:
    def __init__(
        self,
        backend: CheckpointBackend,
        *,
        codec_by_kind: Optional[Dict[str, str]] = None,
        async_save: bool = True,
        keep_last: Optional[int] = None,
        prune_oplog: bool = True,
    ) -> None:
        self.backend = backend
        # e.g. {"opt_state": "int8"} — moments tolerate quantization
        self.codec_by_kind = codec_by_kind or {}
        self.async_save = async_save
        self.keep_last = keep_last
        self.prune_oplog = prune_oplog
        self._pool = ThreadPoolExecutor(max_workers=1)  # ordered commits
        self._pending: Optional[Future] = None
        self.stats: Dict[str, Any] = {"saves": 0, "bytes_written": 0,
                                      "bytes_logical": 0, "save_seconds": 0.0}

    # --- save -------------------------------------------------------------

    def save(self, step: int, upper: UpperHalf, oplog: OpLog,
             block: bool = False,
             job_meta: Optional[Dict[str, Any]] = None) -> Optional[Future]:
        t0 = time.monotonic()
        host_state = upper.to_host()          # snapshot point (blocking)
        structure = upper.structure()
        kinds = {name: e.kind for name, e in upper.items()}
        log = oplog.prune() if self.prune_oplog else oplog
        log_json = log.to_json()
        snapshot_s = time.monotonic() - t0

        def _write() -> int:
            t1 = time.monotonic()
            entries_manifest: Dict[str, Any] = {}
            written = logical = 0
            for name, leaves in host_state.items():
                codec = self.codec_by_kind.get(kinds[name])
                leaf_metas = {}
                for path, arr in leaves.items():
                    m = serialize_tensor(
                        arr, self.backend.put_blob, self.backend.has_blob,
                        codec=codec)
                    written += m.pop("bytes_written", 0)
                    logical += arr.nbytes
                    leaf_metas[path] = m
                entries_manifest[name] = {"kind": kinds[name],
                                          "leaves": leaf_metas}
            manifest = {
                "step": step,
                "entries": entries_manifest,
                "oplog": log_json,
                "structure": structure,
                "job": job_meta or {},
                "format": 1,
            }
            self.backend.commit_manifest(step, manifest)
            self.stats["saves"] += 1
            self.stats["bytes_written"] += written
            self.stats["bytes_logical"] += logical
            self.stats["save_seconds"] += snapshot_s + (time.monotonic() - t1)
            if self.keep_last is not None:
                self._gc(self.keep_last)
            return written

        if self.async_save and not block:
            self.wait()                        # keep at most one in flight
            self._pending = self._pool.submit(_write)
            return self._pending
        _write()
        return None

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # --- restore ------------------------------------------------------------

    def restore(self, step: Optional[int] = None) -> RestoredState:
        self.wait()
        if step is None:
            step = self.backend.latest_step()
            if step is None:
                raise FileNotFoundError("no committed checkpoints")
        manifest = self.backend.get_manifest(step)
        entries: Dict[str, Dict[str, np.ndarray]] = {}
        for name, e in manifest["entries"].items():
            entries[name] = {
                path: deserialize_tensor(meta, self.backend.get_blob)
                for path, meta in e["leaves"].items()
            }
        oplog = OpLog.from_json(manifest["oplog"])
        return RestoredState(step=step, manifest=manifest, entries=entries,
                             oplog=oplog)

    # --- gc -------------------------------------------------------------------

    def _gc(self, keep_last: int) -> None:
        steps = self.backend.list_steps()
        drop = steps[:-keep_last] if keep_last > 0 else []
        for s in drop:
            self.backend.delete_step(s)
        referenced = set()
        for s in self.backend.list_steps():
            referenced |= referenced_hashes(self.backend.get_manifest(s))
        self.backend.gc_blobs(referenced)
