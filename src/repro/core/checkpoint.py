"""CheckpointManager: the phased (capture / encode / commit) snapshot
API over the async pipeline in ``core.async_snapshot``.

``save`` is capture-then-return: the caller thread pays only the
device→staging copy; delta encoding (``core.delta`` +
``kernels.ckpt_codec``) and backend writes overlap subsequent train or
serve steps on the pipeline's encode thread + writer pool. A checkpoint
exists iff its manifest committed (fsync+rename in the backend), so a
crash mid-write never corrupts the latest checkpoint.

Manifests are format 2 or 3: they may record a ``base_step``, forming a
delta chain of XOR links back to a full base snapshot
(``delta_base_interval``). With ``sparse_capture`` (the default when
chaining), chain links are *sparse*: capture fingerprints each leaf
per-chunk (kernels/ckpt_codec) and transfers only dirty chunks, and the
manifest (format 3) records only those chunks. ``restore`` materializes
the chain — full base decoded first, each delta link applied forward —
and returns host state plus the PRUNED op-log (record-prune-replay) and
upper-half structure, which is everything restore needs on any topology.
Formats 1-3 all restore through the same path (matrix in README).

Synchronous behavior (``async_save=False`` or ``save(block=True)``) runs
the same pipeline and joins it before returning.

This manager is mechanism; applications should construct it through the
public surface — ``repro.api.Policy.build_manager`` (validated
configuration) inside a ``repro.api.CheckpointSession`` (the lifecycle
facade) — rather than spelling the kwargs here.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import numpy as np

from repro.core.async_snapshot import (AsyncSnapshotter, SnapshotHandle,
                                       materialize_manifest_chain)
from repro.core.backends.base import CheckpointBackend
from repro.core.oplog import OpLog
from repro.core.split_state import UpperHalf


@dataclass
class RestoredState:
    step: int
    manifest: Dict[str, Any]
    # entry -> leaf path -> np.ndarray. Under a streaming restore, cold
    # entries are LazyLeaves (Mapping) still decoding in the background;
    # every consumer below this dataclass speaks Mapping, so the
    # distinction is invisible except to whoever reads `streamer`.
    entries: Dict[str, Dict[str, np.ndarray]]
    oplog: OpLog
    # the StreamingMaterializer that owns in-flight cold entries (None
    # for an eager restore) — per-source/overlap stats and bulk waits
    streamer: Any = None


class CheckpointManager:
    def __init__(
        self,
        backend: CheckpointBackend,
        *,
        codec_by_kind: Optional[Dict[str, str]] = None,
        async_save: bool = True,
        keep_last: Optional[int] = None,
        prune_oplog: bool = True,
        delta_base_interval: int = 1,
        backpressure: str = "block",
        writers: int = 4,
        compress: bool = True,
        sparse_capture: bool = True,
        sparse_chunk_bytes: Optional[int] = None,
        sparse_min_bytes: Optional[int] = None,
    ) -> None:
        self.backend = backend
        # e.g. {"opt_state": "int8"} — moments tolerate quantization
        self.codec_by_kind = codec_by_kind or {}
        self.async_save = async_save
        self.keep_last = keep_last
        extra: Dict[str, Any] = {}
        if sparse_chunk_bytes is not None:
            extra["sparse_chunk_bytes"] = sparse_chunk_bytes
        self.pipeline = AsyncSnapshotter(
            backend,
            codec_by_kind=codec_by_kind,
            delta_base_interval=delta_base_interval,
            backpressure=backpressure,
            writers=writers,
            compress=compress,
            keep_last=keep_last,
            prune_oplog=prune_oplog,
            sparse_capture=sparse_capture,
            sparse_min_bytes=sparse_min_bytes,
            **extra,
        )

    @property
    def stats(self) -> Dict[str, Any]:
        return self.pipeline.stats

    # --- save -------------------------------------------------------------

    def save(self, step: int, upper: UpperHalf, oplog: OpLog,
             block: bool = False,
             job_meta: Optional[Dict[str, Any]] = None,
             ) -> Optional[SnapshotHandle]:
        """Phase 1 (capture) on this thread; phases 2-3 in the pipeline.

        Returns a SnapshotHandle to the in-flight snapshot, or None when
        it completed synchronously — or was dropped by a "skip"
        backpressure policy (distinguish via ``stats['skipped']``). A
        blocking save is never dropped: asking to block is asking to
        wait for a slot."""
        blocking = block or not self.async_save
        handle = self.pipeline.snapshot(step, upper, oplog,
                                        job_meta=job_meta,
                                        must_take=blocking)
        if handle is None:
            return None
        if blocking:
            try:
                handle.result()
            except BaseException as e:
                self.pipeline.consume_error(e)  # delivered here, not to
                raise                           # a later wait()
            return None
        return handle

    def wait(self) -> None:
        """Join the pipeline; re-raises the latest failed snapshot."""
        self.pipeline.drain()

    def close(self) -> None:
        """Drain and shut down the pipeline's threads. Long-lived
        processes creating managers per job should close them (or use
        the manager as a context manager)."""
        self.pipeline.close()

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # --- restore ------------------------------------------------------------

    def resolve_step(self, step: Optional[int] = None) -> int:
        """Drain in-flight snapshots, then resolve a restore target:
        the latest committed step when ``step`` is None. Raises
        FileNotFoundError when nothing is committed."""
        self.wait()
        if step is None:
            step = self.backend.latest_step()
            if step is None:
                raise FileNotFoundError("no committed checkpoints")
        return step

    def restore(self, step: Optional[int] = None,
                workers: Optional[int] = None,
                skip_entries=(), *, streaming: bool = False,
                lazy_kinds=None) -> RestoredState:
        """Materialize a committed checkpoint's delta chain into host
        arrays. ``workers`` sizes the leaf-decode pool (restore latency
        matters as much as checkpoint overhead — CRIUgpu's point);
        ``skip_entries`` names entries the caller will rebuild instead
        of rebind, left undecoded.

        ``streaming=True`` returns as soon as the hot tier (op-log,
        session state, params) is decoded: entries of the cold kinds
        (``lazy_kinds``, default optimizer moments + KV cache) are
        ``LazyLeaves`` placeholders that keep fetching/decoding in the
        background and block their first toucher — bit-identical to the
        eager path, earlier by the cold tier's fetch+decode time. The
        full restart lifecycle on top of this is ``core.incarnation``."""
        step = self.resolve_step(step)
        streamer = None
        if streaming:
            from repro.core.streaming import (DEFAULT_LAZY_KINDS,
                                              materialize_streaming)
            manifest, entries, streamer = materialize_streaming(
                self.backend, step, workers=workers,
                skip_entries=skip_entries,
                lazy_kinds=(DEFAULT_LAZY_KINDS if lazy_kinds is None
                            else lazy_kinds))
        else:
            manifest, entries = materialize_manifest_chain(
                self.backend, step, workers=workers,
                skip_entries=skip_entries)
        oplog = OpLog.from_json(manifest["oplog"])
        return RestoredState(step=step, manifest=manifest, entries=entries,
                             oplog=oplog, streamer=streamer)

    # retention GC lives in the pipeline (AsyncSnapshotter.gc) and runs
    # on the encode thread after each commit when keep_last is set — do
    # not call it from other threads, it races in-flight encodes
