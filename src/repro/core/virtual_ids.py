"""Virtual handle translation (paper §III).

The runtime hands out resources whose real identities are not stable
across a restart: meshes bound to physical devices, compiled executables,
KV-cache allocations. Exactly like OpenGL's GLuint ids, the real handle
obtained after restart differs from the one obtained originally — so the
application (and the op-log) only ever hold *virtual ids*, and a
translation table maps them to the current incarnation's real objects.

On restore, replay repopulates the table: the same vids come to denote
freshly created real objects, and nothing above the table notices.
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Generic, Iterator, Optional, Tuple, TypeVar

T = TypeVar("T")


class StaleHandleError(KeyError):
    """A vid from a previous incarnation was used without rebinding."""


@dataclass(frozen=True)
class VirtualId:
    """Opaque, serializable handle. ``kind`` is a namespace ("mesh",
    "exec", "cache", ...); ``uid`` is unique within the table's life
    across incarnations (monotone, never reused)."""

    kind: str
    uid: int

    def __repr__(self) -> str:
        return f"<v:{self.kind}#{self.uid}>"


class HandleTable:
    """vid -> real object, with incarnation generations.

    * ``create(kind, obj)``  — allocate a vid bound to obj (logged side).
    * ``bind(vid, obj)``     — (re)bind an existing vid (replay side).
    * ``translate(vid)``     — real object for the *current* incarnation;
                               raises StaleHandleError if not rebound.
    * ``new_incarnation()``  — invalidate all bindings (fresh lower half),
                               keeping vids allocated so replay can rebind.
    """

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._next_uid = itertools.count(1)
        self._generation = 0
        # vid -> (generation, obj)
        self._real: Dict[VirtualId, Tuple[int, Any]] = {}
        self._allocated: Dict[VirtualId, None] = {}

    @property
    def generation(self) -> int:
        return self._generation

    def create(self, kind: str, obj: Any) -> VirtualId:
        with self._lock:
            vid = VirtualId(kind, next(self._next_uid))
            self._allocated[vid] = None
            self._real[vid] = (self._generation, obj)
            return vid

    def allocate(self, kind: str) -> VirtualId:
        """Allocate a vid with no binding yet (e.g. pre-declared)."""
        with self._lock:
            vid = VirtualId(kind, next(self._next_uid))
            self._allocated[vid] = None
            return vid

    def bind(self, vid: VirtualId, obj: Any) -> VirtualId:
        with self._lock:
            if vid not in self._allocated:
                # replay of a log from a previous process: adopt the vid,
                # bumping the uid counter past it so future ids stay unique
                self._allocated[vid] = None
                self._next_uid = itertools.count(
                    max(vid.uid + 1, next(self._next_uid)))
            self._real[vid] = (self._generation, obj)
            return vid

    def translate(self, vid: VirtualId) -> Any:
        with self._lock:
            entry = self._real.get(vid)
            if entry is None:
                raise StaleHandleError(
                    f"{vid} has no binding in generation {self._generation}")
            gen, obj = entry
            if gen != self._generation:
                raise StaleHandleError(
                    f"{vid} bound in generation {gen}, current is "
                    f"{self._generation}; replay must rebind it")
            return obj

    def is_bound(self, vid: VirtualId) -> bool:
        with self._lock:
            e = self._real.get(vid)
            return e is not None and e[0] == self._generation

    def release(self, vid: VirtualId) -> None:
        with self._lock:
            self._real.pop(vid, None)
            self._allocated.pop(vid, None)

    def new_incarnation(self) -> int:
        """Start a fresh lower half: every binding becomes stale."""
        with self._lock:
            self._generation += 1
            return self._generation

    def live_vids(self):
        with self._lock:
            return [v for v, (g, _) in self._real.items()
                    if g == self._generation]


# --- host correspondence -----------------------------------------------------

class HostMap:
    """Logical host coordinate -> physical host rank, via the same
    vid/handle indirection as every other unstable resource.

    A multi-host job addresses its peers by *logical* rank (shard
    ownership, collective neighbors, heartbeat identity). The physical
    rank behind a logical host is exactly as unstable as a GLuint: a
    hot-spare takeover rebinds the dead host's logical coordinate to the
    spare's physical rank, and nothing holding the logical id notices —
    the supervisor's failure loop (``core.supervisor``) drives these
    rebinds. Translation goes through a ``HandleTable``, so a logical
    host whose physical backing died and was never remapped raises
    ``StaleHandleError`` instead of silently resolving to a corpse."""

    def __init__(self, hosts) -> None:
        self._table = HandleTable()
        self._vids: Dict[int, VirtualId] = {
            l: self._table.create("host", p) for l, p in enumerate(hosts)}

    def logical_hosts(self) -> list:
        return sorted(self._vids)

    def vid(self, logical: int) -> VirtualId:
        return self._vids[logical]

    def physical(self, logical: int) -> int:
        """Current physical rank behind a logical host (raises
        StaleHandleError if it was unbound and never remapped)."""
        return self._table.translate(self._vids[logical])

    def physical_hosts(self) -> list:
        """Physical ranks of every *bound* logical host, logical order."""
        return [self._table.translate(v)
                for l, v in sorted(self._vids.items())
                if self._table.is_bound(v)]

    def logical_of(self, physical: int) -> Optional[int]:
        for l in sorted(self._vids):
            v = self._vids[l]
            if self._table.is_bound(v) and \
                    self._table.translate(v) == physical:
                return l
        return None

    def remap(self, logical: int, physical: int) -> VirtualId:
        """Hot-spare takeover: the same logical coordinate now denotes a
        different physical host; the vid is stable across the rebind."""
        return self._table.bind(self._vids[logical], physical)

    def unbind(self, logical: int) -> None:
        """Shrink: the logical host leaves the world (its vid survives,
        translating it raises until a future grow remaps it; ``bind``
        re-adopts released vids, so ``remap`` can revive the slot)."""
        self._table.release(self._vids[logical])

    def admit(self, physical: int) -> int:
        """Grow: bind a physical host to a logical slot — the lowest
        coordinate a shrink/drain vacated if one exists (``bind``
        re-adopts the released vid, so shard ownership keyed on the
        logical rank revives with it), else a brand-new coordinate past
        the current world. Returns the logical rank."""
        for l in sorted(self._vids):
            if not self._table.is_bound(self._vids[l]):
                self._table.bind(self._vids[l], physical)
                return l
        l = max(self._vids) + 1 if self._vids else 0
        self._vids[l] = self._table.create("host", physical)
        return l


# --- device correspondence ---------------------------------------------------

class DeviceMap:
    """Logical mesh coordinate -> physical device, per incarnation.

    The upper half references only (axis_name, index) coordinates; this is
    the paper's upper/lower thread-correspondence problem mapped to
    devices. Elastic restarts rebuild it over a different topology."""

    def __init__(self) -> None:
        self._mesh = None

    def bind_mesh(self, mesh) -> None:
        self._mesh = mesh

    def device_at(self, **coords):
        if self._mesh is None:
            raise StaleHandleError("no mesh bound in this incarnation")
        idx = tuple(coords.get(a, 0) for a in self._mesh.axis_names)
        return self._mesh.devices[idx]

    @property
    def mesh(self):
        if self._mesh is None:
            raise StaleHandleError("no mesh bound in this incarnation")
        return self._mesh
