"""ClusterSupervisor: the failure loop, closed (detect → decide → act).

The paper's economics only land when nobody has to be paged: a crash
costs seconds *if* something notices the death, picks a response, and
drives the restart — MANA-for-MPI and CRIUgpu (PAPERS.md) both ship a
coordinator for exactly this reason. Before this module the ingredients
existed but nothing wired them together: ``HeartbeatMonitor`` /
``FailurePolicy`` (core/failure.py) produced decisions nobody executed,
and the restore machinery (core/incarnation.py) waited to be hand-driven.

The supervisor runs the loop on a (simulated or real) multi-host world:

    heartbeats ──> HeartbeatMonitor.dead_hosts()
                        │
                   FailurePolicy.decide()
                        │
          ┌─────────────┼──────────────────┐
     HOT_SPARE        SHRINK         RESTART_LAST_CKPT
     HostMap remap    unbind dead    (world unchanged;
     + logged         logical hosts   hosts restart in
     DataReassign     + elastic       place)
     (no restore —    restore onto   storage repair +
     peer-replicated  survivors +    Incarnation restore
     state covers     rebalance      from latest
     the loss)        shards         restorable step

Execution is real, not advisory: HOT_SPARE rebinds the dead host's
logical coordinate to a spare through ``core.virtual_ids.HostMap`` and
replays a logged ``DataReassign`` (``rebalance_shards``) so the
decision survives a *later* restart; SHRINK and RESTART tear the runner
down, repair a degraded ``ShardedBackend`` from peer replicas
(``core.replication``) and rebuild the runner through the caller's
``restore`` hook — which drives the Incarnation lifecycle (the
``RestoreTarget`` it receives carries the step, the surviving topology
and a ready-made ``rewrite_op`` for re-shard/re-slot replay).

The runner-*specific* rebuild stays with the caller as the ``restore``
hook — the supported wiring is ``repro.api.CheckpointSession.
supervise``, whose hook resolves the checkpoint's app kind through the
registry and rebuilds whatever workload the manifest names; everything
policy-shaped — detection, decision, storage repair, host-map surgery,
reassignment logging, MTTR accounting — lives here, once, for every
kind. ``launch/train.py --supervise`` and ``launch/serve.py
--supervise`` route production entry points through it;
``benchmarks/mttr.py`` measures detection→serving-again per policy.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.api.errors import CheckpointError
from repro.core.failure import (FailureAction, FailurePolicy,
                                HeartbeatMonitor, HostState,
                                StragglerDetector, rebalance_shards)
from repro.core.oplog import DataReassign, Op
from repro.core.virtual_ids import HostMap


@dataclass
class RestoreTarget:
    """Everything a ``restore`` hook needs to rebuild the runner after a
    decision: which action, which checkpoint step, which physical hosts
    survive, and the rebalanced shard assignment (if the supervisor
    manages shards). ``rewrite_op()`` hands the hook an op-log rewriter
    that replays any logged ``DataReassign`` onto the new assignment —
    the elastic re-shard path through ``Incarnation(rewrite_op=...)``."""
    action: FailureAction
    step: Optional[int]                       # latest restorable step
    hosts: List[int]                          # physical world after the act
    dead: List[int] = field(default_factory=list)
    mapping: Dict[int, int] = field(default_factory=dict)   # dead -> spare
    assignment: Optional[Tuple[Tuple[int, int], ...]] = None

    def rewrite_op(self) -> Optional[Callable[[Op], Op]]:
        if self.assignment is None:
            return None
        assignment = tuple(map(tuple, self.assignment))

        def rewrite(op: Op) -> Op:
            if isinstance(op, DataReassign):
                return dataclasses.replace(op, assignment=assignment)
            return op
        return rewrite


@dataclass
class Incident:
    """One executed decision, with its MTTR: detection (the poll that
    flagged the death) → runner serving again (restore hook returned /
    remap+reassign applied). ``wall_s`` is real elapsed time — the
    number to report; ``mttr_s`` uses the supervisor's injected clock,
    which in simulated worlds usually doesn't advance mid-execution
    (kept for callers whose clock IS wall time)."""
    action: str
    dead: List[int]
    step: Optional[int]
    mttr_s: float
    wall_s: float


class SupervisorError(CheckpointError, RuntimeError):
    """The supervisor could not execute a decision (no restore hook, no
    restorable checkpoint, unrecoverable storage)."""


class ClusterSupervisor:
    """Runs the detect→decide→execute loop for one job.

    ``hosts``    physical ranks the job starts on (logical coordinates
                 0..n-1 are bound to them through a ``HostMap``).
    ``manager``  CheckpointManager — consulted for the latest restorable
                 step and (ShardedBackend) storage repair.
    ``spares``   idle physical ranks the HOT_SPARE policy may consume.
    ``restore``  Callable[[RestoreTarget], runner] — rebuilds the runner
                 through the Incarnation lifecycle; the supported hook
                 is the one ``CheckpointSession.supervise`` wires (the
                 app-kind registry). Required for RESTART/SHRINK.
    ``teardown`` Callable[[runner], None] — optional explicit kill of
                 the current runner before a restore (default: drop the
                 reference; a real launcher would kill pods here).
    ``reassign`` Callable[[runner, assignment], None] — apply + *log* a
                 shard reassignment on the live runner. Defaults to
                 duck-typing ``runner.apply_reassignment`` (Trainer).
    ``n_shards`` data shards the supervisor balances across hosts; None
                 disables shard management (serving).

    The supervisor is deliberately synchronous and single-threaded: the
    caller owns the loop (beat → poll → step), which is what makes the
    whole failure path unit-testable with an injected clock — the same
    property ``HeartbeatMonitor`` was built around.
    """

    def __init__(self, hosts: List[int], *,
                 manager=None,
                 spares: Optional[List[int]] = None,
                 heartbeat_timeout: float = 60.0,
                 clock: Callable[[], float] = time.monotonic,
                 allow_shrink: bool = True,
                 n_shards: Optional[int] = None,
                 restore: Optional[Callable[[RestoreTarget], Any]] = None,
                 teardown: Optional[Callable[[Any], None]] = None,
                 reassign: Optional[Callable[[Any, Any], None]] = None,
                 straggler_k: float = 1.5,
                 repair_storage: bool = True,
                 runner: Any = None,
                 event_sink: Optional[
                     Callable[[float, str, Dict[str, Any]], None]] = None,
                 ) -> None:
        self.clock = clock
        self.manager = manager
        self.hostmap = HostMap(hosts)
        self.monitor = HeartbeatMonitor(list(hosts),
                                        timeout=heartbeat_timeout,
                                        clock=clock)
        self.policy = FailurePolicy(spares=list(spares or []),
                                    allow_shrink=allow_shrink)
        self.stragglers = StragglerDetector(self.monitor, k=straggler_k)
        self.n_shards = n_shards
        self.repair_storage = repair_storage
        self._restore = restore
        self._teardown = teardown
        self._reassign = reassign
        self.runner = runner
        self.incidents: List[Incident] = []
        self.events: List[Tuple[float, str, Dict[str, Any]]] = []
        # optional live tap on the event stream (incident logs): called
        # as (t, kind, detail) for every _event, as it happens
        self._event_sink = event_sink
        # the last assignment THIS supervisor applied; None until it has
        # rebalanced once. Deliberately not seeded with a synthetic
        # initial layout: the runner may have logged its own
        # reassignments, and a restart must replay that log untouched
        # unless the supervisor itself changed the topology.
        self._assignment: Optional[Tuple[Tuple[int, int], ...]] = None

    # --- world state ----------------------------------------------------

    @property
    def world(self) -> List[int]:
        """Physical hosts currently running the job (logical order)."""
        return self.hostmap.physical_hosts()

    def _quiesce(self) -> None:
        """Join the snapshot pipeline and absorb a casualty failure: an
        in-flight snapshot whose writer died WITH the host re-raises
        from ``wait()`` (the backend's loud-write contract), but that
        casualty is part of the incident being handled — recovery must
        proceed from the last *committed* step, not crash on it."""
        if self.manager is None:
            return
        try:
            self.manager.wait()
        except Exception as e:  # noqa: BLE001 — logged, incident-scoped
            self._event("casualty_snapshot", error=repr(e))

    def latest_restorable_step(self) -> Optional[int]:
        if self.manager is None:
            return None
        from repro.core.restore import restorable_steps
        self._quiesce()
        ok = restorable_steps(self.manager.backend)
        return ok[-1] if ok else None

    def _event(self, kind: str, **detail) -> None:
        t = self.clock()
        self.events.append((t, kind, detail))
        if self._event_sink is not None:
            self._event_sink(t, kind, detail)

    # --- the loop: ingest, detect, decide, execute ----------------------

    def beat(self, host: int, step: int) -> None:
        """Heartbeat from a *physical* host (launcher loop / simulator)."""
        if host in self.monitor.hosts:
            self.monitor.beat(host, step)

    def poll(self) -> Optional[RestoreTarget]:
        """One detect→decide→execute cycle. Returns the executed
        decision's RestoreTarget (action NONE is returned as None)."""
        dead = self.monitor.dead_hosts()
        if not dead:
            return None
        t0, w0 = self.clock(), time.monotonic()
        action, info = self.policy.decide(dead, world=self.world)
        self._event("decision", action=action.value, dead=list(dead),
                    **{k: v for k, v in info.items() if k != "survivors"})
        if action is FailureAction.HOT_SPARE:
            target = self._do_hot_spare(dead, info["mapping"])
        elif action is FailureAction.SHRINK:
            target = self._do_shrink(dead, info["survivors"])
        elif action is FailureAction.RESTART_LAST_CKPT:
            target = self._do_restart(dead)
        else:  # pragma: no cover — decide() never returns NONE for dead
            return None
        self.incidents.append(Incident(
            action=action.value, dead=list(dead), step=target.step,
            mttr_s=self.clock() - t0, wall_s=time.monotonic() - w0))
        return target

    def check_stragglers(self) -> List[int]:
        """Straggler mitigation: hosts whose per-step EWMA exceeds
        k×median get their data shards moved to the fast hosts, as a
        *logged* DataReassign — the rebalance replays after any later
        restart. Returns the flagged hosts (possibly already handled)."""
        slow = self.stragglers.stragglers()
        if not slow or self.n_shards is None:
            return slow
        fast = [h for h in self.world if h not in slow]
        if not fast:
            return slow
        self._apply_assignment(rebalance_shards(self.n_shards, fast),
                               reason="straggler", hosts=slow)
        return slow

    def planned_move(self, host: int, to: Optional[int] = None, *,
                     rebuild: bool = False) -> RestoreTarget:
        """Proactively drain a HEALTHY host — the maintenance twin of the
        failure loop, sharing its machinery instead of reinventing it.

        With a landing host (``to``, defaulting to the first spare) the
        move is the hot-spare sequence minus the death: quiesce, repair,
        rebind the host's logical coordinate to the target — the vid
        stays stable, so shard ownership and the heartbeat world follow
        — and return the *drained* host to the spare pool (it is
        healthy; a later failure may consume it). ``rebuild=True``
        additionally tears the runner down and rebuilds it through the
        restore hook on the new world (for runners that pin physical
        resources the remap alone can't move).

        With no landing host available the world shrinks on purpose:
        the drained host leaves, and the runner rebuilds on the
        survivors through the same ``_recover`` path a SHRINK decision
        uses — which requires a restorable checkpoint, exactly like a
        real shrink."""
        logical = self.hostmap.logical_of(host)
        if logical is None:
            raise SupervisorError(
                f"host {host} is not part of this job's world "
                f"({self.hostmap.physical_hosts()}); nothing to drain")
        if to is None and self.policy.spares:
            to = self.policy.spares[0]
        if to is not None and to in self.world:
            raise SupervisorError(
                f"target {to} already serves this job; a planned move "
                "needs an idle landing host (or None to shrink)")
        t0, w0 = self.clock(), time.monotonic()
        if to is not None:
            self._quiesce()
            self._repair()
            self.hostmap.remap(logical, to)
            self.monitor.hosts.pop(host, None)
            self.monitor.hosts[to] = HostState(last_heartbeat=self.clock())
            if to in self.policy.spares:
                self.policy.spares.remove(to)
            self.policy.spares.append(host)   # drained, not dead: reusable
            self._event("planned_move", host=host, to=to, logical=logical)
            hosts = self.world
            assignment = None
            if self.n_shards is not None:
                assignment = self._apply_assignment(
                    rebalance_shards(self.n_shards, hosts),
                    reason="planned_move", hosts=[to])
            target = RestoreTarget(FailureAction.PLANNED_MOVE, step=None,
                                   hosts=hosts, mapping={host: to},
                                   assignment=assignment)
            if rebuild:
                self._recover(target)
            else:
                self._reset_heartbeats()
            action = "planned_move"
        else:
            survivors = [h for h in self.world if h != host]
            if not survivors:
                raise SupervisorError(
                    f"draining host {host} would empty the world; give "
                    "the job a spare to land on first")
            self.hostmap.unbind(logical)
            self.monitor.hosts.pop(host, None)
            assignment = (tuple(rebalance_shards(self.n_shards, survivors))
                          if self.n_shards is not None else None)
            target = RestoreTarget(FailureAction.PLANNED_MOVE, step=None,
                                   hosts=survivors, assignment=assignment)
            self._recover(target)
            self._event("restored", action="planned_drain",
                        step=target.step, hosts=survivors)
            action = "planned_drain"
        self.incidents.append(Incident(
            action=action, dead=[], step=target.step,
            mttr_s=self.clock() - t0, wall_s=time.monotonic() - w0))
        return target

    def grow(self, host: Optional[int] = None) -> RestoreTarget:
        """Elastic expansion — the inverse of SHRINK: admit an idle
        physical host into the world and rebuild the runner onto the
        larger topology from the latest restorable step (snapshot first
        and the grow loses zero steps). The host binds to the lowest
        logical coordinate a previous shrink/drain vacated (its vid
        revives, so shard ownership keyed on the logical rank follows)
        or to a brand-new coordinate; shards rebalance over the grown
        world and the ``RestoreTarget``'s ``rewrite_op`` replays the
        logged ``DataReassign`` onto the new assignment during
        Incarnation replay — the same elastic-restore machinery a
        shrink uses, pointed the other way.

        ``host`` defaults to the first spare (a returned/recovered host
        re-admitted to the pool rejoins as capacity, not dead weight).
        """
        if host is None:
            if not self.policy.spares:
                raise SupervisorError(
                    "grow needs an idle host to admit and the spare "
                    "pool is empty")
            host = self.policy.spares[0]
        if host in self.world:
            raise SupervisorError(
                f"host {host} already serves this job "
                f"({self.world}); grow admits an *idle* host")
        t0, w0 = self.clock(), time.monotonic()
        if host in self.policy.spares:
            self.policy.spares.remove(host)
        logical = self.hostmap.admit(host)
        self.monitor.hosts[host] = HostState(last_heartbeat=self.clock())
        hosts = self.world
        assignment = (tuple(rebalance_shards(self.n_shards, hosts))
                      if self.n_shards is not None else None)
        self._event("grow", host=host, logical=logical, hosts=hosts)
        target = RestoreTarget(FailureAction.GROW, step=None,
                               hosts=hosts, assignment=assignment)
        self._recover(target)
        if assignment is not None:
            # same dance as _do_shrink: the rewrite only transforms an
            # *existing* logged DataReassign — read what replay landed
            # and log the grown assignment freshly if it didn't
            current = getattr(getattr(self.runner, "lower", None),
                              "data_assignment", None)
            self._assignment = (tuple(map(tuple, current))
                                if current is not None else None)
            self._apply_assignment(assignment, reason="grow",
                                   hosts=[host])
        self._event("restored", action="grow", step=target.step,
                    hosts=hosts)
        self.incidents.append(Incident(
            action="grow", dead=[], step=target.step,
            mttr_s=self.clock() - t0, wall_s=time.monotonic() - w0))
        return target

    # --- decision execution ---------------------------------------------

    def _do_hot_spare(self, dead: List[int],
                      mapping: Dict[int, int]) -> RestoreTarget:
        """Rebind each dead host's *logical* coordinate to its spare —
        the vid stays stable, so everything addressing the logical rank
        (shard ownership, the heartbeat world) follows the remap — then
        rebalance shards over the new physical world, logged. No
        rollback: peer-replicated state covers the loss — which is
        exactly why storage repair runs here too (a no-op when the
        dead host's storage survived): the next snapshot must not die
        on a writer the takeover left down."""
        self._quiesce()   # in-flight writers stop before repair copies
        self._repair()
        for d, s in mapping.items():
            logical = self.hostmap.logical_of(d)
            if logical is None:
                raise SupervisorError(
                    f"dead host {d} has no logical coordinate (world: "
                    f"{self.hostmap.physical_hosts()}); cannot hand its "
                    f"role to spare {s}")
            self.hostmap.remap(logical, s)
            del self.monitor.hosts[d]
            self.monitor.hosts[s] = HostState(last_heartbeat=self.clock())
            if s in self.policy.spares:
                self.policy.spares.remove(s)
            self._event("hot_spare", dead=d, spare=s, logical=logical)
        hosts = self.world
        assignment = None
        if self.n_shards is not None:
            assignment = self._apply_assignment(
                rebalance_shards(self.n_shards, hosts),
                reason="hot_spare", hosts=list(mapping.values()))
        # storage repair may have blocked this thread past the timeout
        self._reset_heartbeats()
        return RestoreTarget(FailureAction.HOT_SPARE, step=None,
                             hosts=hosts, dead=list(dead),
                             mapping=dict(mapping), assignment=assignment)

    def _do_shrink(self, dead: List[int],
                   survivors: List[int]) -> RestoreTarget:
        """Elastic restore onto the surviving topology: dead logical
        hosts leave the world, the runner is rebuilt from the latest
        restorable step with shards rebalanced over the survivors — the
        ``RestoreTarget``'s ``rewrite_op`` replays the logged
        ``DataReassign`` onto the new assignment during Incarnation
        replay (the re-shard twin of serving's re-slot rewrite)."""
        for d in dead:
            logical = self.hostmap.logical_of(d)
            if logical is not None:
                self.hostmap.unbind(logical)
            self.monitor.hosts.pop(d, None)
        assignment = (tuple(rebalance_shards(self.n_shards, survivors))
                      if self.n_shards is not None else None)
        target = RestoreTarget(FailureAction.SHRINK, step=None,
                               hosts=list(survivors), dead=list(dead),
                               assignment=assignment)
        self._recover(target)
        if assignment is not None:
            # the rewrite only transforms an *existing* logged
            # DataReassign; a log that never rebalanced has none — read
            # what replay actually applied and log the survivor
            # assignment freshly if it didn't land
            current = getattr(getattr(self.runner, "lower", None),
                              "data_assignment", None)
            self._assignment = (tuple(map(tuple, current))
                                if current is not None else None)
            self._apply_assignment(assignment, reason="shrink",
                                   hosts=list(dead))
        self._event("restored", action="shrink", step=target.step,
                    hosts=list(survivors))
        return target

    def _do_restart(self, dead: List[int]) -> RestoreTarget:
        """Classic C/R: the world keeps its geometry (dead hosts restart
        in place — a rescheduled pod with the same logical rank), the
        runner tears down and resumes through the Incarnation from the
        latest restorable step."""
        target = RestoreTarget(FailureAction.RESTART_LAST_CKPT, step=None,
                               hosts=self.world, dead=list(dead),
                               assignment=self._assignment)
        self._recover(target)
        self._event("restored", action="restart_last_ckpt",
                    step=target.step, hosts=target.hosts)
        return target

    # --- execution helpers ----------------------------------------------

    def _recover(self, target: RestoreTarget) -> None:
        """The one recovery sequence both rebuilding policies share:
        tear the runner down, quiesce in-flight snapshot writers,
        repair degraded storage, resolve the restore step, rebuild the
        runner through the caller's hook, and give every survivor a
        fresh heartbeat grace period (the whole sequence blocked this
        single thread — without the reset, a recovery longer than the
        timeout would make the next poll declare healthy hosts dead).
        Fills ``target.step`` and replaces ``self.runner``."""
        self._teardown_runner()
        self._quiesce()   # in-flight writers stop before repair copies
        self._repair()
        target.step = self._require_step()
        self.runner = self._run_restore(target)
        self._reset_heartbeats()

    def _reset_heartbeats(self) -> None:
        """Give every monitored host a fresh grace period: execution
        blocked this thread, so nobody's beat could be ingested while a
        decision (teardown + repair + restore) ran."""
        now = self.clock()
        for st in self.monitor.hosts.values():
            st.last_heartbeat = now
            st.alive = True

    def _teardown_runner(self) -> None:
        """Kill the current runner — after giving it the protocol's
        optional ``quiesce()`` hook (CheckpointableApp): an app that
        buffers work gets one chance to flush before its replacement is
        rebuilt. A quiesce failure is part of the incident being
        handled, not a new crash."""
        if self.runner is not None:
            q = getattr(self.runner, "quiesce", None)
            if callable(q):
                try:
                    q()
                except Exception as e:  # noqa: BLE001 — incident-scoped
                    self._event("quiesce_failed", error=repr(e))
            if self._teardown is not None:
                self._teardown(self.runner)
        self.runner = None

    def _repair(self) -> None:
        """Rebuild a degraded ShardedBackend from peer replicas before
        the restore depends on it. Storage geometry is independent of
        the compute world (the N virtual storage hosts are directories,
        not processes), so repair always restores full redundancy and
        re-admits the repaired hosts — a shrink changes who *computes*,
        not where blobs live."""
        if not self.repair_storage or self.manager is None:
            return
        backend = getattr(self.manager, "backend", None)
        from repro.core.backends.sharded import ShardedBackend
        if not isinstance(backend, ShardedBackend):
            return
        # cheap probe before the O(all blobs) sweep: a host death shows
        # up as an injected writer failure or a missing host directory.
        # Keeps the hot-spare path O(n_hosts) when storage survived —
        # the common case the ~ms takeover MTTR is advertised on.
        degraded = bool(backend._failed_hosts) or any(
            not (backend.root / f"host_{h:03d}").is_dir()
            for h in range(backend.n_hosts))
        if not degraded:
            return
        from repro.core import replication
        rep = replication.repair(backend)
        if rep.restored or rep.unrecoverable:
            self._event("storage_repair", restored=rep.restored,
                        unrecoverable=len(rep.unrecoverable))
        if rep.unrecoverable:
            raise SupervisorError(
                f"{len(rep.unrecoverable)} blob(s) lost every copy "
                f"(first: {rep.unrecoverable[0]}); the latest "
                "checkpoint(s) referencing them are not restorable")

    def _require_step(self) -> int:
        step = self.latest_restorable_step()
        if step is None:
            raise SupervisorError("no restorable checkpoint to resume "
                                  "from (and the job is down)")
        return step

    def _run_restore(self, target: RestoreTarget) -> Any:
        if self._restore is None:
            raise SupervisorError(
                f"decision {target.action.value} needs a restore hook "
                "to rebuild the runner")
        return self._restore(target)

    def _apply_assignment(self, assignment, *, reason: str,
                          hosts: List[int]):
        assignment = tuple(map(tuple, assignment))
        if assignment == self._assignment:
            return assignment
        self._assignment = assignment
        if self._reassign is not None:
            self._reassign(self.runner, assignment)
        elif self.runner is not None and \
                hasattr(self.runner, "apply_reassignment"):
            self.runner.apply_reassignment(assignment)
        self._event("reassign", reason=reason, hosts=hosts,
                    assignment=assignment)
        return assignment

    # --- observability ----------------------------------------------------

    def mttr(self) -> Dict[str, float]:
        """Worst observed MTTR per executed action, in *wall* seconds —
        the injected clock typically stands still while a decision
        executes (it only ticks when the caller's loop runs), so
        ``Incident.wall_s`` is the number that means anything here.
        ``benchmarks/mttr.py`` additionally folds in the restored
        runner's first step, which this accounting cannot see."""
        out: Dict[str, float] = {}
        for inc in self.incidents:
            out[inc.action] = max(out.get(inc.action, 0.0), inc.wall_s)
        return out
